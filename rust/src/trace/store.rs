//! Content-addressed on-disk trace cache.
//!
//! After the fused evaluator (PR 4), trace generation — the
//! gamma/Dirichlet/multinomial draw loop — is the dominant cost of a
//! sweep. But a routed trace is a pure function of `(model, parallel,
//! seed, iterations, provenance)`, so re-sweeping the same (model,
//! seed) cells — new methods, new memory budgets, new MACT bins, a
//! re-run campaign — regenerates byte-for-byte identical traces. The
//! [`TraceStore`] caches them instead: one compact binary file per
//! trace cell, keyed by the FNV-1a 64 hash of the trace's canonical
//! identity document, shared by every `memfine sweep` / `memfine
//! launch` shard process pointed at the same campaign `--dir`.
//!
//! Safety properties, in the spirit of the checkpoint layer:
//!
//! * **Exact**: records round-trip through `u64`/f64-bit encoding, so
//!   a warm-cache sweep is bit-identical to a cold one (pinned by
//!   engine tests and a CI smoke).
//! * **Torn-write tolerant**: files are written to a per-process temp
//!   name and atomically renamed into place; loads validate magic,
//!   length, key and a trailing FNV checksum, and any mismatch is a
//!   cache miss (the trace regenerates and overwrites), never an
//!   error.
//! * **Concurrency-safe**: shard processes own disjoint cells, and
//!   even racing writers of the same key write identical bytes, so
//!   the atomic rename makes the last one win harmlessly.
//!
//! The store is **tiered** (PR 10): a per-campaign tier sits in front
//! of an optional global root shared across campaigns and hosts
//! (`--trace-cache` on `memfine launch`). Loads fall through to the
//! global tier on a campaign miss and promote hits forward; saves
//! populate both. Content-addressed keys make the sharing safe — two
//! campaigns that agree on a key agree on the bytes — and a corrupt
//! global entry degrades to a regenerate-miss exactly like a corrupt
//! campaign entry, never a failed sweep. `memfine trace-cache
//! stats|gc` keeps a long-lived global root bounded.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::config::{ModelConfig, ParallelConfig};
use crate::error::{Error, Result};
use crate::json;
use crate::trace::provenance::TraceProvenance;
use crate::trace::{RoutingRecord, SharedRoutingTrace};
use crate::util::fnv1a_64;

/// File magic: "MFTR" + format version. Bump on any layout change.
const MAGIC: &[u8; 8] = b"MFTRC001";
/// Fixed header: magic + key + seed + iterations + moe_layers + count.
const HEADER_BYTES: usize = 8 + 5 * 8;
/// Bytes per record: min_recv + mean_recv bits + max_recv.
const RECORD_BYTES: usize = 3 * 8;

/// Content hash (16 hex chars) of a trace's identity: everything that
/// decides its drawn bits. Model and parallel geometry enter via their
/// canonical JSON (same writer the scenario hash uses), provenance via
/// its version-stable hash fields — so, like scenario hashes, trace
/// keys agree across processes, hosts and releases.
pub fn trace_key(
    model: &ModelConfig,
    parallel: &ParallelConfig,
    seed: u64,
    iterations: u64,
    prov: &TraceProvenance,
) -> String {
    let mut fields = vec![
        ("iterations", json::num(iterations as f64)),
        ("model", model.to_json()),
        ("parallel", parallel.to_json()),
        ("seed", json::num(seed as f64)),
    ];
    fields.extend(prov.hash_fields());
    let doc = json::obj(fields);
    format!("{:016x}", fnv1a_64(doc.to_string_compact().as_bytes()))
}

/// In-flight tmp files older than this are debris from a dead writer
/// (a crashed or chaos-killed shard) and are swept on `open`. Live
/// writers rename within milliseconds; an hour is conservatively far
/// from any race.
const TMP_TTL: Duration = Duration::from_secs(3600);

/// Aggregate size of a cache tier, for `memfine trace-cache stats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreStats {
    /// Complete `.trace` entries.
    pub entries: usize,
    /// Their total size in bytes.
    pub bytes: u64,
}

/// What an age-based `gc` pass evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GcStats {
    /// Entries (and stale tmp files) removed.
    pub removed: usize,
    /// Bytes reclaimed.
    pub bytes: u64,
}

/// A directory of cached traces, one `<key>.trace` file per cell,
/// optionally backed by a second, cross-campaign global tier.
#[derive(Clone, Debug)]
pub struct TraceStore {
    dir: PathBuf,
    global: Option<PathBuf>,
}

impl TraceStore {
    /// Open (creating if missing) a single-tier cache rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        Self::open_tiered(dir, None)
    }

    /// Open a cache rooted at `dir` with an optional global tier
    /// behind it. Both directories are created if missing, and stale
    /// in-flight tmp files (older than [`TMP_TTL`]) are swept from
    /// each — debris from writers that died mid-save.
    pub fn open_tiered(
        dir: impl Into<PathBuf>,
        global: Option<&Path>,
    ) -> Result<Self> {
        let dir = dir.into();
        ensure_tier(&dir)?;
        let global = match global {
            Some(g) if g == dir => None, // same root twice: one tier
            Some(g) => {
                ensure_tier(g)?;
                Some(g.to_path_buf())
            }
            None => None,
        };
        Ok(TraceStore { dir, global })
    }

    /// The cache file a key maps to in the campaign (front) tier.
    pub fn path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.trace"))
    }

    /// The global-tier root, if this store is tiered.
    pub fn global_dir(&self) -> Option<&Path> {
        self.global.as_deref()
    }

    /// Complete `.trace` entries currently on disk (tmp files and
    /// foreign names excluded) — an observability read for `memfine
    /// status`; 0 on an unreadable directory, never an error.
    pub fn entry_count(&self) -> usize {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return 0;
        };
        entries
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().and_then(|x| x.to_str()) == Some("trace")
            })
            .count()
    }

    /// Load the trace cached under `key`, reconstructing it against
    /// the caller's (model, parallel) identity. The campaign tier is
    /// consulted first; on a miss (including a torn or corrupt file)
    /// the global tier is tried, and a global hit is promoted forward
    /// into the campaign tier best-effort. Returns `None` — a cache
    /// miss — only when no tier holds a valid entry; the caller
    /// regenerates and overwrites.
    pub fn load(
        &self,
        key: &str,
        model: &ModelConfig,
        parallel: &ParallelConfig,
        seed: u64,
        iterations: u64,
    ) -> Option<SharedRoutingTrace> {
        if let Ok(bytes) = std::fs::read(self.path(key)) {
            if let Some(t) = decode(&bytes, key, model, parallel, seed, iterations) {
                return Some(t);
            }
        }
        let global = self.global.as_deref()?;
        let bytes = std::fs::read(global.join(format!("{key}.trace"))).ok()?;
        let trace = decode(&bytes, key, model, parallel, seed, iterations)?;
        // promote: the bytes just validated, so the campaign tier can
        // adopt them verbatim; failure to promote is just a slower hit
        // next time, never an error
        write_entry(&self.dir, key, &bytes).ok();
        Some(trace)
    }

    /// Cache `trace` under `key`: serialise to a pid+counter-unique
    /// temp file and atomically rename into place, so readers only
    /// ever see a complete file and racing writers of the same key —
    /// even threads within one process — are harmless (identical
    /// content by determinism). The campaign tier is authoritative
    /// (its write errors surface as cache-degrade); the global tier,
    /// when present, is populated best-effort.
    pub fn save(&self, key: &str, trace: &SharedRoutingTrace) -> Result<()> {
        // the on-disk format implies full coverage from iteration 0;
        // range traces (intra-cell splits) are never cached
        assert_eq!(trace.first_iteration, 0, "trace store only holds whole-cell traces");
        // chaos drills inject IO faults here; callers already treat a
        // failed save as cache-degrade (count it, keep the in-memory
        // trace), so an injected ENOSPC exercises that exact path
        crate::faultfs::check(crate::faultfs::SITE_TRACE_STORE).map_err(Error::Io)?;
        let moe_layers = trace.moe_layers() as u64;
        let key_u64 = u64::from_str_radix(key, 16)
            .map_err(|_| Error::config(format!("trace key '{key}' is not 16 hex chars")))?;
        let mut bytes =
            Vec::with_capacity(HEADER_BYTES + trace.records.len() * RECORD_BYTES + 8);
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&key_u64.to_le_bytes());
        bytes.extend_from_slice(&trace.seed.to_le_bytes());
        bytes.extend_from_slice(&trace.iterations.to_le_bytes());
        bytes.extend_from_slice(&moe_layers.to_le_bytes());
        bytes.extend_from_slice(&(trace.records.len() as u64).to_le_bytes());
        for r in &trace.records {
            bytes.extend_from_slice(&r.min_recv.to_le_bytes());
            bytes.extend_from_slice(&r.mean_recv.to_bits().to_le_bytes());
            bytes.extend_from_slice(&r.max_recv.to_le_bytes());
        }
        let checksum = fnv1a_64(&bytes);
        bytes.extend_from_slice(&checksum.to_le_bytes());

        write_entry(&self.dir, key, &bytes).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("write trace cache {}/{key}.trace: {e}", self.dir.display()),
            ))
        })?;
        if let Some(global) = &self.global {
            // best-effort: a full or read-only global root must never
            // fail the sweep that already has its campaign-tier copy
            write_entry(global, key, &bytes).ok();
        }
        Ok(())
    }

    /// Entry count and byte total for the campaign tier (or the only
    /// tier of a single-tier store) — `memfine trace-cache stats`.
    /// Unreadable directories read as empty, never an error.
    pub fn stats(&self) -> StoreStats {
        tier_stats(&self.dir)
    }

    /// Evict every `.trace` entry (and any tmp debris) in the campaign
    /// tier whose mtime is older than `max_age` — `memfine trace-cache
    /// gc`. Content-addressing makes eviction always safe: a future
    /// sweep that wants an evicted trace regenerates it.
    pub fn gc(&self, max_age: Duration) -> GcStats {
        tier_gc(&self.dir, max_age)
    }
}

/// Create a tier directory and sweep stale tmp debris from it.
fn ensure_tier(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir).map_err(|e| {
        Error::Io(std::io::Error::new(
            e.kind(),
            format!("trace cache {}: {e}", dir.display()),
        ))
    })?;
    sweep_stale_tmp(dir, TMP_TTL);
    Ok(())
}

/// Remove in-flight tmp files older than `ttl` — writers that died
/// between write and rename leave them behind forever otherwise.
fn sweep_stale_tmp(dir: &Path, ttl: Duration) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.filter_map(|e| e.ok()) {
        let path = e.path();
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.contains(".tmp.") {
            continue;
        }
        // a future mtime reads as age zero: clock skew must not make
        // a live writer's tmp file look ancient
        let age = e
            .metadata()
            .ok()
            .and_then(|m| m.modified().ok())
            .map(|t| t.elapsed().unwrap_or(Duration::ZERO))
            .unwrap_or(Duration::ZERO);
        if age >= ttl {
            std::fs::remove_file(&path).ok();
        }
    }
}

/// Atomically install `bytes` as `dir/<key>.trace` via a
/// pid+counter-unique tmp name (no two live writers ever share one).
fn write_entry(dir: &Path, key: &str, bytes: &[u8]) -> std::io::Result<()> {
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = dir.join(format!("{key}.tmp.{}.{seq}", std::process::id()));
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, dir.join(format!("{key}.trace"))).map_err(|e| {
        std::fs::remove_file(&tmp).ok();
        e
    })
}

/// Decode and validate one cache file against the caller's identity.
/// Any structural or identity mismatch is `None` — a miss.
fn decode(
    bytes: &[u8],
    key: &str,
    model: &ModelConfig,
    parallel: &ParallelConfig,
    seed: u64,
    iterations: u64,
) -> Option<SharedRoutingTrace> {
    if bytes.len() < HEADER_BYTES + 8 || &bytes[..8] != MAGIC {
        return None;
    }
    let payload = &bytes[..bytes.len() - 8];
    if fnv1a_64(payload) != read_u64(bytes, bytes.len() - 8) {
        return None;
    }
    let file_key = read_u64(bytes, 8);
    let file_seed = read_u64(bytes, 16);
    let file_iterations = read_u64(bytes, 24);
    let moe_layers = read_u64(bytes, 32);
    let count = read_u64(bytes, 40);
    let want_moe = model.layers - model.dense_layers;
    if u64::from_str_radix(key, 16).ok()? != file_key
        || file_seed != seed
        || file_iterations != iterations
        || moe_layers != want_moe
        || count != iterations.saturating_mul(moe_layers)
        || bytes.len() != HEADER_BYTES + count as usize * RECORD_BYTES + 8
    {
        return None;
    }
    let mut records = Vec::with_capacity(count as usize);
    for i in 0..count as usize {
        let off = HEADER_BYTES + i * RECORD_BYTES;
        records.push(RoutingRecord {
            iteration: i as u64 / moe_layers,
            layer: model.dense_layers + i as u64 % moe_layers,
            min_recv: read_u64(bytes, off),
            mean_recv: f64::from_bits(read_u64(bytes, off + 8)),
            max_recv: read_u64(bytes, off + 16),
        });
    }
    Some(SharedRoutingTrace {
        seed,
        iterations,
        model: model.clone(),
        parallel: parallel.clone(),
        first_iteration: 0,
        records,
    })
}

/// Entry count + bytes of complete `.trace` files under `dir`.
fn tier_stats(dir: &Path) -> StoreStats {
    let mut stats = StoreStats { entries: 0, bytes: 0 };
    let Ok(entries) = std::fs::read_dir(dir) else { return stats };
    for e in entries.filter_map(|e| e.ok()) {
        if e.path().extension().and_then(|x| x.to_str()) != Some("trace") {
            continue;
        }
        stats.entries += 1;
        stats.bytes += e.metadata().map(|m| m.len()).unwrap_or(0);
    }
    stats
}

/// Age-based eviction under `dir`: `.trace` entries older than
/// `max_age` go, as does any tmp debris past the same age.
fn tier_gc(dir: &Path, max_age: Duration) -> GcStats {
    let mut out = GcStats { removed: 0, bytes: 0 };
    let Ok(entries) = std::fs::read_dir(dir) else { return out };
    for e in entries.filter_map(|e| e.ok()) {
        let path = e.path();
        let is_trace =
            path.extension().and_then(|x| x.to_str()) == Some("trace");
        let is_tmp = e
            .file_name()
            .to_str()
            .is_some_and(|n| n.contains(".tmp."));
        if !is_trace && !is_tmp {
            continue;
        }
        let Ok(meta) = e.metadata() else { continue };
        let age = meta
            .modified()
            .ok()
            .map(|t| t.elapsed().unwrap_or(Duration::ZERO))
            .unwrap_or(Duration::ZERO);
        if age >= max_age && std::fs::remove_file(&path).is_ok() {
            out.removed += 1;
            out.bytes += meta.len();
        }
    }
    out
}

#[inline]
fn read_u64(bytes: &[u8], off: usize) -> u64 {
    let mut buf = [0u8; 8];
    buf.copy_from_slice(&bytes[off..off + 8]);
    u64::from_le_bytes(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, model_ii, paper_parallel};
    use crate::router::GatingSim;
    use crate::trace::provenance::RouterSampler;

    fn tmp_store(name: &str) -> TraceStore {
        let mut dir = std::env::temp_dir();
        dir.push(format!("memfine-trace-store-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        TraceStore::open(dir).unwrap()
    }

    fn sample_trace(seed: u64, iterations: u64) -> SharedRoutingTrace {
        let gating = GatingSim::new(model_i(), paper_parallel(), seed);
        SharedRoutingTrace::generate(&gating, iterations)
    }

    #[test]
    fn key_is_stable_and_identity_sensitive() {
        let prov = TraceProvenance::default();
        let k = trace_key(&model_i(), &paper_parallel(), 7, 10, &prov);
        assert_eq!(k.len(), 16);
        assert_eq!(k, trace_key(&model_i(), &paper_parallel(), 7, 10, &prov));
        // every identity axis perturbs the key
        assert_ne!(k, trace_key(&model_ii(), &paper_parallel(), 7, 10, &prov));
        assert_ne!(k, trace_key(&model_i(), &paper_parallel(), 8, 10, &prov));
        assert_ne!(k, trace_key(&model_i(), &paper_parallel(), 7, 11, &prov));
        let mut narrow = paper_parallel();
        narrow.ep = 16;
        assert_ne!(k, trace_key(&model_i(), &narrow, 7, 10, &prov));
        let seq = TraceProvenance::legacy_sequential();
        assert_ne!(k, trace_key(&model_i(), &paper_parallel(), 7, 10, &seq));
        let v2 = TraceProvenance { sampler: RouterSampler::Split, rng_version: 2 };
        assert_ne!(k, trace_key(&model_i(), &paper_parallel(), 7, 10, &v2));
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let store = tmp_store("roundtrip");
        let trace = sample_trace(7, 3);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            trace.seed,
            trace.iterations,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        let back = store
            .load(&key, &trace.model, &trace.parallel, trace.seed, trace.iterations)
            .expect("cache hit");
        assert_eq!(back.seed, trace.seed);
        assert_eq!(back.iterations, trace.iterations);
        assert_eq!(back.model, trace.model);
        assert_eq!(back.parallel, trace.parallel);
        assert_eq!(back.records.len(), trace.records.len());
        for (a, b) in back.records.iter().zip(&trace.records) {
            assert_eq!(a.iteration, b.iteration);
            assert_eq!(a.layer, b.layer);
            assert_eq!(a.min_recv, b.min_recv);
            assert_eq!(a.max_recv, b.max_recv);
            // means to the bit — warm-cache byte-identity rests on it
            assert_eq!(a.mean_recv.to_bits(), b.mean_recv.to_bits());
        }
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn entry_count_sees_only_complete_entries() {
        let store = tmp_store("entry-count");
        assert_eq!(store.entry_count(), 0);
        let trace = sample_trace(7, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            7,
            2,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        // a stray tmp file (an in-flight writer) must not be counted
        std::fs::write(store.dir.join("deadbeef.tmp.1"), b"x").unwrap();
        assert_eq!(store.entry_count(), 1);
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn missing_torn_and_corrupt_files_are_misses() {
        let store = tmp_store("corrupt");
        let trace = sample_trace(9, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            9,
            2,
            &TraceProvenance::default(),
        );
        // missing
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_none());
        store.save(&key, &trace).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_some());
        // torn: truncate mid-record
        let path = store.path(&key);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_none());
        // corrupt: flip a payload byte under an intact length
        let mut flipped = bytes.clone();
        flipped[HEADER_BYTES + 3] ^= 0xFF;
        std::fs::write(&path, &flipped).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_none());
        // restore: hit again (regeneration would overwrite in practice)
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_some());
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn mismatched_identity_is_a_miss() {
        let store = tmp_store("mismatch");
        let trace = sample_trace(11, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            11,
            2,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        // wrong seed / iterations / model shape all miss
        assert!(store.load(&key, &trace.model, &trace.parallel, 12, 2).is_none());
        assert!(store.load(&key, &trace.model, &trace.parallel, 11, 3).is_none());
        assert!(store.load(&key, &model_ii(), &trace.parallel, 11, 2).is_none());
        // a file stored under a different key misses too
        let other = trace_key(
            &trace.model,
            &trace.parallel,
            12,
            2,
            &TraceProvenance::default(),
        );
        std::fs::copy(store.path(&key), store.path(&other)).unwrap();
        assert!(store.load(&other, &trace.model, &trace.parallel, 12, 2).is_none());
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn global_tier_serves_misses_and_promotes_hits() {
        let global = tmp_store("tier-global");
        let trace = sample_trace(21, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            21,
            2,
            &TraceProvenance::default(),
        );
        global.save(&key, &trace).unwrap();

        let mut campaign_dir = std::env::temp_dir();
        campaign_dir
            .push(format!("memfine-trace-store-{}-tier-front", std::process::id()));
        std::fs::remove_dir_all(&campaign_dir).ok();
        let store =
            TraceStore::open_tiered(&campaign_dir, Some(&global.dir)).unwrap();
        assert_eq!(store.global_dir(), Some(global.dir.as_path()));

        // cold campaign tier, warm global: load is a hit...
        let back = store
            .load(&key, &trace.model, &trace.parallel, 21, 2)
            .expect("global tier hit");
        assert_eq!(back.records.len(), trace.records.len());
        // ...and the entry was promoted into the campaign tier
        assert!(store.path(&key).exists(), "promotion writes the front tier");

        std::fs::remove_dir_all(&campaign_dir).ok();
        std::fs::remove_dir_all(global.dir).ok();
    }

    #[test]
    fn corrupt_global_entry_is_a_miss_never_an_error() {
        let global = tmp_store("tier-corrupt-global");
        let trace = sample_trace(23, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            23,
            2,
            &TraceProvenance::default(),
        );
        global.save(&key, &trace).unwrap();
        // another host tore the shared entry mid-write
        let gpath = global.path(&key);
        let bytes = std::fs::read(&gpath).unwrap();
        std::fs::write(&gpath, &bytes[..bytes.len() / 3]).unwrap();

        let mut campaign_dir = std::env::temp_dir();
        campaign_dir.push(format!(
            "memfine-trace-store-{}-tier-corrupt-front",
            std::process::id()
        ));
        std::fs::remove_dir_all(&campaign_dir).ok();
        let store =
            TraceStore::open_tiered(&campaign_dir, Some(&global.dir)).unwrap();
        // degrade to regenerate-miss: no panic, no Err, no promotion
        assert!(store.load(&key, &trace.model, &trace.parallel, 23, 2).is_none());
        assert!(!store.path(&key).exists());
        // regeneration overwrites both tiers and heals the global entry
        store.save(&key, &trace).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 23, 2).is_some());
        let healed = std::fs::read(&gpath).unwrap();
        assert_eq!(healed, bytes, "global tier healed to canonical bytes");

        std::fs::remove_dir_all(&campaign_dir).ok();
        std::fs::remove_dir_all(global.dir).ok();
    }

    #[test]
    fn save_populates_both_tiers_and_same_root_collapses() {
        let global = tmp_store("tier-both-global");
        let mut campaign_dir = std::env::temp_dir();
        campaign_dir.push(format!(
            "memfine-trace-store-{}-tier-both-front",
            std::process::id()
        ));
        std::fs::remove_dir_all(&campaign_dir).ok();
        let store =
            TraceStore::open_tiered(&campaign_dir, Some(&global.dir)).unwrap();
        let trace = sample_trace(25, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            25,
            2,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        assert!(store.path(&key).exists());
        assert!(global.path(&key).exists());
        // identical bytes in both tiers — content addressing holds
        assert_eq!(
            std::fs::read(store.path(&key)).unwrap(),
            std::fs::read(global.path(&key)).unwrap()
        );

        // pointing the global tier at the campaign root is one tier
        let flat = TraceStore::open_tiered(&campaign_dir, Some(&campaign_dir))
            .unwrap();
        assert!(flat.global_dir().is_none());

        std::fs::remove_dir_all(&campaign_dir).ok();
        std::fs::remove_dir_all(global.dir).ok();
    }

    #[test]
    fn stale_tmp_debris_is_swept_by_ttl() {
        let store = tmp_store("tmp-sweep");
        std::fs::write(store.dir.join("deadbeef.tmp.42.0"), b"debris").unwrap();
        std::fs::write(store.dir.join("cafe.trace"), b"keep").unwrap();
        // a fresh tmp survives the real TTL (a live writer's file)...
        sweep_stale_tmp(&store.dir, TMP_TTL);
        assert!(store.dir.join("deadbeef.tmp.42.0").exists());
        // ...and a zero TTL treats everything as stale
        sweep_stale_tmp(&store.dir, Duration::ZERO);
        assert!(!store.dir.join("deadbeef.tmp.42.0").exists());
        assert!(store.dir.join("cafe.trace").exists(), "entries never swept");
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn stats_and_gc_account_for_entries() {
        let store = tmp_store("stats-gc");
        assert_eq!(store.stats(), StoreStats { entries: 0, bytes: 0 });
        for seed in [31, 32] {
            let trace = sample_trace(seed, 2);
            let key = trace_key(
                &trace.model,
                &trace.parallel,
                seed,
                2,
                &TraceProvenance::default(),
            );
            store.save(&key, &trace).unwrap();
        }
        let stats = store.stats();
        assert_eq!(stats.entries, 2);
        assert!(stats.bytes > 0);
        // nothing is an hour old yet
        let kept = store.gc(Duration::from_secs(3600));
        assert_eq!(kept, GcStats { removed: 0, bytes: 0 });
        assert_eq!(store.stats().entries, 2);
        // max-age zero evicts everything
        let gone = store.gc(Duration::ZERO);
        assert_eq!(gone.removed, 2);
        assert_eq!(gone.bytes, stats.bytes);
        assert_eq!(store.stats(), StoreStats { entries: 0, bytes: 0 });
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn empty_iteration_trace_roundtrips() {
        // iterations = 0 ⇒ zero records; the store must round-trip the
        // degenerate shape exactly (satellite edge case).
        let store = tmp_store("empty");
        let trace = sample_trace(5, 0);
        assert!(trace.records.is_empty());
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            5,
            0,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        let back = store
            .load(&key, &trace.model, &trace.parallel, 5, 0)
            .expect("empty trace hit");
        assert_eq!(back.iterations, 0);
        assert!(back.records.is_empty());
        std::fs::remove_dir_all(store.dir).ok();
    }
}
