//! End-to-end contract of the shard orchestrator: `launch` must turn
//! a grid into a supervised multi-process fleet whose merged artifact
//! is **byte-identical** to a single-process `memfine sweep` of the
//! same grid — including when a child is killed mid-flight (the chaos
//! drill) or wedges without heartbeating (a stalled shard that the
//! supervisor kills and relaunches).
//!
//! Children are the real `memfine` binary (`CARGO_BIN_EXE_memfine`),
//! so these tests also cover the `sweep --config/--shard/--resume`
//! plumbing the orchestrator drives.

use std::path::PathBuf;
use std::time::Duration;

use memfine::config::{derive_seeds, LaunchConfig, Method, SweepConfig};
use memfine::orchestrator::{
    self, FaultPlan, LaunchOptions, RetryPolicy, ShardEventKind, SuperviseOptions,
};
use memfine::sweep;

/// The 24-scenario determinism grid every sweep integration test pins.
fn grid_3x2x4() -> SweepConfig {
    SweepConfig {
        models: vec!["i".into(), "ii".into()],
        methods: vec![
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ],
        seeds: derive_seeds(7, 4),
        iterations: 10,
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("memfine-it-launch-{}-{name}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_memfine"))
}

fn quiet_opts(dir: &PathBuf) -> LaunchOptions {
    LaunchOptions {
        dir: dir.clone(),
        binary: Some(bin()),
        fault_plan: None,
        trace_cache_global: None,
        quiet: true,
    }
}

#[test]
fn launch_two_procs_matches_single_process_artifact() {
    let mut cfg = LaunchConfig::new(grid_3x2x4());
    cfg.procs = 2;
    cfg.workers_per_proc = 2;
    cfg.poll_ms = 20;
    let dir = tmp_dir("two-procs");
    let launched = orchestrator::launch(&cfg, &quiet_opts(&dir)).expect("launch");

    // a clean launch: every shard completes on its first spawn and the
    // catch-up pass has nothing to heal
    assert_eq!(launched.plan.procs, 2);
    assert!(launched.outcomes.iter().all(|o| o.completed));
    assert!(launched.outcomes.iter().all(|o| o.spawns == 1));
    assert_eq!(launched.merge.healed, 0);
    assert_eq!(launched.merge.resumed, 24);
    assert!(launched.merge.audit.complete());

    // THE acceptance bytes: merged report == single-process sweep
    let direct = sweep::run_sweep(&grid_3x2x4(), 1).expect("direct sweep");
    assert_eq!(
        launched.merge.report.to_json().to_string_pretty(),
        direct.to_json().to_string_pretty(),
        "orchestrated artifact diverged from the single-process run"
    );

    // the compacted merged checkpoint covers the whole grid and the
    // campaign specs were captured next to it
    assert_eq!(launched.merge.compact_stats.records_out, 24);
    assert!(launched.merge.compacted.exists());
    assert!(dir.join("sweep.json").exists());
    assert!(dir.join("launch.json").exists());
    let captured = memfine::json::parse(
        &std::fs::read_to_string(dir.join("launch.json")).unwrap(),
    )
    .unwrap();
    assert_eq!(LaunchConfig::from_json(&captured).unwrap(), cfg);

    // after a successful launch the shard files are absorbed into
    // merged.jsonl — the campaign dir stays bounded
    assert!(!launched.plan.shards[0].checkpoint.exists());

    // same campaign, different topology: a relaunch with 3 procs folds
    // everything back out of merged.jsonl — nothing re-executes
    let mut resumed_cfg = cfg.clone();
    resumed_cfg.procs = 3;
    let relaunched =
        orchestrator::launch(&resumed_cfg, &quiet_opts(&dir)).expect("relaunch");
    assert_eq!(relaunched.merge.resumed, 24);
    assert_eq!(relaunched.merge.healed, 0);
    assert_eq!(
        relaunched.merge.report.to_json().to_string_pretty(),
        direct.to_json().to_string_pretty(),
        "topology-changed resume diverged from the single-process run"
    );

    // a launch dir is one campaign: re-entering it with a different
    // grid is refused (stale shard checkpoints would pollute the
    // compacted merged.jsonl), while the same grid may resume
    let mut other = cfg.clone();
    other.sweep.iterations += 1;
    assert!(
        orchestrator::launch(&other, &quiet_opts(&dir)).is_err(),
        "a different campaign must not reuse the launch dir"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn chaos_killed_child_is_healed_to_identical_bytes() {
    let mut cfg = LaunchConfig::new(grid_3x2x4());
    cfg.procs = 3;
    cfg.poll_ms = 10;
    let dir = tmp_dir("chaos");
    let mut opts = quiet_opts(&dir);
    opts.fault_plan = Some(FaultPlan::kill_one());
    let launched = orchestrator::launch(&cfg, &opts).expect("launch");

    // exactly one child was chaos-killed mid-flight and relaunched
    let chaos_kills: u32 = launched.outcomes.iter().map(|o| o.chaos_kills).sum();
    assert_eq!(chaos_kills, 1, "chaos drill must kill exactly one child");
    let victim = launched
        .outcomes
        .iter()
        .find(|o| o.chaos_kills == 1)
        .expect("victim outcome");
    assert!(victim.spawns >= 2, "victim must have been relaunched");
    assert!(launched.outcomes.iter().all(|o| o.completed));
    assert!(launched
        .events
        .iter()
        .any(|e| matches!(e.kind, ShardEventKind::ChaosKilled { .. })));

    // and the artifact still comes out byte-identical
    let direct = sweep::run_sweep(&grid_3x2x4(), 1).expect("direct sweep");
    assert_eq!(
        launched.merge.report.to_json().to_string_pretty(),
        direct.to_json().to_string_pretty(),
        "chaos-healed artifact diverged from the single-process run"
    );
    assert!(launched.merge.audit.complete());

    // the campaign narrated itself: telemetry is on by default, and the
    // event log records the kill, the relaunch, and the merge
    let (events, torn) =
        memfine::obs::read_events(&dir.join("events.jsonl")).expect("read event log");
    assert_eq!(torn, 0, "a finished campaign leaves no torn event lines");
    let kinds = memfine::obs::summarize(&events);
    assert_eq!(kinds.get("launch_start"), Some(&1));
    assert_eq!(kinds.get("shard_chaos_killed"), Some(&1));
    assert!(
        kinds.get("shard_spawned").copied().unwrap_or(0) >= 4,
        "3 shards + 1 relaunch must all be recorded: {kinds:?}"
    );
    assert!(kinds.get("cell_eval").copied().unwrap_or(0) >= 1, "{kinds:?}");
    assert_eq!(kinds.get("merge_done"), Some(&1));
    std::fs::remove_dir_all(&dir).ok();
}

/// A 3-shard run where one shard's first child wedges without ever
/// touching its checkpoint: the supervisor must flag the stalled
/// heartbeat, kill the child, relaunch the shard for real, and the
/// merged artifact must still match the single-process bytes.
///
/// Uses a small 6-scenario grid and a stall timeout far above its
/// per-cell latency, so only the injected sleeper ever stalls: the
/// heartbeat ticks once per completed trace cell, which is exactly
/// why `LaunchConfig::stall_timeout_ms` must stay comfortably above
/// the slowest cell.
#[test]
#[cfg(unix)]
fn stalled_shard_is_killed_relaunched_and_merges_identically() {
    let tiny = SweepConfig {
        models: vec!["i".into()],
        methods: vec![Method::FullRecompute, Method::Mact(vec![1, 2, 4, 8])],
        seeds: derive_seeds(7, 3),
        iterations: 3,
    };
    let mut cfg = LaunchConfig::new(tiny.clone());
    cfg.procs = 3;
    cfg.poll_ms = 20;
    // far above the tiny grid's per-cell latency (only the injected
    // sleeper may stall), far below the sleeper's 30 s nap
    cfg.stall_timeout_ms = 10_000;
    let dir = tmp_dir("stall");
    std::fs::create_dir_all(&dir).unwrap();
    let plan = orchestrator::plan_shards(&cfg, &dir).expect("plan");
    assert_eq!(plan.shards.len(), 3);

    // children load the grid exactly as launch() provides it
    let sweep_json = dir.join("sweep.json");
    std::fs::write(
        &sweep_json,
        format!("{}\n", cfg.sweep.to_json().to_string_pretty()),
    )
    .unwrap();

    let sup = SuperviseOptions {
        stall_timeout: Duration::from_millis(cfg.stall_timeout_ms),
        poll_interval: Duration::from_millis(cfg.poll_ms),
        policy: RetryPolicy {
            episode_retries: 2,
            campaign_retries: 0,
            backoff_base: Duration::ZERO,
            backoff_cap: Duration::ZERO,
            jitter_seed: 0,
            quarantine: false,
        },
        fault_plan: None,
    };
    let mut events = Vec::new();
    let outcomes = orchestrator::supervise(
        &plan.shards,
        |shard, attempt| {
            use std::process::{Command, Stdio};
            if shard.index == 1 && attempt == 1 {
                // simulate a wedged child: alive, but the checkpoint
                // heartbeat never moves
                return Command::new("sleep")
                    .arg("30")
                    .stdin(Stdio::null())
                    .stdout(Stdio::null())
                    .stderr(Stdio::null())
                    .spawn()
                    .map_err(memfine::Error::Io);
            }
            Command::new(bin())
                .arg("sweep")
                .arg("--config")
                .arg(&sweep_json)
                .arg("--shard")
                .arg(format!("{}/{}", shard.spec.index, shard.spec.count))
                .arg("--checkpoint")
                .arg(&shard.checkpoint)
                .arg("--resume")
                .arg("--workers")
                .arg("1")
                .arg("--out")
                .arg("-")
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .map_err(memfine::Error::Io)
        },
        &sup,
        |ev| events.push(ev.clone()),
    )
    .expect("supervise");

    assert!(outcomes.iter().all(|o| o.completed));
    assert!(outcomes[1].stalls >= 1, "shard 1 must have been stall-killed");
    assert!(outcomes[1].spawns >= 2, "shard 1 must have been relaunched");
    assert!(events
        .iter()
        .any(|e| e.shard == 1 && matches!(e.kind, ShardEventKind::Stalled { .. })));

    let merge =
        orchestrator::merge_and_finish(&cfg, &plan, &dir, &[], None).expect("merge");
    assert_eq!(merge.healed, 0, "all scenarios came from the healed fleet");
    assert!(merge.audit.complete());
    let direct = sweep::run_sweep(&tiny, 1).expect("direct sweep");
    assert_eq!(
        merge.report.to_json().to_string_pretty(),
        direct.to_json().to_string_pretty(),
        "stall-healed artifact diverged from the single-process run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A shard that permanently fails (its retry budget exhausts) must not
/// poison the launch: the merge catch-up executes its scenarios
/// in-process and the artifact still matches.
#[test]
#[cfg(unix)]
fn shard_that_gives_up_is_healed_by_the_merge_catchup() {
    let mut cfg = LaunchConfig::new(grid_3x2x4());
    cfg.procs = 3;
    cfg.poll_ms = 10;
    let dir = tmp_dir("giveup");
    std::fs::create_dir_all(&dir).unwrap();
    let plan = orchestrator::plan_shards(&cfg, &dir).expect("plan");
    let sweep_json = dir.join("sweep.json");
    std::fs::write(
        &sweep_json,
        format!("{}\n", cfg.sweep.to_json().to_string_pretty()),
    )
    .unwrap();

    let sup = SuperviseOptions {
        stall_timeout: Duration::from_secs(30),
        poll_interval: Duration::from_millis(10),
        policy: RetryPolicy {
            episode_retries: 1,
            campaign_retries: 0,
            backoff_base: Duration::ZERO,
            backoff_cap: Duration::ZERO,
            jitter_seed: 0,
            quarantine: false,
        },
        fault_plan: None,
    };
    let outcomes = orchestrator::supervise(
        &plan.shards,
        |shard, _attempt| {
            use std::process::{Command, Stdio};
            if shard.index == 2 {
                // this shard crashes on every attempt
                return Command::new("false")
                    .stdin(Stdio::null())
                    .stdout(Stdio::null())
                    .stderr(Stdio::null())
                    .spawn()
                    .map_err(memfine::Error::Io);
            }
            Command::new(bin())
                .arg("sweep")
                .arg("--config")
                .arg(&sweep_json)
                .arg("--shard")
                .arg(format!("{}/{}", shard.spec.index, shard.spec.count))
                .arg("--checkpoint")
                .arg(&shard.checkpoint)
                .arg("--resume")
                .arg("--workers")
                .arg("1")
                .arg("--out")
                .arg("-")
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .map_err(memfine::Error::Io)
        },
        &sup,
        |_| {},
    )
    .expect("supervise");

    assert!(!outcomes[2].completed);
    assert_eq!(outcomes[2].spawns, 2); // initial + 1 retry
    assert!(outcomes[0].completed && outcomes[1].completed);

    // merge heals the abandoned shard's scenarios in-process
    let merge =
        orchestrator::merge_and_finish(&cfg, &plan, &dir, &[], None).expect("merge");
    assert_eq!(merge.healed, plan.shards[2].scenarios);
    assert!(merge.audit.complete());
    let direct = sweep::run_sweep(&grid_3x2x4(), 1).expect("direct sweep");
    assert_eq!(
        merge.report.to_json().to_string_pretty(),
        direct.to_json().to_string_pretty(),
        "gave-up-shard artifact diverged from the single-process run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// THE acceptance drill of the fault plane: a seeded `FaultPlan` (kill
/// storm + mid-file corruption + injected ENOSPC on every child's
/// checkpoint writer) thrown at a real 3-process launch, which must
/// still converge to the byte-identical single-process artifact —
/// narrating the damage (checkpoint_degraded) and raising the
/// watchdog's io-degrade alert along the way.
#[test]
#[cfg(unix)]
fn seeded_chaos_drill_heals_to_identical_bytes_and_raises_alerts() {
    let mut cfg = LaunchConfig::new(grid_3x2x4());
    cfg.procs = 3;
    cfg.poll_ms = 10;
    let dir = tmp_dir("seeded-chaos");
    let mut opts = quiet_opts(&dir);
    opts.fault_plan = Some(FaultPlan::from_seed(7, &dir));
    let launched = orchestrator::launch(&cfg, &opts).expect("launch");

    // the fleet healed: every shard eventually completed (chaos kills
    // relaunch unconditionally; they never consume retry budget) and
    // the merge audit covers the whole grid
    assert!(launched.outcomes.iter().all(|o| o.completed));
    assert!(launched.merge.audit.complete());

    // THE acceptance bytes, under fire
    let direct = sweep::run_sweep(&grid_3x2x4(), 1).expect("direct sweep");
    assert_eq!(
        launched.merge.report.to_json().to_string_pretty(),
        direct.to_json().to_string_pretty(),
        "seeded chaos drill diverged from the single-process run"
    );

    // every first-attempt child runs with checkpoint:enospc:2 armed;
    // the write ladder retries once in place, so the pair of charges
    // surfaces as exactly one degraded (lost, later healed) record in
    // at least one child — narrated as checkpoint_degraded and
    // escalated once by the watchdog. Kill/corrupt strikes are
    // opportunistic (fast fleets may finish first), so only the IO
    // fault is asserted strictly.
    let (events, torn) =
        memfine::obs::read_events(&dir.join("events.jsonl")).expect("read event log");
    assert_eq!(torn, 0, "a finished campaign leaves no torn event lines");
    let kinds = memfine::obs::summarize(&events);
    assert!(
        kinds.get("checkpoint_degraded").copied().unwrap_or(0) >= 1,
        "injected ENOSPC must surface as a degraded record: {kinds:?}"
    );
    assert_eq!(
        kinds.get("alert_io_degrade_burst"),
        Some(&1),
        "watchdog must raise the io-degrade alert exactly once: {kinds:?}"
    );
    assert_eq!(kinds.get("merge_done"), Some(&1));

    // degraded records are missing from shard checkpoints, so the
    // catch-up pass re-executed them in-process
    assert!(launched.merge.healed >= 1, "degraded records must be healed");
    std::fs::remove_dir_all(&dir).ok();
}

/// A shard that makes real checkpoint progress and then crashes on
/// every relaunch exhausts its episode budget and has its checkpoint
/// quarantined aside: the merge must ignore the quarantined records,
/// re-execute every one of the shard's scenarios in-process, and still
/// produce the single-process bytes.
#[test]
#[cfg(unix)]
fn quarantined_shard_checkpoint_is_ignored_and_healed_identically() {
    let mut cfg = LaunchConfig::new(grid_3x2x4());
    cfg.procs = 3;
    cfg.poll_ms = 10;
    let dir = tmp_dir("quarantine");
    std::fs::create_dir_all(&dir).unwrap();
    let plan = orchestrator::plan_shards(&cfg, &dir).expect("plan");
    let sweep_json = dir.join("sweep.json");
    std::fs::write(
        &sweep_json,
        format!("{}\n", cfg.sweep.to_json().to_string_pretty()),
    )
    .unwrap();

    let sup = SuperviseOptions {
        stall_timeout: Duration::from_secs(30),
        poll_interval: Duration::from_millis(10),
        policy: RetryPolicy {
            episode_retries: 1,
            campaign_retries: 0,
            backoff_base: Duration::ZERO,
            backoff_cap: Duration::ZERO,
            jitter_seed: 0,
            quarantine: true,
        },
        fault_plan: None,
    };
    let mut events = Vec::new();
    let outcomes = orchestrator::supervise(
        &plan.shards,
        |shard, attempt| {
            use std::process::{Command, Stdio};
            let mut cmd;
            if shard.index == 2 && attempt >= 2 {
                // every relaunch crashes without touching the checkpoint
                cmd = Command::new("false");
            } else if shard.index == 2 {
                // first attempt: the full shard sweep succeeds (the
                // supervisor observes real checkpoint progress, which
                // resets the episode budget), then the child dies — so
                // the quarantined file holds genuine records the merge
                // must refuse to trust
                cmd = Command::new("sh");
                cmd.arg("-c").arg(format!(
                    "{} sweep --config {} --shard {}/{} --checkpoint {} --resume \
                     --workers 1 --out - >/dev/null 2>&1; sleep 0.3; exit 1",
                    bin().display(),
                    sweep_json.display(),
                    shard.spec.index,
                    shard.spec.count,
                    shard.checkpoint.display(),
                ));
            } else {
                cmd = Command::new(bin());
                cmd.arg("sweep")
                    .arg("--config")
                    .arg(&sweep_json)
                    .arg("--shard")
                    .arg(format!("{}/{}", shard.spec.index, shard.spec.count))
                    .arg("--checkpoint")
                    .arg(&shard.checkpoint)
                    .arg("--resume")
                    .arg("--workers")
                    .arg("1")
                    .arg("--out")
                    .arg("-");
            }
            cmd.stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .map_err(memfine::Error::Io)
        },
        &sup,
        |ev| events.push(ev.clone()),
    )
    .expect("supervise");

    assert!(!outcomes[2].completed);
    assert!(outcomes[2].quarantined, "shard 2 must have been quarantined");
    assert_eq!(outcomes[2].spawns, 2); // progress reset the budget once
    assert!(outcomes[0].completed && outcomes[1].completed);
    let aside = orchestrator::supervise::quarantine_path(&plan.shards[2].checkpoint);
    assert!(aside.exists(), "checkpoint must be renamed aside, not deleted");
    assert!(
        !plan.shards[2].checkpoint.exists(),
        "the live checkpoint path must be vacated"
    );
    assert!(events
        .iter()
        .any(|e| e.shard == 2 && matches!(e.kind, ShardEventKind::Quarantined { .. })));

    // the quarantined records are dead to the merge: every shard-2
    // scenario is redistributed to the in-process catch-up pass
    let merge =
        orchestrator::merge_and_finish(&cfg, &plan, &dir, &[], None).expect("merge");
    assert_eq!(merge.healed, plan.shards[2].scenarios);
    assert!(merge.audit.complete());
    let direct = sweep::run_sweep(&grid_3x2x4(), 1).expect("direct sweep");
    assert_eq!(
        merge.report.to_json().to_string_pretty(),
        direct.to_json().to_string_pretty(),
        "quarantine-healed artifact diverged from the single-process run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// THE acceptance drill of the multi-host plane: a 2-"host" launch
/// (both local, same machine — the ssh seam shares this exact code
/// path) loses host `h1` wholesale at the first supervision poll. The
/// chaos spec kills h1's children and silences its lease; the
/// supervisor must detect the expiry, declare the host lost exactly
/// once, reassign its shards to the survivor under the normal retry
/// budget, and still merge to the byte-identical single-process
/// artifact. The watchdog turns the loss into `alert_host_lost` in
/// the campaign event log.
#[test]
#[cfg(unix)]
fn whole_host_loss_drill_heals_to_identical_bytes() {
    let mut cfg = LaunchConfig::new(grid_3x2x4());
    cfg.procs = 4;
    cfg.workers_per_proc = 1;
    cfg.poll_ms = 10;
    cfg.hosts = vec!["local".into(), "local".into()];
    cfg.lease_timeout_ms = 500;
    let dir = tmp_dir("host-loss");
    let mut opts = quiet_opts(&dir);
    opts.fault_plan = Some(FaultPlan {
        host_loss: vec![orchestrator::chaos::HostLossSpec { at_poll: 1, host: 1 }],
        ..FaultPlan::default()
    });
    let launched = orchestrator::launch(&cfg, &opts).expect("launch");

    // the loss was declared exactly once, for h1
    let lost: Vec<_> = launched
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            ShardEventKind::HostLost { host } => Some(host.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(lost, vec!["h1".to_string()], "exactly one loss, of h1");

    // h1's in-flight shards were chaos-killed and moved to the survivor
    let chaos_kills: u32 = launched.outcomes.iter().map(|o| o.chaos_kills).sum();
    assert!(chaos_kills >= 1, "the strike must land on a running child");
    assert!(
        launched.events.iter().any(|e| matches!(&e.kind,
            ShardEventKind::Reassigned { from_host, to_host }
                if from_host == "h1" && to_host == "h0")),
        "a lost shard must be reassigned to the survivor: {:?}",
        launched.events
    );
    assert!(launched.outcomes.iter().all(|o| o.completed));
    assert!(launched.merge.audit.complete());

    // THE acceptance bytes, across a machine loss
    let direct = sweep::run_sweep(&grid_3x2x4(), 1).expect("direct sweep");
    assert_eq!(
        launched.merge.report.to_json().to_string_pretty(),
        direct.to_json().to_string_pretty(),
        "host-loss drill diverged from the single-process run"
    );

    // the event log narrates the loss and the watchdog escalates it
    // exactly once
    let (events, torn) =
        memfine::obs::read_events(&dir.join("events.jsonl")).expect("read event log");
    assert_eq!(torn, 0);
    let kinds = memfine::obs::summarize(&events);
    assert_eq!(kinds.get("shard_host_lost"), Some(&1), "{kinds:?}");
    assert_eq!(kinds.get("alert_host_lost"), Some(&1), "{kinds:?}");
    assert!(
        kinds.get("shard_reassigned").copied().unwrap_or(0) >= 1,
        "{kinds:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The cross-campaign tier of the trace cache: two sequential
/// campaigns over the same grid share one global `--trace-cache`
/// root. The second campaign must regenerate nothing — every
/// `cell_eval` it emits is a cache hit served through the global
/// tier — and both artifacts must be byte-identical to each other
/// and to the single-process run.
#[test]
fn warm_global_trace_cache_serves_a_second_campaign_without_regeneration() {
    let mut cfg = LaunchConfig::new(grid_3x2x4());
    cfg.procs = 2;
    cfg.workers_per_proc = 2;
    cfg.poll_ms = 20;
    let global = tmp_dir("warm-global-root");
    let dir_a = tmp_dir("warm-a");
    let dir_b = tmp_dir("warm-b");

    let mut opts_a = quiet_opts(&dir_a);
    opts_a.trace_cache_global = Some(global.clone());
    let a = orchestrator::launch(&cfg, &opts_a).expect("launch a");

    // the first campaign populated the shared root with content-keyed
    // entries (best-effort writes, but on a healthy disk they land)
    let warmed = std::fs::read_dir(&global)
        .expect("global root exists")
        .filter_map(|e| e.ok())
        .filter(|e| {
            e.path()
                .extension()
                .is_some_and(|x| x == "trace")
        })
        .count();
    assert!(warmed >= 1, "campaign A must warm the global tier");

    let mut opts_b = quiet_opts(&dir_b);
    opts_b.trace_cache_global = Some(global.clone());
    let b = orchestrator::launch(&cfg, &opts_b).expect("launch b");

    // zero regenerations: every cell evaluation in campaign B was
    // served from cache (its own campaign tier is cold, so the hits
    // necessarily came through the global tier)
    let (events, _) =
        memfine::obs::read_events(&dir_b.join("events.jsonl")).expect("read event log");
    let cell_evals: Vec<_> =
        events.iter().filter(|e| e.kind == "cell_eval").collect();
    assert!(!cell_evals.is_empty());
    for ev in &cell_evals {
        assert_eq!(
            ev.field_str("cache"),
            Some("hit"),
            "warm-cache campaign must not regenerate: {:?}",
            ev.fields.to_string_compact()
        );
    }

    let direct = sweep::run_sweep(&grid_3x2x4(), 1).expect("direct sweep");
    for (name, launched) in [("a", &a), ("b", &b)] {
        assert_eq!(
            launched.merge.report.to_json().to_string_pretty(),
            direct.to_json().to_string_pretty(),
            "campaign {name} diverged from the single-process run"
        );
    }
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
    std::fs::remove_dir_all(&global).ok();
}
