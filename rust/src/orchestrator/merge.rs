//! Merge, verify, and compact a launch's shard checkpoints into the
//! final artifact.
//!
//! The heavy lifting is the sweep engine's own resume path: one
//! in-process `run_sweep_with` over every shard checkpoint folds all
//! completed rows into the grid-ordered reducer **and executes any
//! scenario the fleet failed to deliver** (a shard that exhausted its
//! retry budget, rows lost to a torn tail) — the "final catch-up
//! shard" in one call. The result is then audited against the full
//! planned hash set (belt and braces: the catch-up should have left
//! no gap), and the shard files are compacted into a single canonical
//! `merged.jsonl` — deduplicated, torn tails dropped, hash-ordered —
//! so long campaigns keep a bounded, restart-friendly checkpoint.
//!
//! By the sweep determinism contract, the merged report is
//! byte-identical to a single-process `memfine sweep` of the same
//! grid, however many shards ran, crashed, or were healed.

use std::path::{Path, PathBuf};

use crate::config::LaunchConfig;
use crate::error::{Error, Result};
use crate::orchestrator::plan::LaunchPlan;
use crate::sweep::checkpoint::{
    audit_planned, write_compacted, CheckpointSet, CompactStats, CoverageAudit,
};
use crate::sweep::{self, SweepReport, SweepRunOptions};

/// What the merge step produced.
#[derive(Debug)]
pub struct MergeOutcome {
    /// The final report — byte-identical to an unsharded run.
    pub report: SweepReport,
    /// Scenarios folded straight from shard checkpoints.
    pub resumed: usize,
    /// Scenarios the catch-up pass had to execute in-process (0 on a
    /// clean launch).
    pub healed: usize,
    /// Post-merge coverage audit (always complete on success).
    pub audit: CoverageAudit,
    /// Path of the canonical compacted checkpoint.
    pub compacted: PathBuf,
    pub compact_stats: CompactStats,
}

/// Merge the fleet's checkpoints, heal any coverage gap in-process,
/// audit the result against the plan, and compact the merged
/// checkpoint into `dir/merged.jsonl`. `prior_state` lists
/// same-campaign checkpoint files beyond the current shard plan
/// (earlier topologies' shard files, a previous run's merged.jsonl) —
/// they fold in like any shard file. After a complete audit and a
/// successful compaction every absorbed source file is removed:
/// `merged.jsonl` alone carries the campaign forward, so long
/// campaigns don't accumulate per-topology shard files.
/// `trace_cache_global` stacks a cross-campaign cache root behind the
/// campaign tier, so healing after a lost host (or a second campaign
/// over the same grid) regenerates nothing already drawn anywhere.
pub fn merge_and_finish(
    cfg: &LaunchConfig,
    plan: &LaunchPlan,
    dir: &Path,
    prior_state: &[PathBuf],
    trace_cache_global: Option<&Path>,
) -> Result<MergeOutcome> {
    let mut paths: Vec<PathBuf> =
        plan.shards.iter().map(|s| s.checkpoint.clone()).collect();
    for src in prior_state {
        if !paths.contains(src) {
            paths.push(src.clone());
        }
    }

    // Catch-up + merge in one resume run: fold every checkpointed row,
    // execute whatever is missing (appended to the first shard file,
    // like any resumed sweep) — reading the campaign's shared trace
    // cache, so healing a gap never re-draws a cached cell.
    let opts = SweepRunOptions {
        workers: 0,
        checkpoint: paths.clone(),
        resume: true,
        sampler: cfg.sampler,
        rng: cfg.rng,
        trace_cache: Some(dir.join("trace-cache")),
        trace_cache_global: trace_cache_global.map(Path::to_path_buf),
        pin_cores: cfg.pin_cores,
        // the catch-up pass logs into the same campaign event log the
        // shards appended to (sidecar: never affects merged bytes)
        events: cfg.telemetry.then(|| dir.join("events.jsonl")),
        ..Default::default()
    };
    let summary = sweep::run_sweep_with(&cfg.sweep, &opts)?;

    // One reload serves both the audit (against the hashes the plan
    // derived up front — no grid re-expansion) and the compaction
    // (written from the loaded set — no third read of the shard
    // files). Shards that never spawned left no file; load tolerates
    // that, and their scenarios were healed into the first file.
    let set = CheckpointSet::load(&paths)?;
    let audit = audit_planned(&plan.planned, &set);
    if !audit.complete() {
        return Err(Error::schedule(format!(
            "merged checkpoints still miss {} of {} planned scenarios after catch-up",
            audit.missing.len(),
            audit.planned
        )));
    }

    let compacted = dir.join("merged.jsonl");
    let compact_stats = write_compacted(&set, &compacted)?;
    // every absorbed record now lives in merged.jsonl (the audit above
    // proved coverage); drop the source files so the campaign dir
    // stays bounded however many topologies ran it
    for p in &paths {
        if *p != compacted {
            std::fs::remove_file(p).ok();
        }
    }

    Ok(MergeOutcome {
        report: summary.report,
        resumed: summary.resumed,
        healed: summary.executed,
        audit,
        compacted,
        compact_stats,
    })
}
