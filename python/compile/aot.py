"""AOT export: lower every JAX entry point to HLO *text* artifacts.

This is the only place python touches the pipeline; `make artifacts`
runs it once and the rust binary is self-contained afterwards.

Interchange format is HLO TEXT, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` rust crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (see DESIGN.md §5):

  train_step.hlo.txt      (params, m, v, tokens, step) -> (params', m', v', loss)
  fwd_loss.hlo.txt        (params, tokens) -> loss
  router_topk.hlo.txt     (x, w_gate) -> (weights, indices)   [Pallas]
  expert_ffn_c{C}.hlo.txt (x, w1, w3, w2, mask) -> out        [Pallas]
                          one per FCDA chunk-capacity bin C
  params.bin              initial flat f32 parameter vector (raw LE bytes)
  manifest.json           shapes, dtypes, param layout, config dump
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.expert_ffn import expert_ffn, vmem_bytes, mxu_flops
from .kernels.router_topk import router_topk

# Coordinator topology: the rust EP demo runs COORD_EP worker threads,
# each hosting COORD_LOCAL_EXPERTS experts (block layout), with
# COORD_TOKENS tokens per rank per micro-batch. Drop-free capacity for
# chunk bin c is ep·tokens·top_k/c — in the worst case every routed
# copy of a chunk lands on ONE expert, and chunking divides exactly
# that buffer (paper Eq. 6).
COORD_EP = 4
COORD_LOCAL_EXPERTS = 8
COORD_TOKENS = 512  # tokens per EP rank per micro-batch in the demo
CHUNK_BINS = [1, 2, 4, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(name, file, inputs, outputs, extra=None):
    ent = {
        "name": name,
        "file": file,
        "inputs": [{"shape": list(s), "dtype": d} for s, d in inputs],
        "outputs": [{"shape": list(s), "dtype": d} for s, d in outputs],
    }
    if extra:
        ent.update(extra)
    return ent


def export(out_dir: str, cfg: M.ModelConfig, seed: int = 0,
           coord_hidden: int | None = None) -> dict:
    """Lower all entry points and write artifacts. Returns the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "config": {k: getattr(cfg, k) for k in (
            "vocab", "seq", "d_model", "n_heads", "n_layers",
            "n_dense_layers", "n_experts", "top_k", "d_ff", "d_ff_dense",
            "batch", "n_chunks")},
        "param_count": M.param_count(cfg),
        "params_file": "params.bin",
        "param_layout": [
            {"name": n, "shape": list(s)} for n, s in M.param_shapes(cfg)
        ],
        "entries": [],
    }
    n = M.param_count(cfg)
    pvec = _spec((n,))
    toks = _spec((cfg.batch, cfg.seq), jnp.int32)
    scalar = _spec(())

    # --- train step -------------------------------------------------------
    lowered = jax.jit(
        lambda p, m, v, t, s: M.train_step(cfg, p, m, v, t, s)
    ).lower(pvec, pvec, pvec, toks, scalar)
    path = os.path.join(out_dir, "train_step.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["entries"].append(_io_entry(
        "train_step", "train_step.hlo.txt",
        inputs=[((n,), "f32"), ((n,), "f32"), ((n,), "f32"),
                ((cfg.batch, cfg.seq), "i32"), ((), "f32")],
        outputs=[((n,), "f32"), ((n,), "f32"), ((n,), "f32"), ((), "f32")],
    ))

    # --- eval loss --------------------------------------------------------
    lowered = jax.jit(lambda p, t: M.eval_loss(cfg, p, t)).lower(pvec, toks)
    with open(os.path.join(out_dir, "fwd_loss.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["entries"].append(_io_entry(
        "fwd_loss", "fwd_loss.hlo.txt",
        inputs=[((n,), "f32"), ((cfg.batch, cfg.seq), "i32")],
        outputs=[((), "f32")],
    ))

    # --- coordinator kernels (Pallas) --------------------------------------
    # The rust coordinator runs COORD_EP worker ranks, each hosting
    # COORD_LOCAL_EXPERTS experts; its router and per-chunk expert FFN
    # are separate executables so the L3 scheduler owns dispatch/combine.
    h = coord_hidden or cfg.d_model
    g = cfg.d_ff
    e_local = COORD_LOCAL_EXPERTS
    e_global = COORD_EP * COORD_LOCAL_EXPERTS
    x_r = _spec((COORD_TOKENS, h))
    wg = _spec((h, e_global))
    lowered = jax.jit(
        lambda x, w: router_topk(x, w, cfg.top_k)
    ).lower(x_r, wg)
    with open(os.path.join(out_dir, "router_topk.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["entries"].append(_io_entry(
        "router_topk", "router_topk.hlo.txt",
        inputs=[((COORD_TOKENS, h), "f32"), ((h, e_global), "f32")],
        outputs=[((COORD_TOKENS, cfg.top_k), "f32"),
                 ((COORD_TOKENS, cfg.top_k), "i32")],
        extra={"top_k": cfg.top_k},
    ))
    manifest["coordinator"] = {
        "ep": COORD_EP,
        "local_experts": COORD_LOCAL_EXPERTS,
        "global_experts": e_global,
        "tokens_per_rank": COORD_TOKENS,
        "hidden": h,
        "ffn": g,
        "top_k": cfg.top_k,
        "chunk_bins": CHUNK_BINS,
    }

    kernel_perf = []
    # 128-token tiles: large enough to amortise grid overhead, small
    # enough that the per-step VMEM footprint stays well under 16 MiB
    # at Table-3 dims (see kernels.expert_ffn.vmem_bytes).
    kernel_tile = 128
    total_copies = COORD_EP * COORD_TOKENS * cfg.top_k
    for c_k in CHUNK_BINS:
        cap = total_copies // c_k
        name = f"expert_ffn_c{c_k}"
        lowered = jax.jit(
            lambda x, w1, w3, w2, mk: expert_ffn(
                x, w1, w3, w2, mk, token_tile=kernel_tile)
        ).lower(
            _spec((e_local, cap, h)), _spec((e_local, h, g)),
            _spec((e_local, h, g)), _spec((e_local, g, h)),
            _spec((e_local, cap)),
        )
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["entries"].append(_io_entry(
            name, fname,
            inputs=[((e_local, cap, h), "f32"), ((e_local, h, g), "f32"),
                    ((e_local, h, g), "f32"), ((e_local, g, h), "f32"),
                    ((e_local, cap), "f32")],
            outputs=[((e_local, cap, h), "f32")],
            extra={"chunk_bin": c_k, "capacity": cap},
        ))
        kernel_perf.append({
            "chunk_bin": c_k,
            "capacity": cap,
            "vmem_bytes_per_step": vmem_bytes(kernel_tile, h, g),
            "mxu_flops_per_expert": mxu_flops(cap, h, g),
        })
    manifest["kernel_perf"] = kernel_perf

    # --- initial parameters -------------------------------------------------
    key = jax.random.PRNGKey(seed)
    vec = M.flatten(cfg, M.init_params(cfg, key))
    import numpy as np

    np.asarray(vec, dtype="<f4").tofile(os.path.join(out_dir, "params.bin"))

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--config", default="e2e", choices=["e2e", "tiny"],
                    help="model config preset")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = M.E2E if args.config == "e2e" else M.TINY
    manifest = export(args.out, cfg, seed=args.seed)
    total = sum(
        os.path.getsize(os.path.join(args.out, e["file"]))
        for e in manifest["entries"]
    )
    print(f"wrote {len(manifest['entries'])} HLO artifacts "
          f"({total/1e6:.1f} MB text) + params.bin "
          f"({manifest['param_count']*4/1e6:.1f} MB) to {args.out}")


if __name__ == "__main__":
    main()
