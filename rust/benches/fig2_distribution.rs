//! `cargo bench --bench fig2_distribution` — regenerates Fig. 2 (tokens
//! received per MoE layer at iteration 7, Model I) and times the
//! routing path that produces it.

use memfine::bench::{fmt_time, time_fn};
use memfine::config::{model_i, paper_parallel};
use memfine::router::GatingSim;
use memfine::sim::repro;

fn main() {
    memfine::logging::init();
    repro::fig2(7, 7).expect("fig2 repro");

    let sim = GatingSim::new(model_i(), paper_parallel(), 7);
    let t = time_fn("route one (iteration, layer)", 3, 20, || {
        sim.route(7, 15).max_received()
    });
    println!(
        "\n[bench] {}: median {} ({:.0} routes/s)",
        t.name,
        fmt_time(t.median_s),
        t.per_sec()
    );
    let t = time_fn("full 16-layer iteration profile", 1, 10, || {
        sim.iteration_profile(7).len()
    });
    println!("[bench] {}: median {}", t.name, fmt_time(t.median_s));
}
