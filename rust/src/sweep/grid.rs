//! Grid expansion: a [`SweepConfig`] unfolds into an ordered list of
//! fully-resolved [`Scenario`]s — the unit of work the pool executes.
//!
//! Ordering is part of the determinism contract: scenarios enumerate
//! models × methods × seeds in the exact order the config lists them,
//! and the scenario `index` is the reduction key every downstream
//! aggregation sorts by. Two sweeps with the same config produce the
//! same scenario list byte for byte, regardless of worker count.

use crate::config::{model_by_name, paper_run, Method, RunConfig, SweepConfig};
use crate::error::Result;

/// One cell-instance of the grid: a (model, method, seed) triple with
/// its resolved run envelope.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Position in the grid enumeration — the deterministic reduction
    /// key.
    pub index: usize,
    /// Model preset name ("i" / "ii").
    pub model: String,
    pub method: Method,
    pub seed: u64,
    /// Fully-resolved run config (method and seed already applied).
    pub run: RunConfig,
}

/// Expand the grid in (model, method, seed) order.
pub fn expand(cfg: &SweepConfig) -> Result<Vec<Scenario>> {
    cfg.validate()?;
    let mut scenarios = Vec::with_capacity(cfg.scenario_count());
    for model_name in &cfg.models {
        let model = model_by_name(model_name)?;
        for method in &cfg.methods {
            for &seed in &cfg.seeds {
                let mut run = paper_run(model.clone(), method.clone());
                run.iterations = cfg.iterations;
                run.seed = seed;
                scenarios.push(Scenario {
                    index: scenarios.len(),
                    model: model_name.clone(),
                    method: method.clone(),
                    seed,
                    run,
                });
            }
        }
    }
    Ok(scenarios)
}

/// The scenarios of one (model, seed) *trace cell*: they differ only
/// in method, so they share a single routed-token stream
/// ([`crate::trace::SharedRoutingTrace`]) — this is the execution
/// granularity of the sweep engine, which dispatches the whole cell as
/// **one fused job**: a single trace walk evaluating every method
/// simultaneously ([`crate::sim::evaluate_cell`]). Scenario `index`
/// values are the global grid enumeration (methods stride by the seed
/// count), so any per-scenario reduction is unchanged by the regroup.
#[derive(Clone, Debug)]
pub struct TraceCell {
    /// Model preset name.
    pub model: String,
    /// Routing seed shared by the cell's scenarios.
    pub seed: u64,
    /// One scenario per method, in the config's method order.
    pub scenarios: Vec<Scenario>,
}

/// Expand the grid grouped into (model, seed) trace cells. The cells
/// enumerate model-major, seed-minor; each cell's scenarios keep their
/// global grid indices from [`expand`].
pub fn expand_cells(cfg: &SweepConfig) -> Result<Vec<TraceCell>> {
    let scenarios = expand(cfg)?;
    let n_seeds = cfg.seeds.len();
    let n_methods = cfg.methods.len();
    let mut cells: Vec<TraceCell> = Vec::with_capacity(cfg.models.len() * n_seeds);
    for (mi, model_name) in cfg.models.iter().enumerate() {
        for (si, &seed) in cfg.seeds.iter().enumerate() {
            let cell_scenarios: Vec<Scenario> = (0..n_methods)
                .map(|me| scenarios[(mi * n_methods + me) * n_seeds + si].clone())
                .collect();
            debug_assert!(cell_scenarios
                .iter()
                .all(|s| s.seed == seed && &s.model == model_name));
            cells.push(TraceCell {
                model: model_name.clone(),
                seed,
                scenarios: cell_scenarios,
            });
        }
    }
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_count_and_indices() {
        let cfg = SweepConfig::paper_grid(7, 3, 5);
        let scenarios = expand(&cfg).unwrap();
        assert_eq!(scenarios.len(), 2 * 3 * 3);
        for (i, s) in scenarios.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.run.seed, s.seed);
            assert_eq!(s.run.method, s.method);
            assert_eq!(s.run.iterations, 5);
        }
    }

    #[test]
    fn expansion_order_is_model_method_seed() {
        let cfg = SweepConfig::paper_grid(7, 2, 5);
        let scenarios = expand(&cfg).unwrap();
        // first half model i, second half model ii
        assert!(scenarios[..6].iter().all(|s| s.model == "i"));
        assert!(scenarios[6..].iter().all(|s| s.model == "ii"));
        // seeds vary fastest
        assert_eq!(scenarios[0].method, scenarios[1].method);
        assert_ne!(scenarios[0].seed, scenarios[1].seed);
        assert_ne!(scenarios[1].method, scenarios[2].method);
    }

    #[test]
    fn cells_group_by_model_and_seed_preserving_indices() {
        let cfg = SweepConfig::paper_grid(7, 3, 5);
        let flat = expand(&cfg).unwrap();
        let cells = expand_cells(&cfg).unwrap();
        // 2 models × 3 seeds cells, 3 methods each
        assert_eq!(cells.len(), 6);
        let mut seen = vec![false; flat.len()];
        for cell in &cells {
            assert_eq!(cell.scenarios.len(), 3);
            for sc in &cell.scenarios {
                assert_eq!(sc.model, cell.model);
                assert_eq!(sc.seed, cell.seed);
                // the cell's scenario is the flat grid's scenario
                assert_eq!(sc.method, flat[sc.index].method);
                assert_eq!(sc.run, flat[sc.index].run);
                assert!(!seen[sc.index], "index {} duplicated", sc.index);
                seen[sc.index] = true;
            }
            // methods within a cell follow the config's method order
            assert_eq!(cell.scenarios[0].method, cfg.methods[0]);
            assert_eq!(cell.scenarios[2].method, cfg.methods[2]);
        }
        assert!(seen.iter().all(|&s| s), "cells cover the whole grid");
    }

    #[test]
    fn expansion_rejects_invalid_grid() {
        let mut cfg = SweepConfig::paper_grid(7, 2, 5);
        cfg.models = vec!["bogus".into()];
        assert!(expand(&cfg).is_err());
    }
}
