//! Memory planner walkthrough: the paper's §3 cost model as a tool.
//!
//! For a given model/parallelism it prints, per pipeline stage, the
//! static footprint (Eq. 1), the dense and MoE activation terms
//! (Table 2 / Eq. 2), the Eq. 8 token budget `s'_max`, and a sweep of
//! "what imbalance level OOMs at which chunk count" — the table an
//! operator would consult before launching a large-EP job.
//!
//! Run: `cargo run --release --example memory_planner -- [i|ii] [gpu-gb]`

use memfine::bench::BenchReport;
use memfine::config::{model_i, model_ii, paper_run, Method, GB};
use memfine::memory::{fits, ActivationModel, StaticModel};
use memfine::util::fmt_bytes;

fn main() -> memfine::Result<()> {
    memfine::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = match args.first().map(String::as_str) {
        Some("ii") => model_ii(),
        _ => model_i(),
    };
    let gpu_gb: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);

    let mut run = paper_run(model, Method::Mact(vec![1, 2, 4, 8]));
    run.gpu_mem_bytes = gpu_gb * GB;
    let act = ActivationModel::new(&run);
    let sta = StaticModel::new(&run);
    let budget = (run.alpha * run.gpu_mem_bytes as f64) as u64;

    println!("MemFine memory planner");
    println!(
        "model: L={} h={} g_e={} experts={} top_k={}  |  parallel: t={} p={} e={} b={}",
        run.model.layers, run.model.hidden, run.model.ffn_expert, run.model.n_experts,
        run.model.top_k, run.parallel.tp, run.parallel.pp, run.parallel.ep,
        run.parallel.micro_batch
    );
    println!("GPU: {} (budget α=0.9 → {})\n", fmt_bytes(run.gpu_mem_bytes), fmt_bytes(budget));

    let mut stages = BenchReport::new(
        "per-stage budget (Eq. 1 + Eq. 8)",
        &["stage", "static", "dense act", "moe B/token", "s'_max"],
    );
    for stage in 0..run.parallel.pp {
        let st = sta.bytes_on_rank(stage);
        stages.row(&[
            stage.to_string(),
            fmt_bytes(st),
            fmt_bytes(act.dense_bytes()),
            act.moe_bytes_per_token().to_string(),
            act.s_prime_max(stage, st, budget, true).to_string(),
        ]);
    }
    stages.print();

    // Imbalance sweep: fraction of the theoretical peak landing on one
    // rank vs minimal chunk count that still fits (0 = impossible).
    let theo = act.s_prime_theoretical_peak();
    let mut sweep = BenchReport::new(
        "minimal chunk count to fit vs imbalance severity",
        &["s' (% of peak)", "tokens", "act @ c=1", "min c that fits"],
    );
    for pct in [5u64, 10, 25, 40, 50, 65, 80, 100] {
        let s_recv = theo * pct / 100;
        let min_c = (1..=64u64).find(|&c| fits(&run, s_recv, c, true));
        sweep.row(&[
            format!("{pct}%"),
            s_recv.to_string(),
            fmt_bytes(act.peak_bytes(0, s_recv, true)),
            min_c.map(|c| c.to_string()).unwrap_or_else(|| "none".into()),
        ]);
    }
    sweep.print();
    println!("\nreading: rows where 'min c' > 1 are exactly the regimes where Method 1 OOMs and MemFine trains.");
    Ok(())
}
