//! Minimal leveled stderr logger (the offline registry carries no
//! `log`/`env_logger`, so the facade is in-tree).
//!
//! Level comes from `MEMFINE_LOG` (off|error|warn|info|debug|trace),
//! defaulting to `info`. Messages go to stderr with a monotonic
//! timestamp so example/bench output on stdout stays machine-parsable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Once, OnceLock};
use std::time::Instant;

/// Log severity, ordered so that `Error < Warn < … < Trace` and a
/// message is emitted when `level <= max_level`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Off => "OFF  ",
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();
static INIT: Once = Once::new();

/// Parse a level name, case-insensitive; unknown names yield None.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(Level::Off),
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Install the logger. Idempotent and thread-safe: the environment
/// read and level store run exactly once (guarded by [`Once`]), so
/// concurrent or repeated `init` calls cannot race a level change or
/// re-read a mutated environment. The monotonic clock anchors on the
/// first call (or the first log/`elapsed_ms`, whichever comes first).
pub fn init() {
    INIT.call_once(|| {
        let level = std::env::var("MEMFINE_LOG")
            .ok()
            .and_then(|s| parse_level(&s))
            .unwrap_or(Level::Info);
        START.get_or_init(Instant::now);
        MAX_LEVEL.store(level as u8, Ordering::Relaxed);
    });
}

/// Milliseconds elapsed on the shared monotonic clock — the same
/// anchor the log timestamps use, so event-log `t_ms` stamps
/// ([`crate::obs`]) and stderr lines are directly comparable.
pub fn elapsed_ms() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e3
}

/// Current maximum level.
pub fn max_level() -> Level {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Off,
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        4 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether a message at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level != Level::Off && level <= max_level()
}

/// Emit one message (used by the level helpers below).
pub fn log(level: Level, target: &str, msg: impl std::fmt::Display) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {target}] {msg}", level.tag());
}

pub fn error(target: &str, msg: impl std::fmt::Display) {
    log(Level::Error, target, msg);
}
pub fn warn(target: &str, msg: impl std::fmt::Display) {
    log(Level::Warn, target, msg);
}
pub fn info(target: &str, msg: impl std::fmt::Display) {
    log(Level::Info, target, msg);
}
pub fn debug(target: &str, msg: impl std::fmt::Display) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_names() {
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("TRACE"), Some(Level::Trace));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn level_ordering_gates_messages() {
        assert!(Level::Error < Level::Trace);
        init();
        // default level is info unless MEMFINE_LOG overrides; debug and
        // trace stay quiet at info.
        if max_level() == Level::Info {
            assert!(enabled(Level::Warn));
            assert!(!enabled(Level::Debug));
        }
        assert!(!enabled(Level::Off));
    }

    #[test]
    fn init_is_idempotent_and_thread_safe() {
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(init);
            }
        });
        let level = max_level();
        init();
        assert_eq!(max_level(), level);
        info("logging::tests", "logger smoke test");
    }

    #[test]
    fn elapsed_ms_is_monotonic() {
        let a = elapsed_ms();
        let b = elapsed_ms();
        assert!(b >= a);
        assert!(a >= 0.0);
    }
}
