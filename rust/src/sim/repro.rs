//! Paper-artifact reproduction routines shared by the CLI
//! (`memfine repro ...`) and the `cargo bench` harnesses. Each prints
//! the same rows/series the paper reports, with the paper's numbers
//! alongside for comparison (EXPERIMENTS.md records a snapshot).

use crate::bench::BenchReport;
use crate::config::{model_i, model_ii, paper_run, Method, ModelConfig};
use crate::router::GatingSim;
use crate::sim::Simulator;
use crate::Result;

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

fn methods() -> Vec<(&'static str, Method)> {
    vec![
        ("1 (full recompute)", Method::FullRecompute),
        ("2 (fixed c=8)", Method::FixedChunk(8)),
        ("3 (MACT 1,2,4,8)", Method::Mact(vec![1, 2, 4, 8])),
    ]
}

fn run_sim(model: ModelConfig, method: Method, seed: u64, iters: u64) -> Result<super::RunOutcome> {
    run_sim_opt(model, method, seed, iters, true)
}

fn run_sim_opt(
    model: ModelConfig,
    method: Method,
    seed: u64,
    iters: u64,
    selective: bool,
) -> Result<super::RunOutcome> {
    let mut run = paper_run(model, method);
    run.seed = seed;
    run.iterations = iters;
    run.allow_selective_recompute = selective;
    Ok(Simulator::new(run)?.run_all())
}

/// Table 4: memory comparison (static / active / all / trains?).
pub fn table4(seed: u64) -> Result<()> {
    let mut report = BenchReport::new(
        "Table 4 — memory comparison (paper values in parentheses)",
        &["model", "method", "static GB", "active GB", "all GB", "training"],
    );
    // Paper's Table 4 rows for side-by-side comparison.
    let paper: [[(f64, f64, f64, &str); 3]; 2] = [
        [
            (43.0, 22.9, 65.9, "x"),
            (43.0, 3.7, 46.7, "ok"),
            (43.0, 11.9, 54.9, "ok"),
        ],
        [
            (39.5, 22.9, 62.4, "ok"),
            (39.5, 3.7, 43.2, "ok"),
            (39.5, 11.9, 51.4, "ok"),
        ],
    ];
    let mut reductions = Vec::new();
    for (mi, (mname, model)) in [("I", model_i()), ("II", model_ii())].into_iter().enumerate() {
        let mut m1_act = 0.0;
        for (idx, (name, method)) in methods().into_iter().enumerate() {
            // Table 4 measures the *memory* configuration: chunked
            // recomputation everywhere (the paper's accounting). The
            // selective-recompute speed trade, which deliberately
            // re-spends the freed headroom, is reported in Fig. 4 and
            // the ablation bench instead.
            let out = run_sim_opt(model.clone(), method, seed, 25, false)?;
            let sta = out.static_bytes as f64 / GB;
            let act = out.peak_act_bytes as f64 / GB;
            let all = out
                .iterations
                .iter()
                .map(|i| i.peak_total_bytes)
                .max()
                .unwrap_or(0) as f64
                / GB;
            let (p_sta, p_act, p_all, p_train) = paper[mi][idx];
            if idx == 0 {
                m1_act = act;
            } else if mname == "I" {
                reductions.push((name, 100.0 * (1.0 - act / m1_act)));
            }
            report.row(&[
                mname.to_string(),
                name.to_string(),
                format!("{sta:.1} ({p_sta})"),
                format!("{act:.1} ({p_act})"),
                format!("{all:.1} ({p_all})"),
                format!(
                    "{} ({})",
                    if out.trained() { "ok" } else { "x" },
                    p_train
                ),
            ]);
        }
    }
    report.print();
    println!("\nheadline activation reductions vs Method 1 (paper: c=8 → 83.84 %, MACT → 48.03 %):");
    for (name, red) in reductions {
        println!("  method {name}: {red:.2} %");
    }
    Ok(())
}

/// Fig. 2: tokens received per MoE layer at one iteration (Model I).
pub fn fig2(seed: u64, iteration: u64) -> Result<()> {
    let run = paper_run(model_i(), Method::FullRecompute);
    let gating = GatingSim::new(run.model.clone(), run.parallel.clone(), seed);
    let mut report = BenchReport::new(
        &format!("Fig. 2 — received tokens per MoE layer (iteration {iteration})"),
        &["layer", "min", "mean", "max", "max/theoretical"],
    );
    let theo = gating.total_copies() as f64;
    for layer in run.model.dense_layers..run.model.layers {
        let r = gating.route(iteration, layer);
        let s = r.summary();
        report.row(&[
            layer.to_string(),
            r.min_received().to_string(),
            format!("{:.0}", s.mean()),
            r.max_received().to_string(),
            format!("{:.2}", r.max_received() as f64 / theo),
        ]);
    }
    report.print();
    println!("\npaper shape: deeper layers more imbalanced; max approaches the theoretical peak, min → 0.");
    Ok(())
}

/// Fig. 4: TGS per iteration for the three methods on both models.
pub fn fig4(seed: u64, iters: u64) -> Result<()> {
    for (mname, model) in [("I", model_i()), ("II", model_ii())] {
        let outs: Vec<_> = methods()
            .into_iter()
            .map(|(name, m)| (name, run_sim(model.clone(), m, seed, iters).unwrap()))
            .collect();
        let mut report = BenchReport::new(
            &format!("Fig. 4 — TGS per iteration, Model {mname}"),
            &["iter", "method 1", "method 2", "method 3"],
        );
        for it in 0..iters as usize {
            let cell = |o: &super::RunOutcome| {
                let i = &o.iterations[it];
                if i.oom {
                    "OOM".to_string()
                } else {
                    format!("{:.0}", i.tgs)
                }
            };
            report.row(&[
                it.to_string(),
                cell(&outs[0].1),
                cell(&outs[1].1),
                cell(&outs[2].1),
            ]);
        }
        report.print();
        let avg: Vec<f64> = outs.iter().map(|(_, o)| o.avg_tgs).collect();
        println!("\nModel {mname} average TGS: m1={:.0} m2={:.0} m3={:.0}", avg[0], avg[1], avg[2]);
        if outs[0].1.trained() {
            println!(
                "  m3 vs m1: {:+.2} %   (paper Model II: +4.42 %)",
                100.0 * (avg[2] / avg[0] - 1.0)
            );
            println!(
                "  m2 vs m1: {:+.2} %   (paper Model II: -5.40 %)",
                100.0 * (avg[1] / avg[0] - 1.0)
            );
        } else {
            println!("  method 1: OOM (paper Model I: cannot train)");
        }
        println!(
            "  m3 vs m2: {:+.2} %   (paper Model I: +18.26 %)",
            100.0 * (avg[2] / avg[1] - 1.0)
        );
    }
    Ok(())
}

/// Fig. 5: MACT chunk values per (layer, iteration) for Model I.
pub fn fig5(seed: u64, iters: u64) -> Result<()> {
    let out = run_sim(model_i(), Method::Mact(vec![1, 2, 4, 8]), seed, iters)?;
    let model = model_i();
    let grid = out.chunks.grid(model.layers, iters);
    println!("\n== Fig. 5 — MACT chunk value per (layer, iteration), Model I ==");
    print!("layer\\iter |");
    for it in 0..iters {
        print!("{it:>3}");
    }
    println!();
    println!("{}", "-".repeat(12 + 3 * iters as usize));
    for layer in (model.dense_layers..model.layers).rev() {
        print!("{layer:>10} |", );
        for it in 0..iters as usize {
            print!("{:>3}", grid[layer as usize][it]);
        }
        println!();
    }
    let means = out.chunks.mean_per_iteration(iters);
    println!("\nmean chunk per iteration:");
    for (it, m) in means.iter().enumerate() {
        println!("  iter {it:>2}: {m:.2} {}", "#".repeat((m * 4.0) as usize));
    }
    println!("\npaper shape: larger chunks concentrate in deep layers during iterations ~5-15, then stabilise.");
    Ok(())
}
