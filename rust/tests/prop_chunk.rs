//! Property tests for the FCDA chunk decomposition (paper §4.1),
//! wired through the crate's own harness (`memfine::prop`, no
//! `proptest` offline):
//!
//! * `split_chunks`: full coverage of the token range, contiguity, no
//!   empty chunk, length spread ≤ 1;
//! * `RecomputeSchedule::build`: every chunk is forwarded in order,
//!   then the backward phase walks chunks in reverse with the exact
//!   Recompute → Backward → Free triple per chunk (Eq. 6/7).

use memfine::chunk::{split_chunks, RecomputeSchedule, Step};
use memfine::prop::{assert_prop, PairGen, U64Range};

#[test]
fn prop_split_chunks_invariants() {
    let gen = PairGen(U64Range(1, 1_048_576), U64Range(1, 128));
    assert_prop(101, 500, &gen, |&(tokens, c)| {
        let chunks = split_chunks(tokens, c);
        let effective = c.min(tokens);
        if chunks.len() as u64 != effective {
            return Err(format!(
                "expected {effective} chunks for n={tokens} c={c}, got {}",
                chunks.len()
            ));
        }
        // coverage + contiguity: chunk i starts where i-1 ended, the
        // first at 0, and the lengths sum to the token count.
        let mut cursor = 0u64;
        for (i, ch) in chunks.iter().enumerate() {
            if ch.index != i as u64 {
                return Err(format!("index {} at position {i}", ch.index));
            }
            if ch.start != cursor {
                return Err(format!("gap before chunk {i}: start {} != {cursor}", ch.start));
            }
            if ch.len == 0 {
                return Err(format!("empty chunk {i} (n={tokens}, c={c})"));
            }
            cursor += ch.len;
        }
        if cursor != tokens {
            return Err(format!("covered {cursor} of {tokens} tokens"));
        }
        // near-equal split: max − min ≤ 1
        let max = chunks.iter().map(|ch| ch.len).max().unwrap();
        let min = chunks.iter().map(|ch| ch.len).min().unwrap();
        if max - min > 1 {
            return Err(format!("len spread {min}..{max} > 1"));
        }
        Ok(())
    });
}

#[test]
fn prop_recompute_schedule_exact_shape() {
    let gen = PairGen(U64Range(1, 500_000), U64Range(1, 64));
    assert_prop(103, 300, &gen, |&(tokens, c)| {
        let s = RecomputeSchedule::build(tokens, c);
        let n = s.chunks.len() as u64;
        if s.steps.len() as u64 != 4 * n {
            return Err(format!("{} steps for {n} chunks", s.steps.len()));
        }
        // phase 1: all forwards, ascending chunk order
        for i in 0..n {
            if s.steps[i as usize] != Step::Forward(i) {
                return Err(format!("step {i} is {:?}, want Forward({i})", s.steps[i as usize]));
            }
        }
        // phase 2: reverse chunk order, Recompute → Backward → Free
        for (pos, i) in (0..n).rev().enumerate() {
            let base = (n + 3 * pos as u64) as usize;
            let triple = [&s.steps[base], &s.steps[base + 1], &s.steps[base + 2]];
            if *triple[0] != Step::Recompute(i)
                || *triple[1] != Step::Backward(i)
                || *triple[2] != Step::Free(i)
            {
                return Err(format!("backward triple for chunk {i} malformed: {triple:?}"));
            }
        }
        // and the schedule's own validator agrees
        if !s.validate() {
            return Err("validate() rejected a built schedule".into());
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_peak_equals_largest_chunk() {
    // The paper's memory claim in executable form: with recomputed
    // activations costing `len` units, the peak live cost equals the
    // largest chunk, never the sum (Eq. 6).
    let gen = PairGen(U64Range(1, 200_000), U64Range(1, 32));
    assert_prop(107, 300, &gen, |&(tokens, c)| {
        let s = RecomputeSchedule::build(tokens, c);
        let peak = s.peak_live_cost(|len| len);
        let max_chunk = s.chunks.iter().map(|ch| ch.len).max().unwrap_or(0);
        if peak != max_chunk {
            return Err(format!("peak {peak} != largest chunk {max_chunk}"));
        }
        Ok(())
    });
}
