//! Timing harness for the `rust/benches/*.rs` targets (no `criterion`
//! in the offline registry).
//!
//! `time_fn` warms up, then reports median / mean / p10 / p90 over N
//! timed runs of a closure; `BenchReport` renders aligned tables that
//! `cargo bench` prints — each paper table/figure bench uses this to
//! emit its rows.

use std::time::Instant;

use crate::util::stats::Summary;

/// Result of timing one closure.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub runs: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl Timing {
    pub fn per_sec(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f` with `warmup` discarded runs then `runs` measured runs.
/// The closure's return value is black-boxed to keep the optimiser
/// honest.
pub fn time_fn<T>(name: &str, warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Timing {
    assert!(runs > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Summary::new();
    for _ in 0..runs {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        runs,
        median_s: samples.p50(),
        mean_s: samples.mean(),
        p10_s: samples.percentile(10.0),
        p90_s: samples.percentile(90.0),
    }
}

/// Identity function the optimiser cannot elide.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty time for humans: picks ns/µs/ms/s.
pub fn fmt_time(seconds: f64) -> String {
    if seconds < 1e-6 {
        format!("{:.0} ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.2} µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.2} ms", seconds * 1e3)
    } else {
        format!("{seconds:.3} s")
    }
}

/// Aligned table printer for bench output.
pub struct BenchReport {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl BenchReport {
    pub fn new(title: &str, header: &[&str]) -> Self {
        BenchReport {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_positive() {
        let t = time_fn("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(t.median_s > 0.0);
        assert!(t.p10_s <= t.median_s && t.median_s <= t.p90_s);
        assert_eq!(t.runs, 5);
        assert!(t.per_sec() > 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with(" s"));
    }

    #[test]
    fn report_renders_aligned() {
        let mut r = BenchReport::new("t", &["name", "value"]);
        r.row(&["a".into(), "1".into()]);
        r.row(&["long-name".into(), "22".into()]);
        let text = r.render();
        assert!(text.contains("== t =="));
        let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn report_rejects_bad_row() {
        let mut r = BenchReport::new("t", &["a", "b"]);
        r.row(&["only-one".into()]);
    }
}
