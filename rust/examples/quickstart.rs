//! Quickstart: the MemFine public API in ~60 lines.
//!
//! 1. Build the paper's Model I run config.
//! 2. Ask the memory model whether unrestricted routing can OOM (it
//!    can — that's the paper's premise).
//! 3. Let MACT pick the chunk count that makes the worst case fit.
//! 4. Simulate a few iterations and print the TGS.
//!
//! Run with: `cargo run --release --example quickstart`

use memfine::chunk::Mact;
use memfine::config::{model_i, paper_run, Method};
use memfine::memory::{fits, ActivationModel};
use memfine::sim::Simulator;
use memfine::util::fmt_bytes;

fn main() -> memfine::Result<()> {
    memfine::logging::init();

    // The paper's experimental envelope: Model I (16-layer reduced
    // DeepSeek-V3) on 32 × 64 GB GPUs with e=32, p=4, drop-free top-8.
    let run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
    let act = ActivationModel::new(&run);

    // Worst case: every routed copy lands on one rank (s' → e·s·t_k).
    let worst = act.s_prime_theoretical_peak();
    println!("theoretical worst-case received tokens: {worst}");
    println!(
        "activation at worst case, no chunking: {}",
        fmt_bytes(act.peak_bytes(0, worst, true))
    );
    println!(
        "fits in 64 GB without chunking?  {}",
        if fits(&run, worst, 1, true) { "yes" } else { "NO — this is the paper's OOM" }
    );

    // MACT (Eq. 8/9): per-stage token budget → minimal chunk bin.
    let mact = Mact::new(&run, vec![1, 2, 4, 8]);
    for stage in 0..run.parallel.pp {
        let d = mact.decide(stage, worst);
        println!(
            "stage {stage}: s'_max = {:>7}  →  ideal c = {}, chosen bin = {} (feasible: {})",
            d.s_prime_max, d.ideal_c, d.chosen_c, d.feasible
        );
    }

    // Simulate 10 training iterations under MACT.
    let mut run = run;
    run.iterations = 10;
    let outcome = Simulator::new(run)?.run_all();
    println!(
        "\nsimulated {} iterations: peak activation {}, avg TGS {:.0}, OOM iterations {}",
        outcome.iterations.len(),
        fmt_bytes(outcome.peak_act_bytes),
        outcome.avg_tgs,
        outcome.oom_iterations
    );
    println!("MemFine keeps the run alive without touching the router. ✓");
    Ok(())
}
