//! Pipeline-parallel scheduling: Megatron-style 1F1B with optional
//! interleaved virtual stages.
//!
//! The memory model's `m_g = v·p + p − 2·r − 1` (paper Eq. 2 note) is
//! *derived* here from the actual schedule — the number of forward
//! activations a stage holds before its first backward — and the unit
//! tests assert the closed form matches the constructed schedule, so
//! the simulator and the paper's formula cannot drift apart.

use crate::error::{Error, Result};

/// One pipeline operation on a stage's timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipeOp {
    /// Forward of micro-batch `mb` on virtual stage `v`.
    Forward { mb: u64, v: u64 },
    /// Backward of micro-batch `mb` on virtual stage `v`.
    Backward { mb: u64, v: u64 },
}

/// The schedule of one pipeline rank: ordered ops.
#[derive(Clone, Debug)]
pub struct StageSchedule {
    pub pp_rank: u64,
    pub ops: Vec<PipeOp>,
}

impl StageSchedule {
    /// Maximum number of micro-batch activations simultaneously alive
    /// (forward issued, backward not yet) — the schedule-derived `m_g`.
    pub fn peak_in_flight(&self) -> u64 {
        let mut live = 0i64;
        let mut peak = 0i64;
        for op in &self.ops {
            match op {
                PipeOp::Forward { .. } => {
                    live += 1;
                    peak = peak.max(live);
                }
                PipeOp::Backward { .. } => live -= 1,
            }
        }
        peak.max(0) as u64
    }

    /// Every forward has a matching backward, each exactly once, and
    /// no backward precedes its forward.
    pub fn validate(&self, micro_batches: u64, vpp: u64) -> Result<()> {
        use std::collections::HashMap;
        let mut state: HashMap<(u64, u64), u8> = HashMap::new();
        for op in &self.ops {
            match *op {
                PipeOp::Forward { mb, v } => {
                    if mb >= micro_batches || v >= vpp {
                        return Err(Error::schedule(format!("op out of range: {op:?}")));
                    }
                    let e = state.entry((mb, v)).or_insert(0);
                    if *e != 0 {
                        return Err(Error::schedule(format!("double forward {op:?}")));
                    }
                    *e = 1;
                }
                PipeOp::Backward { mb, v } => {
                    let e = state.entry((mb, v)).or_insert(0);
                    if *e != 1 {
                        return Err(Error::schedule(format!(
                            "backward without forward {op:?}"
                        )));
                    }
                    *e = 2;
                }
            }
        }
        if state.len() as u64 != micro_batches * vpp
            || state.values().any(|&s| s != 2)
        {
            return Err(Error::schedule("schedule incomplete"));
        }
        Ok(())
    }
}

/// Build the 1F1B schedule for `pp_rank` of `pp` stages over
/// `micro_batches` micro-batches (vpp = 1).
///
/// Warm-up: `p − r − 1` forwards; steady state alternates 1F1B;
/// cool-down drains backwards.
pub fn one_f_one_b(pp: u64, pp_rank: u64, micro_batches: u64) -> StageSchedule {
    assert!(pp_rank < pp);
    let warmup = (pp - pp_rank - 1).min(micro_batches);
    let mut ops = Vec::new();
    let mut next_fwd = 0;
    let mut next_bwd = 0;
    for _ in 0..warmup {
        ops.push(PipeOp::Forward { mb: next_fwd, v: 0 });
        next_fwd += 1;
    }
    while next_fwd < micro_batches {
        ops.push(PipeOp::Forward { mb: next_fwd, v: 0 });
        next_fwd += 1;
        ops.push(PipeOp::Backward { mb: next_bwd, v: 0 });
        next_bwd += 1;
    }
    while next_bwd < micro_batches {
        ops.push(PipeOp::Backward { mb: next_bwd, v: 0 });
        next_bwd += 1;
    }
    StageSchedule { pp_rank, ops }
}

/// Megatron-style interleaved 1F1B (virtual pipeline): each rank hosts
/// `vpp` model chunks and warms up `2(p − r − 1) + (vpp − 1)·p`
/// forward chunks before the first backward. The peak in-flight count
/// is therefore `vp + p − 2r − 1` — exactly the paper's `m_g` (Eq. 2
/// note), which the tests assert against the constructed schedule.
/// Note this differs from the textbook non-interleaved 1F1B
/// ([`one_f_one_b`]), whose warm-up is `p − r − 1` (peak `p − r`).
pub fn interleaved_1f1b(
    pp: u64,
    pp_rank: u64,
    vpp: u64,
    micro_batches: u64,
) -> StageSchedule {
    assert!(pp_rank < pp && vpp >= 1);
    let total = micro_batches * vpp;
    let warmup = (2 * (pp - pp_rank - 1) + (vpp - 1) * pp).min(total);
    // forward order: round-robin micro-batch groups of size p over
    // virtual stages (Megatron interleaving)
    let fwd_seq: Vec<(u64, u64)> = {
        let mut seq = Vec::with_capacity(total as usize);
        let groups = micro_batches.div_ceil(pp);
        for g in 0..groups {
            for v in 0..vpp {
                for i in 0..pp {
                    let mb = g * pp + i;
                    if mb < micro_batches {
                        seq.push((mb, v));
                    }
                }
            }
        }
        seq
    };
    // backward order mirrors forward order (reverse virtual stage)
    let bwd_seq: Vec<(u64, u64)> = fwd_seq
        .iter()
        .map(|&(mb, v)| (mb, vpp - 1 - v))
        .collect();
    let mut ops = Vec::new();
    let mut fi = 0usize;
    let mut bi = 0usize;
    for _ in 0..warmup {
        let (mb, v) = fwd_seq[fi];
        ops.push(PipeOp::Forward { mb, v });
        fi += 1;
    }
    while fi < fwd_seq.len() {
        let (mb, v) = fwd_seq[fi];
        ops.push(PipeOp::Forward { mb, v });
        fi += 1;
        let (mb, v) = bwd_seq[bi];
        ops.push(PipeOp::Backward { mb, v });
        bi += 1;
    }
    while bi < bwd_seq.len() {
        let (mb, v) = bwd_seq[bi];
        ops.push(PipeOp::Backward { mb, v });
        bi += 1;
    }
    StageSchedule { pp_rank, ops }
}

/// Closed-form in-flight bound from the paper: `vp + p − 2r − 1`,
/// clamped to the number of forward units available.
pub fn m_g_closed_form(pp: u64, pp_rank: u64, vpp: u64, micro_batches: u64) -> u64 {
    let raw = (vpp * pp + pp) as i64 - 2 * pp_rank as i64 - 1;
    (raw.max(1) as u64).min(micro_batches * vpp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_f_one_b_valid_all_ranks() {
        for rank in 0..4 {
            let s = one_f_one_b(4, rank, 16);
            s.validate(16, 1).unwrap();
        }
    }

    #[test]
    fn one_f_one_b_peak_is_p_minus_r() {
        // textbook non-interleaved 1F1B: warm-up p−r−1 → peak p−r
        for pp in [2u64, 4, 8] {
            for rank in 0..pp {
                let s = one_f_one_b(pp, rank, 32);
                assert_eq!(s.peak_in_flight(), (pp - rank).min(32), "pp={pp} rank={rank}");
            }
        }
    }

    #[test]
    fn last_stage_holds_one() {
        let s = one_f_one_b(4, 3, 16);
        assert_eq!(s.peak_in_flight(), 1);
    }

    #[test]
    fn few_microbatches_cap_in_flight() {
        let s = one_f_one_b(8, 0, 2);
        assert_eq!(s.peak_in_flight(), 2);
        s.validate(2, 1).unwrap();
    }

    #[test]
    fn interleaved_valid_and_deeper() {
        for rank in 0..4 {
            let s = interleaved_1f1b(4, rank, 2, 8);
            s.validate(8, 2).unwrap();
            // interleaving holds MORE in flight than plain 1F1B
            let plain = one_f_one_b(4, rank, 8).peak_in_flight();
            assert!(s.peak_in_flight() >= plain, "rank {rank}");
        }
    }

    #[test]
    fn interleaved_peak_matches_paper_m_g() {
        // Megatron interleaved warm-up 2(p−r−1) + (v−1)p ⇒ peak
        // in-flight = vp + p − 2r − 1, the paper's m_g, for v = 1 and 2.
        for vpp in [1u64, 2] {
            for rank in 0..4u64 {
                let s = interleaved_1f1b(4, rank, vpp, 16);
                let bound = m_g_closed_form(4, rank, vpp, 16);
                assert_eq!(
                    s.peak_in_flight(),
                    bound,
                    "vpp={vpp} rank={rank}"
                );
            }
        }
    }

    #[test]
    fn validate_rejects_missing_backward() {
        let mut s = one_f_one_b(2, 0, 4);
        s.ops.pop();
        assert!(s.validate(4, 1).is_err());
    }

    #[test]
    fn validate_rejects_double_forward() {
        let s = StageSchedule {
            pp_rank: 0,
            ops: vec![
                PipeOp::Forward { mb: 0, v: 0 },
                PipeOp::Forward { mb: 0, v: 0 },
            ],
        };
        assert!(s.validate(1, 1).is_err());
    }

    #[test]
    fn paper_setting_m_g() {
        // p=4, v=1, 960 micro-batches: stage 0 = 7, stage 3 = 1 —
        // matches config::ParallelConfig::m_g, via the interleaved
        // (Megatron) scheduler the paper models.
        assert_eq!(m_g_closed_form(4, 0, 1, 960), 7);
        assert_eq!(m_g_closed_form(4, 3, 1, 960), 1);
        let s = interleaved_1f1b(4, 0, 1, 960);
        assert_eq!(s.peak_in_flight(), 7);
        assert_eq!(interleaved_1f1b(4, 3, 1, 960).peak_in_flight(), 1);
    }
}
