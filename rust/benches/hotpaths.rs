//! `cargo bench --bench hotpaths` — micro-benchmarks of the Layer-3
//! hot paths (EXPERIMENTS.md §Perf tracks these before/after):
//!
//!   * router sampling (multinomial over 256 experts)
//!   * dispatch planning (token-level all-to-all plan)
//!   * MACT decision
//!   * FCDA schedule construction
//!   * memory-model evaluation
//!   * JSON parse of a manifest-sized document
//!   * PJRT execute round-trip overhead (when artifacts are present)

use memfine::bench::{fmt_time, time_fn, BenchReport};
use memfine::chunk::{Mact, RecomputeSchedule};
use memfine::config::{model_i, paper_parallel, paper_run, Method};
use memfine::dispatch;
use memfine::memory::ActivationModel;
use memfine::router::GatingSim;
use memfine::util::rng::Rng;

fn main() {
    memfine::logging::init();
    let mut report = BenchReport::new(
        "L3 hot paths",
        &["path", "median", "p90", "ops/s"],
    );
    let mut add = |t: memfine::bench::Timing| {
        report.row(&[
            t.name.clone(),
            fmt_time(t.median_s),
            fmt_time(t.p90_s),
            format!("{:.0}", t.per_sec()),
        ]);
    };

    // Router sampling.
    let sim = GatingSim::new(model_i(), paper_parallel(), 7);
    add(time_fn("router.route (256 experts, 1M copies)", 3, 30, || {
        sim.route(7, 15).max_received()
    }));

    // Dispatch planning at coordinator scale: 4 ranks × 512 tokens × top-2.
    let parallel = {
        let mut p = paper_parallel();
        p.ep = 4;
        p
    };
    let assignments: Vec<Vec<Vec<u32>>> = {
        let mut rng = Rng::new(3);
        (0..4)
            .map(|_| {
                (0..512)
                    .map(|_| {
                        let a = rng.below(32) as u32;
                        let mut b = rng.below(32) as u32;
                        if b == a {
                            b = (b + 1) % 32;
                        }
                        vec![a, b]
                    })
                    .collect()
            })
            .collect()
    };
    add(time_fn("dispatch.plan (4096 copies)", 10, 100, || {
        dispatch::plan(&parallel, 32, &assignments, 4096).unwrap().placed()
    }));

    // MACT decision.
    let run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
    let mact = Mact::new(&run, vec![1, 2, 4, 8]);
    add(time_fn("mact.decide", 1000, 10_000, || {
        mact.decide(1, 250_000).chosen_c
    }));

    // FCDA schedule.
    add(time_fn("RecomputeSchedule::build(4096, 8)", 100, 5_000, || {
        RecomputeSchedule::build(4096, 8).steps.len()
    }));

    // Memory model.
    let act = ActivationModel::new(&run);
    add(time_fn("memory.peak_bytes_chunked", 1000, 50_000, || {
        act.peak_bytes_chunked(1, 250_000, 4, true)
    }));

    // JSON parse (manifest-sized doc).
    let doc = {
        let mut s = String::from("{\"entries\":[");
        for i in 0..64 {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"name\":\"e{i}\",\"shape\":[8,1024,256],\"dtype\":\"f32\",\"n\":{i}}}"
            ));
        }
        s.push_str("]}");
        s
    };
    add(time_fn("json.parse (manifest-sized)", 50, 2_000, || {
        memfine::json::parse(&doc).unwrap()
    }));

    // PJRT execute overhead (only with artifacts present).
    if let Ok(store) = memfine::runtime::ArtifactStore::open("artifacts") {
        if store.entries.contains_key("router_topk") {
            let spec = &store.entries["router_topk"].inputs;
            let x = memfine::runtime::HostTensor::F32(vec![0.1; spec[0].elements()]);
            let w = memfine::runtime::HostTensor::F32(vec![0.1; spec[1].elements()]);
            // compile once outside the timer
            store.execute("router_topk", &[x.clone(), w.clone()]).unwrap();
            add(time_fn("pjrt execute router_topk (512×256)", 3, 30, || {
                store.execute("router_topk", &[x.clone(), w.clone()]).unwrap().len()
            }));
        }
    } else {
        eprintln!("(artifacts/ not built — skipping PJRT hot path; run `make artifacts`)");
    }

    report.print();
}
