//! PJRT runtime: load HLO-text artifacts, compile once, execute from
//! the rust hot path. Python never runs here — `make artifacts` is the
//! only compile-path step.
//!
//! The execution backend wraps the `xla` crate (xla_extension 0.5.1,
//! CPU plugin): `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`. The interchange format is HLO
//! *text* because jax ≥ 0.5 emits 64-bit instruction ids that this XLA
//! rejects in proto form (see /opt/xla-example/README.md).
//!
//! The `xla` crate is not available in the offline registry, so the
//! whole execution path is gated behind the `pjrt` cargo feature.
//! `--features pjrt` alone compiles it against the in-tree
//! [`xla_stub`] API shim (so CI type-checks the execution path; every
//! execute attempt degrades to `Error::Runtime`); real execution
//! additionally needs the `xla-backend` feature plus an `xla` path
//! dependency added to `Cargo.toml` in an environment that has the
//! XLA toolchain (see the feature comments there). Without `pjrt`,
//! manifest loading and all metadata stay fully functional and
//! [`ArtifactStore::execute`] returns `Error::Runtime` — callers
//! (coordinator, train driver, tests) degrade gracefully exactly as
//! they do when `artifacts/` is absent.
//!
//! [`ArtifactStore`] reads `artifacts/manifest.json` (via the crate's
//! own JSON parser), exposes typed entry metadata, and memoises
//! compiled executables so each variant is compiled exactly once per
//! process — one executable per FCDA chunk bin, exactly as MACT
//! assumes.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::json::{self, Value};

#[cfg(all(feature = "pjrt", not(feature = "xla-backend")))]
pub mod xla_stub;
// Without the real backend, `xla::...` below resolves to the stub —
// with `xla-backend` the alias vanishes and the extern crate takes
// over, so the exact same code compiles against both.
#[cfg(all(feature = "pjrt", not(feature = "xla-backend")))]
use self::xla_stub as xla;

/// Shape + dtype of one executable input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::artifact("entry missing shape"))?
            .iter()
            .map(|d| d.as_u64().map(|x| x as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| Error::artifact("bad shape"))?;
        Ok(TensorSpec { shape, dtype: v.req_str("dtype")?.to_string() })
    }
}

/// One AOT entry from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// FCDA chunk bin for expert kernels (None otherwise).
    pub chunk_bin: Option<u64>,
    /// Per-expert capacity for expert kernels.
    pub capacity: Option<u64>,
}

/// Parameter-vector slice layout from the manifest.
#[derive(Clone, Debug)]
pub struct ParamLayout {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
}

/// The artifact directory: manifest + lazily compiled executables.
pub struct ArtifactStore {
    dir: PathBuf,
    pub entries: HashMap<String, ArtifactEntry>,
    pub param_count: usize,
    pub param_layout: ParamLayout,
    /// The manifest `config` block (model dims).
    pub config: Value,
    /// The full manifest root (coordinator block, kernel_perf, ...).
    pub manifest: Value,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactStore {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = json::parse(&text)?;
        let mut entries = HashMap::new();
        for e in manifest
            .get("entries")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::artifact("manifest missing entries"))?
        {
            let name = e.req_str("name")?.to_string();
            let inputs = e
                .get("inputs")
                .and_then(Value::as_arr)
                .ok_or_else(|| Error::artifact("entry missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Value::as_arr)
                .ok_or_else(|| Error::artifact("entry missing outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name,
                    file: e.req_str("file")?.to_string(),
                    inputs,
                    outputs,
                    chunk_bin: e.get("chunk_bin").and_then(Value::as_u64),
                    capacity: e.get("capacity").and_then(Value::as_u64),
                },
            );
        }
        let param_layout = {
            let arr = manifest
                .get("param_layout")
                .and_then(Value::as_arr)
                .ok_or_else(|| Error::artifact("manifest missing param_layout"))?;
            let mut names = Vec::new();
            let mut shapes = Vec::new();
            for p in arr {
                names.push(p.req_str("name")?.to_string());
                shapes.push(
                    p.get("shape")
                        .and_then(Value::as_arr)
                        .ok_or_else(|| Error::artifact("param missing shape"))?
                        .iter()
                        .filter_map(Value::as_u64)
                        .map(|x| x as usize)
                        .collect(),
                );
            }
            ParamLayout { names, shapes }
        };
        #[cfg(feature = "pjrt")]
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PJRT CPU client: {e:?}")))?;
        Ok(ArtifactStore {
            dir,
            entries,
            param_count: manifest.req_u64("param_count")? as usize,
            param_layout,
            config: manifest.get("config").cloned().unwrap_or(Value::Null),
            manifest,
            #[cfg(feature = "pjrt")]
            client,
            #[cfg(feature = "pjrt")]
            compiled: Mutex::new(HashMap::new()),
        })
    }

    #[cfg(feature = "pjrt")]
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load the initial parameter vector (params.bin, little-endian f32).
    pub fn initial_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("params.bin");
        let bytes = std::fs::read(&path)?;
        if bytes.len() != self.param_count * 4 {
            return Err(Error::artifact(format!(
                "params.bin has {} bytes, expected {}",
                bytes.len(),
                self.param_count * 4
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Compile (or fetch memoised) executable for `name`.
    #[cfg(feature = "pjrt")]
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.compiled.lock().unwrap();
            if let Some(exe) = cache.get(name) {
                return Ok(exe.clone());
            }
        }
        let entry = self
            .entries
            .get(name)
            .ok_or_else(|| Error::artifact(format!("no artifact entry '{name}'")))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::artifact("non-utf8 path"))?,
        )
        .map_err(|e| Error::runtime(format!("parse {}: {e:?}", entry.file)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {name}: {e:?}")))?;
        let exe = std::sync::Arc::new(exe);
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute `name` on f32/i32 host buffers, validating shapes against
    /// the manifest. Returns the flattened f32 outputs (i32 outputs are
    /// converted losslessly for ids ≤ 2^24; the router indices fit).
    #[cfg(feature = "pjrt")]
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let entry = self
            .entries
            .get(name)
            .ok_or_else(|| Error::artifact(format!("no artifact entry '{name}'")))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            return Err(Error::runtime(format!(
                "{name}: {} inputs given, expects {}",
                inputs.len(),
                entry.inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (spec, input)) in entry.inputs.iter().zip(inputs).enumerate() {
            if input.elements() != spec.elements() {
                return Err(Error::runtime(format!(
                    "{name} input {i}: {} elements, expects {:?}",
                    input.elements(),
                    spec.shape
                )));
            }
            literals.push(input.to_literal(&spec.shape)?);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::runtime(format!("execute {name}: {e:?}")))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("fetch {name}: {e:?}")))?;
        // aot.py lowers with return_tuple=True: decompose.
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| Error::runtime(format!("untuple {name}: {e:?}")))?;
        if parts.len() != entry.outputs.len() {
            return Err(Error::runtime(format!(
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                entry.outputs.len()
            )));
        }
        parts
            .into_iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }

    /// Stub when the crate is built without the `pjrt` feature: the
    /// manifest metadata above stays available, but execution is
    /// impossible — callers see the same `Error::Runtime` degradation
    /// path they use when artifacts are missing.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute(&self, name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if !self.entries.contains_key(name) {
            return Err(Error::artifact(format!("no artifact entry '{name}'")));
        }
        Err(Error::runtime(format!(
            "cannot execute '{name}': built without the `pjrt` feature \
             (no XLA backend in this environment)"
        )))
    }
}

/// A host-side tensor: f32 or i32 flat buffer + logical shape.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => Err(Error::runtime("expected f32 tensor")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => Err(Error::runtime("expected i32 tensor")),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            return Err(Error::runtime(format!("expected scalar, len {}", v.len())));
        }
        Ok(v[0])
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        if shape.len() == 1 {
            return Ok(lit);
        }
        lit.reshape(&dims)
            .map_err(|e| Error::runtime(format!("reshape to {shape:?}: {e:?}")))
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        match spec.dtype.as_str() {
            "i32" => Ok(HostTensor::I32(lit.to_vec::<i32>().map_err(|e| {
                Error::runtime(format!("literal→i32: {e:?}"))
            })?)),
            _ => Ok(HostTensor::F32(lit.to_vec::<f32>().map_err(|e| {
                Error::runtime(format!("literal→f32: {e:?}"))
            })?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { shape: vec![2, 3, 4], dtype: "f32".into() };
        assert_eq!(t.elements(), 24);
        let s = TensorSpec { shape: vec![], dtype: "f32".into() };
        assert_eq!(s.elements(), 1);
    }

    #[test]
    fn host_tensor_accessors() {
        let f = HostTensor::F32(vec![1.0, 2.0]);
        assert_eq!(f.elements(), 2);
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        let s = HostTensor::F32(vec![3.5]);
        assert_eq!(s.scalar_f32().unwrap(), 3.5);
        assert!(f.scalar_f32().is_err());
    }

    #[test]
    fn open_missing_dir_is_artifact_error() {
        match ArtifactStore::open("/nonexistent-path-xyz") {
            Err(Error::Artifact(msg)) => assert!(msg.contains("make artifacts")),
            Err(other) => panic!("unexpected error {other:?}"),
            Ok(_) => panic!("open unexpectedly succeeded"),
        }
    }
}
