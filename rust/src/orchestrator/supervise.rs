//! Shard-fleet supervision: spawn one child process per [`ShardPlan`],
//! watch liveness through checkpoint-growth heartbeats
//! ([`crate::orchestrator::health`]), kill and relaunch crashed or
//! stalled shards with `--resume` under a [`RetryPolicy`], and
//! summarise each shard's fate.
//!
//! The retry shape is policy-driven, not hard-coded: relaunch budgets
//! are scoped to a *failure episode* and reset whenever the shard
//! shows fresh checkpoint progress (so a long campaign with occasional
//! independent failures does not die by attrition), a global campaign
//! budget bounds fleet-wide relaunches (the guard against a crash loop
//! that happens to append bytes each attempt), relaunches back off
//! exponentially with deterministic jitter, and a shard that gives up
//! without progress has its checkpoint *quarantined* — renamed aside
//! so the merge catch-up re-executes its cells from scratch, keeping
//! the campaign artifact byte-identical.
//!
//! The supervisor is generic over the *spawner* — any
//! `FnMut(&ShardPlan, attempt) -> Result<Child>` — so tests can
//! inject wedged or crashing fakes without touching the real `memfine
//! sweep` command line, and every decision it makes is surfaced as a
//! [`ShardEvent`] through the caller's callback. [`supervise_fleet`]
//! lifts the same seam to a [`HostPool`]: one spawner per host, a
//! live shard→host assignment, and a lease plane whose expiry the
//! poll loop treats as **whole-host loss** — the dead host's shards
//! are reassigned to survivors under the same retry budgets/backoff,
//! and merge catch-up heals whatever the host never wrote.
//!
//! Scripted chaos ([`crate::orchestrator::chaos::FaultPlan`]) is
//! executed from inside the poll loop: kill specs strike at their poll
//! tick (relaunches from an injected kill never consume retry budget),
//! corruption specs damage a shard's checkpoint in flight, slow specs
//! delay a shard's first spawn, and host-loss specs kill every child
//! on one host and stop its lease — the shards then wait for the
//! lease to expire, exactly as they would under a real machine loss.
//!
//! Correctness never depends on supervision: children checkpoint every
//! completed scenario, relaunches resume from those checkpoints, and
//! the merge step audits coverage and re-runs any gap in-process — so
//! a kill at any point (including injected chaos) costs only the
//! in-flight work, never the artifact's bytes.

use std::path::PathBuf;
use std::process::Child;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::logging;
use crate::orchestrator::chaos::{
    self, CorruptMode, CorruptSpec, FaultPlan, HostLossSpec, KillSpec,
};
use crate::orchestrator::health::{probe_len, HeartbeatMonitor};
use crate::orchestrator::host::HostPool;
use crate::orchestrator::plan::ShardPlan;
use crate::util;

/// File-name suffix appended to a quarantined shard checkpoint. The
/// rename changes the extension away from `.jsonl`, which is what
/// excludes the file from every campaign-state glob (launch resume,
/// merge inputs, `memfine status`).
pub const QUARANTINE_SUFFIX: &str = ".quarantined";

/// The relaunch policy: how hard supervision fights for a shard
/// before handing its cells to the merge catch-up.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Relaunches allowed per failure episode. An episode ends (and
    /// the budget resets) when the shard's checkpoint shows observed
    /// progress.
    pub episode_retries: u32,
    /// Fleet-wide relaunch budget across the whole campaign; 0 means
    /// unlimited. This is the backstop against a shard that crashes
    /// in a loop while still appending bytes each attempt — every
    /// such append resets its episode budget, so only a global bound
    /// can stop it.
    pub campaign_retries: u32,
    /// Base delay before the first relaunch of an episode; doubles
    /// per relaunch. Zero disables backoff entirely.
    pub backoff_base: Duration,
    /// Ceiling for the exponential backoff.
    pub backoff_cap: Duration,
    /// Seed for the deterministic backoff jitter (derived from the
    /// campaign dir by `launch`, so drills replay exactly).
    pub jitter_seed: u64,
    /// Rename a persistently-failing shard's checkpoint aside
    /// ([`QUARANTINE_SUFFIX`]) when it gives up without progress, so
    /// the merge redistributes its cells through catch-up.
    pub quarantine: bool,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            episode_retries: 2,
            campaign_retries: 16,
            backoff_base: Duration::from_millis(100),
            backoff_cap: Duration::from_secs(10),
            jitter_seed: 0,
            quarantine: true,
        }
    }
}

impl RetryPolicy {
    /// Deterministic backoff before relaunch `relaunch` (1-based) of
    /// `shard`: `min(base * 2^(relaunch-1), cap)` plus a jittered
    /// fraction in `[0, 25%)` keyed on (jitter_seed, shard, relaunch).
    pub fn backoff(&self, shard: usize, relaunch: u32) -> Duration {
        if self.backoff_base.is_zero() {
            return Duration::ZERO;
        }
        let exp = relaunch.saturating_sub(1).min(16);
        let base = self
            .backoff_base
            .saturating_mul(1u32 << exp)
            .min(self.backoff_cap);
        let mut h = util::fnv1a_64(&self.jitter_seed.to_le_bytes());
        h = util::fnv1a_64_update(h, &(shard as u64).to_le_bytes());
        h = util::fnv1a_64_update(h, &relaunch.to_le_bytes());
        let frac = (h % 1000) as f64 / 4000.0;
        base + base.mul_f64(frac)
    }
}

/// Supervision knobs (see [`crate::config::LaunchConfig`] for the
/// serialisable source of these values).
#[derive(Clone, Debug, Default)]
pub struct SuperviseOptions {
    /// Kill a shard whose checkpoint has not changed for this long.
    /// The heartbeat ticks once per completed trace cell, so this
    /// must exceed the slowest cell's runtime; as a guard against a
    /// deterministic kill-retry livelock when it doesn't, the
    /// effective timeout doubles on each relaunch of a shard.
    pub stall_timeout: Duration,
    /// How often to poll child exits and heartbeats.
    pub poll_interval: Duration,
    /// The relaunch policy.
    pub policy: RetryPolicy,
    /// Scripted chaos to execute during supervision (kill storms,
    /// checkpoint corruption, slow spawns). IO fault specs are armed
    /// by `launch`, not here.
    pub fault_plan: Option<FaultPlan>,
}

/// What happened to a shard, as told to the event callback.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardEventKind {
    /// A child process started (attempt 1 = initial spawn).
    Spawned { pid: u32, attempt: u32 },
    /// The shard's checkpoint changed size.
    Progress { checkpoint_bytes: u64 },
    /// The chaos drill killed this shard's child.
    ChaosKilled { pid: u32 },
    /// The chaos drill damaged this shard's checkpoint file.
    ChaosCorrupted { mode: String, bytes: u64 },
    /// No checkpoint change for longer than the stall timeout; the
    /// child was killed and is eligible for relaunch.
    Stalled { idle_ms: u64 },
    /// The child exited unsuccessfully.
    Crashed { exit_code: Option<i32> },
    /// A relaunch was scheduled after a backoff delay.
    Backoff { delay_ms: u64 },
    /// The child exited successfully.
    Completed,
    /// The supervisor stopped trying (a retry budget exhausted, or a
    /// relaunch failed to spawn — the reason says which). The merge
    /// catch-up will re-run this shard's missing scenarios
    /// in-process.
    GaveUp { reason: String },
    /// The shard's checkpoint was renamed aside
    /// ([`QUARANTINE_SUFFIX`]) after it gave up without progress; its
    /// planned cells will be redistributed through merge catch-up.
    Quarantined { reason: String },
    /// A host's lease expired: the whole machine is declared lost.
    /// Emitted once per lost host (the shard index is the first shard
    /// that was assigned to it, or 0 if it owned none).
    HostLost { host: String },
    /// This shard was moved off a lost host onto a survivor; a
    /// relaunch there follows under the normal retry budget.
    Reassigned { from_host: String, to_host: String },
}

impl ShardEventKind {
    /// Stable event-type tag for the campaign event log
    /// ([`crate::obs`]) — `memfine events --type shard_crashed` and
    /// friends filter on these names.
    pub fn tag(&self) -> &'static str {
        match self {
            ShardEventKind::Spawned { .. } => "shard_spawned",
            ShardEventKind::Progress { .. } => "shard_progress",
            ShardEventKind::ChaosKilled { .. } => "shard_chaos_killed",
            ShardEventKind::ChaosCorrupted { .. } => "shard_chaos_corrupted",
            ShardEventKind::Stalled { .. } => "shard_stalled",
            ShardEventKind::Crashed { .. } => "shard_crashed",
            ShardEventKind::Backoff { .. } => "shard_backoff",
            ShardEventKind::Completed => "shard_completed",
            ShardEventKind::GaveUp { .. } => "shard_gave_up",
            ShardEventKind::Quarantined { .. } => "shard_quarantined",
            ShardEventKind::HostLost { .. } => "shard_host_lost",
            ShardEventKind::Reassigned { .. } => "shard_reassigned",
        }
    }
}

/// One supervision event, tagged by shard index.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEvent {
    pub shard: usize,
    pub kind: ShardEventKind,
}

/// Per-shard summary of a supervision run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardOutcome {
    pub shard: usize,
    /// Child processes launched (1 = clean first run).
    pub spawns: u32,
    /// Stall kills.
    pub stalls: u32,
    /// Unsuccessful exits (not counting stall/chaos kills).
    pub crashes: u32,
    /// Injected chaos kills.
    pub chaos_kills: u32,
    /// Whether some attempt exited successfully.
    pub completed: bool,
    /// Whether the shard's checkpoint was quarantined aside.
    pub quarantined: bool,
    /// Exit code of the last observed exit (`None` after a kill).
    pub last_exit_code: Option<i32>,
}

struct ShardState {
    child: Option<Child>,
    monitor: HeartbeatMonitor,
    /// Relaunches consumed in the current failure episode; reset to 0
    /// on observed checkpoint progress.
    episode_retries_used: u32,
    /// Deferred relaunch deadline (exponential backoff).
    respawn_at: Option<Instant>,
    /// The shard's host went dark (chaos-killed or lease paused) and
    /// it must not respawn until the lease expires and the supervisor
    /// reassigns it to a survivor.
    awaiting_host: bool,
    outcome: ShardOutcome,
}

fn kill_and_reap(mut child: Child) {
    // kill on an already-exited child errors; either way wait() reaps
    let _ = child.kill();
    let _ = child.wait();
}

fn spawn_into<E>(
    shard: usize,
    plan: &ShardPlan,
    st: &mut ShardState,
    pool: &mut HostPool<'_>,
    on_event: &mut E,
) -> Result<()>
where
    E: FnMut(&ShardEvent),
{
    let attempt = st.outcome.spawns + 1;
    let child = pool.spawn(shard, plan, attempt)?;
    st.outcome.spawns = attempt;
    st.monitor.reset(Instant::now());
    on_event(&ShardEvent {
        shard,
        kind: ShardEventKind::Spawned { pid: child.id(), attempt },
    });
    st.child = Some(child);
    Ok(())
}

/// The quarantine destination for a shard checkpoint:
/// `shard-i-of-n.jsonl` → `shard-i-of-n.jsonl.quarantined`.
pub fn quarantine_path(checkpoint: &std::path::Path) -> PathBuf {
    let mut name = checkpoint.as_os_str().to_os_string();
    name.push(QUARANTINE_SUFFIX);
    PathBuf::from(name)
}

/// Report a shard as given up; when `quarantine_eligible` (episode
/// budget exhausted — the shard failed repeatedly *without* progress)
/// and the policy allows it, rename its checkpoint aside so merge
/// catch-up redistributes the cells.
fn give_up<E>(
    shard: usize,
    plan: &ShardPlan,
    st: &mut ShardState,
    policy: &RetryPolicy,
    reason: String,
    quarantine_eligible: bool,
    on_event: &mut E,
) where
    E: FnMut(&ShardEvent),
{
    on_event(&ShardEvent {
        shard,
        kind: ShardEventKind::GaveUp { reason: reason.clone() },
    });
    if !(quarantine_eligible && policy.quarantine) || !plan.checkpoint.exists() {
        return;
    }
    let aside = quarantine_path(&plan.checkpoint);
    match std::fs::rename(&plan.checkpoint, &aside) {
        Ok(()) => {
            st.outcome.quarantined = true;
            on_event(&ShardEvent {
                shard,
                kind: ShardEventKind::Quarantined { reason },
            });
        }
        Err(e) => logging::warn(
            "orchestrator",
            format!(
                "failed to quarantine {}: {e}; merge will read it as-is",
                plan.checkpoint.display()
            ),
        ),
    }
}

/// Consume budget and schedule the relaunch of a failed shard, or
/// give up (and possibly quarantine) when a budget is exhausted.
fn schedule_respawn<E>(
    shard: usize,
    plan: &ShardPlan,
    st: &mut ShardState,
    policy: &RetryPolicy,
    campaign_relaunches: &mut u32,
    on_event: &mut E,
) where
    E: FnMut(&ShardEvent),
{
    if st.episode_retries_used >= policy.episode_retries {
        let reason = format!(
            "episode retry budget exhausted ({} relaunches without checkpoint progress)",
            policy.episode_retries
        );
        give_up(shard, plan, st, policy, reason, true, on_event);
        return;
    }
    if policy.campaign_retries > 0 && *campaign_relaunches >= policy.campaign_retries {
        let reason = format!(
            "campaign failure budget exhausted ({} relaunches fleet-wide)",
            policy.campaign_retries
        );
        give_up(shard, plan, st, policy, reason, false, on_event);
        return;
    }
    st.episode_retries_used += 1;
    *campaign_relaunches += 1;
    let delay = policy.backoff(shard, st.outcome.spawns);
    if !delay.is_zero() {
        on_event(&ShardEvent {
            shard,
            kind: ShardEventKind::Backoff { delay_ms: delay.as_millis() as u64 },
        });
    }
    st.respawn_at = Some(Instant::now() + delay);
}

/// Run the fleet to completion: spawn every shard, poll exits and
/// heartbeats, heal crashes/stalls under the retry policy, execute any
/// scripted chaos, and return one [`ShardOutcome`] per shard. A shard
/// that exhausts a budget is reported (`completed: false`, possibly
/// `quarantined`) rather than failing the call — the merge layer
/// decides whether the launch can still be healed. Only a *first*
/// spawn failure is fatal (a broken binary/config would fail every
/// shard identically); on that path all already-spawned children are
/// killed before returning.
pub fn supervise<S, E>(
    shards: &[ShardPlan],
    spawn: S,
    opts: &SuperviseOptions,
    on_event: E,
) -> Result<Vec<ShardOutcome>>
where
    S: FnMut(&ShardPlan, u32) -> Result<Child>,
    E: FnMut(&ShardEvent),
{
    let mut pool = HostPool::single_local(Box::new(spawn));
    supervise_fleet(shards, &mut pool, opts, on_event)
}

/// [`supervise`], generalised over a [`HostPool`]: shards spawn on
/// their assigned hosts, the pool's lease plane (if installed via
/// [`HostPool::with_leases`]) is ticked every poll, and an expired
/// lease is handled as whole-host loss — one `HostLost` event, then
/// per shard a `Reassigned` event and a relaunch on a survivor under
/// the normal retry budget (or `GaveUp` when no host survives). A
/// single-host pool without leases behaves exactly like the legacy
/// seam.
pub fn supervise_fleet<E>(
    shards: &[ShardPlan],
    pool: &mut HostPool<'_>,
    opts: &SuperviseOptions,
    mut on_event: E,
) -> Result<Vec<ShardOutcome>>
where
    E: FnMut(&ShardEvent),
{
    let now = Instant::now();
    pool.init_assignment(shards.len());
    let mut states: Vec<ShardState> = (0..shards.len())
        .map(|i| ShardState {
            child: None,
            monitor: HeartbeatMonitor::new(now),
            episode_retries_used: 0,
            respawn_at: None,
            awaiting_host: false,
            outcome: ShardOutcome {
                shard: i,
                spawns: 0,
                stalls: 0,
                crashes: 0,
                chaos_kills: 0,
                completed: false,
                quarantined: false,
                last_exit_code: None,
            },
        })
        .collect();

    let plan = opts.fault_plan.clone().unwrap_or_default();
    let mut pending_kills: Vec<KillSpec> = plan.kills.clone();
    let mut pending_corrupt: Vec<CorruptSpec> = plan.corrupt.clone();
    let mut pending_host_loss: Vec<HostLossSpec> = plan.host_loss.clone();
    // hosts a chaos spec has silenced but the lease plane has not yet
    // declared lost: they keep the poll loop alive, so a drill can
    // never terminate with its loss half-executed
    let mut chaos_pending_hosts: std::collections::BTreeSet<usize> =
        std::collections::BTreeSet::new();

    for i in 0..states.len() {
        if let Some(slow) = plan.slow.iter().find(|s| s.shard % shards.len() == i) {
            // a simulated slow host: the shard's first spawn lags the
            // rest of the fleet
            std::thread::sleep(Duration::from_millis(slow.delay_ms));
        }
        if let Err(e) =
            spawn_into(i, &shards[i], &mut states[i], pool, &mut on_event)
        {
            for st in states.iter_mut() {
                if let Some(child) = st.child.take() {
                    kill_and_reap(child);
                }
            }
            return Err(e);
        }
    }

    let mut campaign_relaunches: u32 = 0;
    let mut polls: u64 = 0;
    loop {
        polls += 1;

        // deferred (backed-off) relaunches whose deadline has passed
        for i in 0..states.len() {
            let due = states[i]
                .respawn_at
                .is_some_and(|at| Instant::now() >= at);
            if !due {
                continue;
            }
            // never respawn onto a host that is dark or already lost:
            // park the shard until the lease plane reassigns it
            let host = pool.host_of(i);
            if chaos_pending_hosts.contains(&host) || pool.is_lost(host) {
                states[i].respawn_at = None;
                states[i].awaiting_host = true;
                continue;
            }
            states[i].respawn_at = None;
            if let Err(e) =
                spawn_into(i, &shards[i], &mut states[i], pool, &mut on_event)
            {
                on_event(&ShardEvent {
                    shard: i,
                    kind: ShardEventKind::GaveUp {
                        reason: format!("relaunch failed to spawn: {e}"),
                    },
                });
            }
        }

        for i in 0..states.len() {
            let st = &mut states[i];
            let Some(child) = st.child.as_mut() else { continue };
            let mut respawn = false;
            match child.try_wait() {
                Ok(Some(status)) => {
                    st.child = None;
                    st.outcome.last_exit_code = status.code();
                    if status.success() {
                        st.outcome.completed = true;
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Completed,
                        });
                    } else {
                        st.outcome.crashes += 1;
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Crashed { exit_code: status.code() },
                        });
                        respawn = true;
                    }
                }
                Ok(None) => {
                    let now = Instant::now();
                    let len = probe_len(&shards[i].checkpoint);
                    // escalate per relaunch: a cell that is slower
                    // than the configured timeout (rather than a
                    // wedged child) eventually gets room to finish
                    // instead of being killed identically forever
                    let timeout = opts.stall_timeout
                        * (1u32 << (st.outcome.spawns.saturating_sub(1)).min(6));
                    if st.monitor.observe(len, now) {
                        // fresh checkpoint progress closes the current
                        // failure episode: the relaunch budget resets
                        st.episode_retries_used = 0;
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Progress {
                                checkpoint_bytes: len.unwrap_or(0),
                            },
                        });
                    } else if st.monitor.stalled(timeout, now) {
                        let idle_ms = st.monitor.idle(now).as_millis() as u64;
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Stalled { idle_ms },
                        });
                        if let Some(child) = st.child.take() {
                            kill_and_reap(child);
                        }
                        st.outcome.stalls += 1;
                        st.outcome.last_exit_code = None;
                        respawn = true;
                    }
                }
                Err(_) => {
                    // the OS lost track of the child; reclaim and
                    // treat it as a crash
                    if let Some(child) = st.child.take() {
                        kill_and_reap(child);
                    }
                    st.outcome.crashes += 1;
                    st.outcome.last_exit_code = None;
                    on_event(&ShardEvent {
                        shard: i,
                        kind: ShardEventKind::Crashed { exit_code: None },
                    });
                    respawn = true;
                }
            }
            if respawn {
                schedule_respawn(
                    i,
                    &shards[i],
                    &mut states[i],
                    &opts.policy,
                    &mut campaign_relaunches,
                    &mut on_event,
                );
            }
        }

        // Scripted kills: at most one strike per poll. A spec with an
        // explicit shard waits for that shard to be running; a
        // heuristic spec (shard: None) prefers the first still-running
        // shard with demonstrable checkpoint progress (a true
        // mid-flight kill), falling back to any running child once a
        // few polls have elapsed, so the drill cannot silently no-op
        // on fast grids. Relaunch is unconditional and immediate — an
        // injected fault must not consume the shard's retry budget.
        if let Some(k) = pending_kills
            .iter()
            .position(|k| polls >= k.at_poll)
        {
            let spec = pending_kills[k].clone();
            let target = match spec.shard {
                Some(s) => {
                    let i = s % states.len();
                    states[i].child.is_some().then_some(i)
                }
                None => (0..states.len())
                    .find(|&i| {
                        states[i].child.is_some()
                            && states[i].monitor.last_len().unwrap_or(0) > 0
                    })
                    .or_else(|| {
                        if polls >= spec.at_poll.max(3) {
                            (0..states.len()).find(|&i| states[i].child.is_some())
                        } else {
                            None
                        }
                    }),
            };
            if let Some(i) = target {
                let st = &mut states[i];
                // a candidate that exited between polls is no strike:
                // leave the spec pending and let the normal exit path
                // reap it next iteration
                let still_running = matches!(
                    st.child.as_mut().expect("target is running").try_wait(),
                    Ok(None)
                );
                if still_running {
                    let child = st.child.take().expect("target is running");
                    let pid = child.id();
                    kill_and_reap(child);
                    st.outcome.chaos_kills += 1;
                    st.outcome.last_exit_code = None;
                    on_event(&ShardEvent {
                        shard: i,
                        kind: ShardEventKind::ChaosKilled { pid },
                    });
                    if let Err(e) =
                        spawn_into(i, &shards[i], st, pool, &mut on_event)
                    {
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::GaveUp {
                                reason: format!("relaunch failed to spawn: {e}"),
                            },
                        });
                    }
                    pending_kills.remove(k);
                }
            }
        }

        // Scripted checkpoint corruption: a spec stays pending until
        // its shard's checkpoint has enough content to damage.
        let mut c = 0;
        while c < pending_corrupt.len() {
            let spec = pending_corrupt[c].clone();
            if polls < spec.at_poll {
                c += 1;
                continue;
            }
            let i = spec.shard % shards.len();
            let applied = match spec.mode {
                CorruptMode::MiddleRecord => {
                    chaos::corrupt_middle_record(&shards[i].checkpoint)
                }
                CorruptMode::TruncateTail { bytes } => {
                    chaos::truncate_tail(&shards[i].checkpoint, bytes)
                }
            };
            match applied {
                Ok(Some(bytes)) => {
                    on_event(&ShardEvent {
                        shard: i,
                        kind: ShardEventKind::ChaosCorrupted {
                            mode: spec.mode.tag().to_string(),
                            bytes,
                        },
                    });
                    pending_corrupt.remove(c);
                }
                Ok(None) => c += 1, // not enough content yet
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => c += 1,
                Err(e) => {
                    logging::warn(
                        "chaos",
                        format!(
                            "corrupt spec for {} failed ({e}); dropping it",
                            shards[i].checkpoint.display()
                        ),
                    );
                    pending_corrupt.remove(c);
                }
            }
        }

        // Scripted host loss: kill every child on the target host and
        // silence its lease. The shards are parked (`awaiting_host`)
        // rather than respawned — exactly like a real machine loss,
        // nothing moves until the lease expires below.
        let mut hl = 0;
        while hl < pending_host_loss.len() {
            let spec = pending_host_loss[hl].clone();
            if polls < spec.at_poll {
                hl += 1;
                continue;
            }
            if !pool.has_leases() {
                logging::warn(
                    "chaos",
                    "host_loss spec ignored: no lease plane \
                     (single-host launch cannot declare a host lost)",
                );
                pending_host_loss.remove(hl);
                continue;
            }
            let host = spec.host % pool.n_hosts();
            if pool.is_lost(host) || chaos_pending_hosts.contains(&host) {
                pending_host_loss.remove(hl);
                continue;
            }
            for i in 0..states.len() {
                if pool.host_of(i) != host {
                    continue;
                }
                let st = &mut states[i];
                let running = st
                    .child
                    .as_mut()
                    .map(|c| matches!(c.try_wait(), Ok(None)))
                    .unwrap_or(false);
                if running {
                    let child = st.child.take().expect("checked running");
                    let pid = child.id();
                    kill_and_reap(child);
                    st.outcome.chaos_kills += 1;
                    st.outcome.last_exit_code = None;
                    st.awaiting_host = true;
                    on_event(&ShardEvent {
                        shard: i,
                        kind: ShardEventKind::ChaosKilled { pid },
                    });
                } else if st.respawn_at.is_some() {
                    st.respawn_at = None;
                    st.awaiting_host = true;
                }
            }
            pool.pause_lease(host);
            chaos_pending_hosts.insert(host);
            pending_host_loss.remove(hl);
        }

        // Lease plane: renew our own hosts' leases, observe everyone's,
        // and treat an expiry as whole-host loss — reassign the dead
        // host's unfinished shards to survivors under the normal retry
        // budget; merge catch-up heals anything nobody re-runs.
        for host in pool.tick(Instant::now()) {
            chaos_pending_hosts.remove(&host);
            let host_id = pool.host_id(host).to_string();
            let anchor = (0..states.len())
                .find(|&i| pool.host_of(i) == host)
                .unwrap_or(0);
            on_event(&ShardEvent {
                shard: anchor,
                kind: ShardEventKind::HostLost { host: host_id.clone() },
            });
            for i in 0..states.len() {
                if pool.host_of(i) != host {
                    continue;
                }
                let st = &mut states[i];
                let active =
                    st.child.is_some() || st.respawn_at.is_some() || st.awaiting_host;
                if !active {
                    continue; // completed or already given up
                }
                if let Some(child) = st.child.take() {
                    kill_and_reap(child);
                    st.outcome.last_exit_code = None;
                }
                st.respawn_at = None;
                st.awaiting_host = false;
                match pool.reassign(i) {
                    Some(to) => {
                        let to_id = pool.host_id(to).to_string();
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Reassigned {
                                from_host: host_id.clone(),
                                to_host: to_id,
                            },
                        });
                        schedule_respawn(
                            i,
                            &shards[i],
                            st,
                            &opts.policy,
                            &mut campaign_relaunches,
                            &mut on_event,
                        );
                    }
                    None => give_up(
                        i,
                        &shards[i],
                        st,
                        &opts.policy,
                        format!("host {host_id} lost with no surviving hosts"),
                        false,
                        &mut on_event,
                    ),
                }
            }
        }

        if chaos_pending_hosts.is_empty()
            && states.iter().all(|s| {
                s.child.is_none() && s.respawn_at.is_none() && !s.awaiting_host
            })
        {
            break;
        }
        std::thread::sleep(opts.poll_interval);
    }

    if !pending_kills.is_empty()
        || !pending_corrupt.is_empty()
        || !pending_host_loss.is_empty()
    {
        logging::warn(
            "chaos",
            format!(
                "fleet finished with {} kill, {} corrupt and {} host-loss \
                 spec(s) still pending (the drill outran the work)",
                pending_kills.len(),
                pending_corrupt.len(),
                pending_host_loss.len()
            ),
        );
    }

    Ok(states.into_iter().map(|s| s.outcome).collect())
}

#[cfg(test)]
#[cfg(unix)]
mod tests {
    use super::*;
    use crate::config::ShardSpec;
    use std::path::PathBuf;
    use std::process::{Command, Stdio};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-supervise-{}-{name}", std::process::id()));
        p
    }

    fn one_shard(name: &str) -> Vec<ShardPlan> {
        vec![ShardPlan {
            index: 0,
            count: 1,
            spec: ShardSpec { index: 0, count: 1 },
            checkpoint: tmp(&format!("{name}.jsonl")),
            log: tmp(&format!("{name}.log")),
            cells: 1,
            scenarios: 1,
        }]
    }

    fn sh(script: String) -> Result<Child> {
        Command::new("sh")
            .arg("-c")
            .arg(script)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .map_err(crate::Error::Io)
    }

    fn fast_opts() -> SuperviseOptions {
        SuperviseOptions {
            stall_timeout: Duration::from_millis(400),
            poll_interval: Duration::from_millis(20),
            policy: RetryPolicy {
                episode_retries: 2,
                campaign_retries: 0,
                backoff_base: Duration::ZERO,
                backoff_cap: Duration::ZERO,
                jitter_seed: 0,
                quarantine: false,
            },
            fault_plan: None,
        }
    }

    #[test]
    fn event_kind_tags_are_distinct_shard_names() {
        let kinds = [
            ShardEventKind::Spawned { pid: 1, attempt: 1 },
            ShardEventKind::Progress { checkpoint_bytes: 0 },
            ShardEventKind::ChaosKilled { pid: 1 },
            ShardEventKind::ChaosCorrupted { mode: String::new(), bytes: 0 },
            ShardEventKind::Stalled { idle_ms: 0 },
            ShardEventKind::Crashed { exit_code: None },
            ShardEventKind::Backoff { delay_ms: 0 },
            ShardEventKind::Completed,
            ShardEventKind::GaveUp { reason: String::new() },
            ShardEventKind::Quarantined { reason: String::new() },
            ShardEventKind::HostLost { host: String::new() },
            ShardEventKind::Reassigned {
                from_host: String::new(),
                to_host: String::new(),
            },
        ];
        let tags: std::collections::BTreeSet<_> =
            kinds.iter().map(|k| k.tag()).collect();
        assert_eq!(tags.len(), kinds.len());
        assert!(tags.iter().all(|t| t.starts_with("shard_")));
    }

    #[test]
    fn deterministic_backoff_doubles_and_caps() {
        let policy = RetryPolicy {
            backoff_base: Duration::from_millis(100),
            backoff_cap: Duration::from_secs(1),
            jitter_seed: 7,
            ..RetryPolicy::default()
        };
        // deterministic: same inputs, same delay
        assert_eq!(policy.backoff(0, 1), policy.backoff(0, 1));
        // jitter separates shards and attempts (with this seed)
        assert_ne!(policy.backoff(0, 1), policy.backoff(1, 1));
        for k in 1..=12u32 {
            let d = policy.backoff(0, k);
            let un_jittered = Duration::from_millis(100)
                .saturating_mul(1 << (k - 1).min(16))
                .min(Duration::from_secs(1));
            assert!(d >= un_jittered, "jitter only adds: {d:?} < {un_jittered:?}");
            assert!(
                d <= un_jittered.mul_f64(1.25),
                "jitter bounded by 25%: {d:?}"
            );
        }
        // zero base disables backoff
        let off = RetryPolicy { backoff_base: Duration::ZERO, ..policy };
        assert_eq!(off.backoff(0, 5), Duration::ZERO);
    }

    #[test]
    fn clean_child_completes_first_spawn() {
        let shards = one_shard("clean");
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, _| sh(format!("printf line >> {}", plan.checkpoint.display())),
            &fast_opts(),
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 1);
        assert_eq!(outcomes[0].crashes + outcomes[0].stalls, 0);
        assert_eq!(outcomes[0].last_exit_code, Some(0));
        assert!(events
            .iter()
            .any(|e| e.kind == ShardEventKind::Completed));
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn crash_is_retried_until_budget_exhausts() {
        let shards = one_shard("crashy");
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |_, _| sh("exit 3".into()),
            &fast_opts(),
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        // initial spawn + episode_retries relaunches, then give up —
        // the crashes never touch the checkpoint, so the episode
        // budget never resets
        assert!(!outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 3);
        assert_eq!(outcomes[0].crashes, 3);
        assert_eq!(outcomes[0].last_exit_code, Some(3));
        assert!(events
            .iter()
            .any(|e| matches!(&e.kind, ShardEventKind::GaveUp { reason }
                if reason.contains("retry budget exhausted"))));
    }

    #[test]
    fn episode_budget_resets_on_observed_progress() {
        // The fix for the lifetime-counter bug pinned by the previous
        // revision of this test: a shard that shows fresh checkpoint
        // progress before every crash opens a new failure episode each
        // time, so it heals even though its total relaunch count far
        // exceeds episode_retries.
        let shards = one_shard("episodes");
        std::fs::remove_file(&shards[0].checkpoint).ok();
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, attempt| {
                if attempt <= 4 {
                    // append (observable progress), linger long enough
                    // for the supervisor to see it, then die
                    sh(format!(
                        "printf line >> {}; sleep 0.3; exit 1",
                        plan.checkpoint.display()
                    ))
                } else {
                    sh(format!("printf line >> {}", plan.checkpoint.display()))
                }
            },
            &fast_opts(),
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert!(
            events
                .iter()
                .any(|e| matches!(e.kind, ShardEventKind::Progress { .. })),
            "progress must have been observed between crashes"
        );
        assert!(
            outcomes[0].completed,
            "4 healing episodes must outlive an episode budget of 2"
        );
        assert_eq!(outcomes[0].spawns, 5);
        assert_eq!(outcomes[0].crashes, 4);
        assert!(!outcomes[0].quarantined);
        assert!(!events
            .iter()
            .any(|e| matches!(e.kind, ShardEventKind::GaveUp { .. })));
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn campaign_budget_bounds_a_progressing_crash_loop() {
        // The backstop for the pathological flip side of episode
        // resets: a crash loop that appends bytes on every attempt
        // resets its episode budget forever, so only the fleet-wide
        // campaign budget can stop it.
        let shards = one_shard("campaign");
        std::fs::remove_file(&shards[0].checkpoint).ok();
        let mut opts = fast_opts();
        opts.policy.campaign_retries = 3;
        opts.policy.quarantine = true;
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, _| {
                sh(format!(
                    "printf line >> {}; sleep 0.3; exit 1",
                    plan.checkpoint.display()
                ))
            },
            &opts,
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert!(!outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 4, "initial + campaign_retries");
        assert!(events
            .iter()
            .any(|e| matches!(&e.kind, ShardEventKind::GaveUp { reason }
                if reason.contains("campaign failure budget"))));
        // campaign exhaustion is not the shard's fault: its checkpoint
        // (with real records) is NOT quarantined
        assert!(!outcomes[0].quarantined);
        assert!(shards[0].checkpoint.exists());
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn exhausted_shard_checkpoint_is_quarantined_aside() {
        let shards = one_shard("quarantine");
        let aside = quarantine_path(&shards[0].checkpoint);
        std::fs::remove_file(&shards[0].checkpoint).ok();
        std::fs::remove_file(&aside).ok();
        let mut opts = fast_opts();
        opts.policy.quarantine = true;
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, attempt| {
                if attempt == 1 {
                    // write once so there is a file to quarantine
                    sh(format!(
                        "printf garbage >> {}; sleep 0.3; exit 1",
                        plan.checkpoint.display()
                    ))
                } else {
                    // then fail instantly, without progress
                    sh("exit 1".into())
                }
            },
            &opts,
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert!(!outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 3);
        assert!(outcomes[0].quarantined);
        assert!(!shards[0].checkpoint.exists(), "checkpoint renamed aside");
        assert!(aside.exists());
        assert_eq!(
            aside.extension().and_then(|e| e.to_str()),
            Some("quarantined"),
            "the rename must leave the campaign-state globs (*.jsonl) blind to it"
        );
        assert!(events
            .iter()
            .any(|e| matches!(&e.kind, ShardEventKind::Quarantined { reason }
                if reason.contains("episode retry budget"))));
        std::fs::remove_file(&aside).ok();
    }

    #[test]
    fn backoff_defers_relaunch_and_is_reported() {
        let shards = one_shard("backoff");
        std::fs::remove_file(&shards[0].checkpoint).ok();
        let mut opts = fast_opts();
        opts.policy.backoff_base = Duration::from_millis(60);
        opts.policy.backoff_cap = Duration::from_millis(500);
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, attempt| {
                if attempt == 1 {
                    sh("exit 1".into())
                } else {
                    sh(format!("printf line >> {}", plan.checkpoint.display()))
                }
            },
            &opts,
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert!(outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 2);
        let backoff_pos = events
            .iter()
            .position(|e| matches!(&e.kind, ShardEventKind::Backoff { delay_ms } if *delay_ms >= 60))
            .expect("a backoff event with the base delay");
        let respawn_pos = events
            .iter()
            .position(|e| matches!(&e.kind, ShardEventKind::Spawned { attempt, .. } if *attempt == 2))
            .expect("the deferred relaunch");
        assert!(backoff_pos < respawn_pos);
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn crash_then_success_heals_within_budget() {
        let shards = one_shard("flaky");
        let outcomes = supervise(
            &shards,
            |plan, attempt| {
                if attempt == 1 {
                    sh("exit 1".into())
                } else {
                    sh(format!("printf line >> {}", plan.checkpoint.display()))
                }
            },
            &fast_opts(),
            |_| {},
        )
        .unwrap();
        assert!(outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 2);
        assert_eq!(outcomes[0].crashes, 1);
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn stalled_child_is_killed_and_relaunched() {
        let shards = one_shard("wedged");
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, attempt| {
                if attempt == 1 {
                    // wedge without ever touching the checkpoint
                    sh("sleep 30".into())
                } else {
                    sh(format!("printf line >> {}", plan.checkpoint.display()))
                }
            },
            &fast_opts(),
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert!(outcomes[0].completed);
        assert_eq!(outcomes[0].stalls, 1);
        assert_eq!(outcomes[0].spawns, 2);
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, ShardEventKind::Stalled { .. })));
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn chaos_kills_a_progressing_child_once_and_heals() {
        let shards = one_shard("chaos");
        std::fs::remove_file(&shards[0].checkpoint).ok();
        let opts = SuperviseOptions {
            stall_timeout: Duration::from_secs(30),
            fault_plan: Some(FaultPlan::kill_one()),
            ..fast_opts()
        };
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, _| {
                // write progress immediately, then linger long enough
                // for the supervisor to observe it and strike
                sh(format!(
                    "printf line >> {}; sleep 2",
                    plan.checkpoint.display()
                ))
            },
            &opts,
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert_eq!(outcomes[0].chaos_kills, 1);
        assert_eq!(outcomes[0].spawns, 2);
        // the relaunch ran the same script to completion
        assert!(outcomes[0].completed);
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, ShardEventKind::ChaosKilled { .. })));
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn scripted_corruption_damages_the_middle_record_in_flight() {
        let shards = one_shard("corrupt-live");
        std::fs::remove_file(&shards[0].checkpoint).ok();
        let opts = SuperviseOptions {
            fault_plan: Some(FaultPlan {
                corrupt: vec![CorruptSpec {
                    at_poll: 1,
                    shard: 0,
                    mode: CorruptMode::MiddleRecord,
                }],
                ..FaultPlan::default()
            }),
            ..fast_opts()
        };
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, _| {
                // three complete lines at once, then linger so the
                // corruption lands while the child is alive
                sh(format!(
                    "printf 'aaaa\\nbbbb\\ncccc\\n' >> {}; sleep 0.3",
                    plan.checkpoint.display()
                ))
            },
            &opts,
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert!(outcomes[0].completed);
        assert!(
            events.iter().any(|e| matches!(
                &e.kind,
                ShardEventKind::ChaosCorrupted { mode, bytes }
                    if mode == "middle" && *bytes == 4
            )),
            "{events:?}"
        );
        let data = std::fs::read(&shards[0].checkpoint).unwrap();
        assert_eq!(&data[..], b"aaaa\nxxxx\ncccc\n");
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn whole_host_loss_reassigns_shards_to_the_survivor() {
        use crate::orchestrator::host::{HostKind, HostPool, HostSlot, HostSpec};
        let dir = tmp("fleet-drill-dir");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mut shards = one_shard("fleet-0");
        shards.push(ShardPlan {
            index: 1,
            count: 2,
            spec: ShardSpec { index: 1, count: 2 },
            checkpoint: tmp("fleet-1.jsonl"),
            log: tmp("fleet-1.log"),
            cells: 1,
            scenarios: 1,
        });
        for s in &shards {
            std::fs::remove_file(&s.checkpoint).ok();
        }
        // h0 writes the checkpoint and exits clean; h1 wedges forever
        // — so shard 1 can only ever finish after it is reassigned
        let slot = |id: &str, healthy: bool| {
            HostSlot::new(
                HostSpec { id: id.into(), kind: HostKind::Local },
                Box::new(move |plan: &ShardPlan, _| {
                    if healthy {
                        sh(format!("printf line >> {}", plan.checkpoint.display()))
                    } else {
                        sh("sleep 30".into())
                    }
                }),
            )
        };
        let mut pool =
            HostPool::new(vec![slot("h0", true), slot("h1", false)]).unwrap();
        pool.with_leases(&dir, Duration::from_millis(240), Instant::now())
            .unwrap();
        let opts = SuperviseOptions {
            stall_timeout: Duration::from_secs(30),
            fault_plan: Some(FaultPlan {
                host_loss: vec![chaos::HostLossSpec { at_poll: 1, host: 1 }],
                ..FaultPlan::default()
            }),
            ..fast_opts()
        };
        let mut events = Vec::new();
        let outcomes =
            supervise_fleet(&shards, &mut pool, &opts, |ev| events.push(ev.clone()))
                .unwrap();
        assert!(outcomes[0].completed, "h0's shard is untouched");
        assert_eq!(outcomes[0].spawns, 1);
        assert!(
            outcomes[1].completed,
            "shard 1 must heal on the survivor: {events:?}"
        );
        assert_eq!(outcomes[1].spawns, 2);
        assert_eq!(outcomes[1].chaos_kills, 1);
        let lost: Vec<_> = events
            .iter()
            .filter_map(|e| match &e.kind {
                ShardEventKind::HostLost { host } => Some(host.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(lost, vec!["h1".to_string()], "exactly one loss, of h1");
        assert!(
            events.iter().any(|e| matches!(&e.kind,
                ShardEventKind::Reassigned { from_host, to_host }
                    if from_host == "h1" && to_host == "h0" && e.shard == 1)),
            "{events:?}"
        );
        assert!(!events
            .iter()
            .any(|e| matches!(e.kind, ShardEventKind::GaveUp { .. })));
        for s in &shards {
            std::fs::remove_file(&s.checkpoint).ok();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn host_loss_without_a_lease_plane_is_dropped_loudly() {
        let shards = one_shard("no-lease-hostloss");
        std::fs::remove_file(&shards[0].checkpoint).ok();
        let opts = SuperviseOptions {
            fault_plan: Some(FaultPlan {
                host_loss: vec![chaos::HostLossSpec { at_poll: 1, host: 0 }],
                ..FaultPlan::default()
            }),
            ..fast_opts()
        };
        // the legacy single-host seam: the spec must not wedge the loop
        let outcomes = supervise(
            &shards,
            |plan, _| {
                sh(format!(
                    "printf line >> {}; sleep 0.2",
                    plan.checkpoint.display()
                ))
            },
            &opts,
            |_| {},
        )
        .unwrap();
        assert!(outcomes[0].completed);
        assert_eq!(outcomes[0].chaos_kills, 0);
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn first_spawn_failure_is_fatal_and_reaps_the_fleet() {
        let mut shards = one_shard("fatal-0");
        shards.push(ShardPlan {
            index: 1,
            count: 2,
            spec: ShardSpec { index: 1, count: 2 },
            checkpoint: tmp("fatal-1.jsonl"),
            log: tmp("fatal-1.log"),
            cells: 1,
            scenarios: 1,
        });
        let err = supervise(
            &shards,
            |plan, _| {
                if plan.index == 0 {
                    sh("sleep 30".into())
                } else {
                    Err(crate::Error::config("no such binary"))
                }
            },
            &fast_opts(),
            |_| {},
        );
        assert!(err.is_err());
    }
}
