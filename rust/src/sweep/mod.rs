//! Parallel scenario-sweep engine — the substrate behind every
//! table/figure grid in the reproduction.
//!
//! A sweep is the cross product `models × methods × seeds` from a
//! [`SweepConfig`], expanded into ordered [`grid::Scenario`]s and
//! grouped into [`grid::TraceCell`]s — the (model, seed) cells whose
//! scenarios differ only in method. Each cell draws its routed-token
//! stream **once** ([`crate::trace::SharedRoutingTrace`]) and then
//! dispatches **one fused job** that walks the trace once and
//! evaluates every method simultaneously
//! ([`crate::sim::evaluate_cell`], memoised kernels, `RunSummary`
//! aggregates); the per-method pass
//! ([`crate::sim::run_scenario_on_trace`]) survives behind
//! [`SweepRunOptions::unfused`] as the A/B reference the fused path is
//! pinned byte-identical against. This is the paper's
//! paired-comparison structure, exploited for throughput. Workers
//! stream flat [`report::ScenarioResult`]s back as scenarios finish;
//! the [`report::SweepReducer`] folds them incrementally in grid-index
//! order (memory stays O(cells) of aggregate state plus the flat rows
//! the artifact carries — the heavyweight `RunOutcome`s die in the
//! workers), and the optional [`checkpoint`] layer appends each result
//! to a JSON-lines file keyed by scenario content hash, enabling
//! `--resume`, `--shard i/n` splits, and cross-host merges.
//!
//! **Determinism contract:** the report — including its serialised
//! bytes — depends only on the `SweepConfig` (and the opt-in
//! `fast_router` sampler choice). Worker count, thread scheduling,
//! shard splits, kill/resume points, and checkpoint merge order cannot
//! perturb it, because
//!
//! 1. every scenario derives its RNG streams purely from its own
//!    config/seed (no shared mutable state, nothing drawn from a
//!    global generator at execution time), and trace sharing only
//!    changes *when* a stream is drawn, never *what* is drawn —
//!    `run_scenario_on_trace` is pinned bit-identical to
//!    `run_scenario`;
//! 2. results are keyed by grid index and folded in ascending index
//!    order whatever their arrival order, so floats accumulate in one
//!    fixed order (see [`report::SweepReducer`]);
//! 3. scenario identity under resume is a content hash of the
//!    resolved run config ([`checkpoint::scenario_hash`]) — grid
//!    position and execution parameters never enter it;
//! 4. JSON objects serialise with sorted keys, and every number in a
//!    checkpoint round-trips bit-exactly.
//!
//! `tests/integration_sweep.rs` pins all of it: a 24-scenario grid run
//! with 1 worker, 8 workers, as two merged shards, and as a killed-
//! then-resumed sweep must emit bit-identical JSON.

pub mod checkpoint;
pub mod grid;
pub mod pool;
pub mod report;

pub use grid::{expand, expand_cells, Scenario, TraceCell};
pub use pool::{parallel_for_each_indexed, parallel_map_indexed};
pub use report::{CellStats, ScenarioResult, SweepReducer, SweepReport};

use std::path::PathBuf;

use crate::config::{ShardSpec, SweepConfig};
use crate::error::{Error, Result};
use crate::router::GatingSim;
use crate::sim;
use crate::trace::SharedRoutingTrace;

/// Default worker count: the machine's parallelism, capped so a small
/// grid doesn't spawn idle threads.
pub fn default_workers(scenarios: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1);
    hw.min(scenarios.max(1))
}

/// Execution parameters of one sweep invocation. Deliberately **not**
/// part of [`SweepConfig`]: the config is the grid's identity (it is
/// serialised into the artifact and hashed into checkpoints), while
/// everything here only decides *how* that grid gets executed — the
/// artifact bytes must come out identical for any choice of these
/// (`fast_router` excepted: it selects a different, equally valid
/// sample of the same routing distribution and is therefore part of
/// the scenario hash).
#[derive(Clone, Debug, Default)]
pub struct SweepRunOptions {
    /// Worker threads (0 = all cores, capped to the grid).
    pub workers: usize,
    /// Checkpoint files: the first is the append/write target, all are
    /// read on `resume` (pass several to merge shard files).
    pub checkpoint: Vec<PathBuf>,
    /// Skip scenarios whose content hash already appears in the
    /// checkpoint files, folding their stored results instead.
    pub resume: bool,
    /// Run only the trace cells this shard owns (round-robin by cell
    /// index, so no shard ever re-draws another shard's traces).
    pub shard: Option<ShardSpec>,
    /// Execute at most this many scenarios this invocation (budgeted
    /// runs; also how the tests simulate a killed sweep). Resumed
    /// results don't count against it.
    pub limit: Option<usize>,
    /// Draw routing traces with the binomial-splitting multinomial
    /// ([`crate::util::rng::Rng::multinomial_split`]) — same
    /// distribution, materially faster on peaky expert popularity,
    /// different bit-stream (so it participates in the scenario hash).
    pub fast_router: bool,
    /// Evaluate each of a cell's methods as its own pass over the
    /// shared trace ([`sim::run_scenario_on_trace`] per scenario) — the
    /// pre-fusion engine, kept as the A/B reference the fused default
    /// ([`sim::evaluate_cell`]) is pinned byte-identical against.
    /// Execution-only: artifacts never depend on this flag.
    pub unfused: bool,
}

/// What a sweep invocation did, plus the report it produced.
#[derive(Debug)]
pub struct SweepRunSummary {
    pub report: SweepReport,
    /// Scenarios in the full grid.
    pub total: usize,
    /// Scenarios satisfied from checkpoint files.
    pub resumed: usize,
    /// Scenarios executed by this invocation.
    pub executed: usize,
    /// Scenarios excluded by the shard split / `limit` (still missing
    /// from this invocation's report).
    pub skipped: usize,
    /// Unparseable checkpoint lines that were ignored (torn tail of a
    /// killed run).
    pub skipped_checkpoint_lines: usize,
}

/// One worker job: the still-to-run scenarios of a trace cell, with
/// their precomputed content hashes.
struct CellWork {
    todo: Vec<(String, grid::Scenario)>,
}

fn run_cell(
    work: CellWork,
    fast_router: bool,
    unfused: bool,
) -> Result<Vec<(String, ScenarioResult)>> {
    let first = &work.todo[0].1;
    // One trace per (model, seed) cell; every method below evaluates
    // against it. GatingSim only reads (model, parallel, seed), all of
    // which are method-independent within the cell.
    let gating = GatingSim::new(
        first.run.model.clone(),
        first.run.parallel.clone(),
        first.run.seed,
    )
    .with_fast_multinomial(fast_router);
    let trace = SharedRoutingTrace::generate(&gating, first.run.iterations);
    if unfused {
        // Pre-fusion A/B path: one full evaluation pass per method.
        return work
            .todo
            .into_iter()
            .map(|(hash, sc)| {
                debug_assert!(sc.run.method == sc.method && sc.run.seed == sc.seed);
                let out = sim::run_scenario_on_trace(&sc.run, sc.method.clone(), &trace)?;
                Ok((hash, ScenarioResult::new(&sc, &out)))
            })
            .collect();
    }
    // Fused default: one trace walk evaluates every still-to-run
    // method of the cell simultaneously (sim::evaluate_cell), returning
    // lightweight RunSummary aggregates — pinned byte-identical to the
    // per-method path above.
    let methods: Vec<_> = work.todo.iter().map(|(_, sc)| sc.method.clone()).collect();
    let outcomes = sim::evaluate_cell(&first.run, &methods, &trace)?;
    debug_assert_eq!(outcomes.len(), work.todo.len());
    Ok(work
        .todo
        .into_iter()
        .zip(outcomes)
        .map(|((hash, sc), out)| {
            debug_assert!(out.method == sc.method && sc.run.seed == sc.seed);
            (hash, ScenarioResult::from_summary(&sc, &out.summary))
        })
        .collect())
}

/// Run a sweep under the given execution options: resume from
/// checkpoints, apply the shard filter and scenario budget, execute
/// the remaining trace cells on the worker pool, stream results
/// through the reducer (checkpointing each as it lands), and finish
/// the report. See the module docs for the determinism contract.
pub fn run_sweep_with(cfg: &SweepConfig, opts: &SweepRunOptions) -> Result<SweepRunSummary> {
    let cells = grid::expand_cells(cfg)?;
    let total = cfg.scenario_count();

    if opts.resume && opts.checkpoint.is_empty() {
        return Err(Error::config("resume requires at least one checkpoint path"));
    }
    let done = if opts.resume {
        checkpoint::CheckpointSet::load(&opts.checkpoint)?
    } else {
        checkpoint::CheckpointSet::empty()
    };
    let mut writer = match opts.checkpoint.first() {
        None => checkpoint::CheckpointWriter::disabled(),
        Some(p) if opts.resume => checkpoint::CheckpointWriter::append(p)?,
        Some(p) => checkpoint::CheckpointWriter::create(p)?,
    };

    let mut reducer = SweepReducer::new(cfg.clone())?;
    let mut resumed = 0usize;
    let mut skipped = 0usize;
    let mut budget = opts.limit.unwrap_or(usize::MAX);
    let mut work: Vec<CellWork> = Vec::new();
    // Hashing serialises the full run envelope per scenario — only
    // worth it when a checkpoint will be read or written.
    let hashing = !opts.checkpoint.is_empty();
    for (cell_index, cell) in cells.into_iter().enumerate() {
        // Shard ownership is per trace *cell*, never per scenario: a
        // split cell would force every shard to re-draw the same
        // routing trace — the exact cost trace sharing removes. Cells
        // are homogeneous (each holds one scenario per method), so
        // round-robin over cells balances shards as well as scenario
        // striding did.
        let owned = match opts.shard {
            Some(s) => s.owns(cell_index),
            None => true,
        };
        let mut todo = Vec::new();
        for sc in cell.scenarios {
            // Resume must hash every scenario (other shards' rows fold
            // in regardless of ownership); a write-only checkpoint run
            // needs hashes only for the scenarios it will execute.
            let hash = if opts.resume || (hashing && owned) {
                checkpoint::scenario_hash(&sc.run, opts.fast_router)
            } else {
                String::new()
            };
            if let Some(prev) = done.get(&hash) {
                // hashes are grid-position-independent; re-key the
                // stored row into this grid's enumeration and re-label
                // it with this grid's spellings (a checkpoint written
                // from an aliased grid — model "1" vs "i" — hashes
                // identically but must not leak its labels into the
                // artifact)
                let mut row = prev.clone();
                row.index = sc.index;
                row.model = sc.model.clone();
                row.method = sc.method.name();
                row.seed = sc.seed;
                reducer.push(row);
                resumed += 1;
            } else if owned && budget > 0 {
                budget -= 1;
                todo.push((hash, sc));
            } else {
                skipped += 1;
            }
        }
        if !todo.is_empty() {
            work.push(CellWork { todo });
        }
    }
    let executed: usize = work.iter().map(|w| w.todo.len()).sum();
    let workers = if opts.workers == 0 {
        default_workers(work.len().max(1))
    } else {
        opts.workers
    };

    // Stream: each finished cell delivers its rows on this thread —
    // checkpoint line out first (kill-safety), then fold.
    let mut first_err: Option<Error> = None;
    let fast_router = opts.fast_router;
    let unfused = opts.unfused;
    pool::parallel_for_each_indexed(
        work,
        workers,
        |_, w| run_cell(w, fast_router, unfused),
        |_, res| match res {
            Ok(rows) => {
                for (hash, row) in rows {
                    if let Err(e) = writer.record(&hash, &row) {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                    reducer.push(row);
                }
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        },
    );
    if let Some(e) = first_err {
        return Err(e);
    }

    Ok(SweepRunSummary {
        report: reducer.finish(),
        total,
        resumed,
        executed,
        skipped,
        skipped_checkpoint_lines: done.skipped_lines,
    })
}

/// Run the full sweep on `workers` threads and reduce the results —
/// the plain path (no checkpointing/sharding) used by the CLI default,
/// examples and tests.
pub fn run_sweep(cfg: &SweepConfig, workers: usize) -> Result<SweepReport> {
    let opts = SweepRunOptions { workers, ..SweepRunOptions::default() };
    Ok(run_sweep_with(cfg, &opts)?.report)
}

/// The pre-trace-sharing execution path: every scenario draws its own
/// routing trace through the pure [`sim::run_scenario`]. Kept as the
/// A/B reference — `benches/sweep_scaling.rs` measures trace sharing
/// against it, and the unit tests pin both paths to identical bytes
/// (which is the trace-sharing correctness argument in one line).
pub fn run_sweep_legacy(cfg: &SweepConfig, workers: usize) -> Result<SweepReport> {
    let scenarios = grid::expand(cfg)?;
    let outcomes = pool::parallel_map_indexed(scenarios, workers, |_, sc| {
        debug_assert!(sc.run.method == sc.method && sc.run.seed == sc.seed);
        let out = sim::run_scenario(&sc.run, sc.method.clone(), sc.seed);
        (sc, out)
    });
    let mut results = Vec::with_capacity(outcomes.len());
    for (sc, out) in outcomes {
        results.push(ScenarioResult::new(&sc, &out?));
    }
    Ok(SweepReport::build(cfg.clone(), results))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    /// A small single-model grid whose 10 iterations cover the
    /// early-training chaos window (peak ~iteration 8), so the MACT
    /// cell demonstrably chunks and Method 1 demonstrably peaks.
    fn tiny_grid() -> SweepConfig {
        SweepConfig {
            models: vec!["i".into()],
            methods: vec![Method::FullRecompute, Method::Mact(vec![1, 2, 4, 8])],
            seeds: vec![7, 8],
            iterations: 10,
        }
    }

    #[test]
    fn sweep_runs_and_aggregates() {
        let report = run_sweep(&tiny_grid(), 2).unwrap();
        assert_eq!(report.scenarios.len(), 4);
        assert_eq!(report.cells.len(), 2);
        // MACT cell must report a positive activation reduction vs m1
        let mact = &report.cells[1];
        assert!(mact.act_reduction_vs_m1_pct.unwrap() > 0.0);
        // every scenario row carries real simulation output
        assert!(report.scenarios.iter().all(|s| s.peak_act_bytes > 0));
        assert!(report.scenarios.iter().all(|s| s.iterations == 10));
    }

    #[test]
    fn worker_count_does_not_change_the_report() {
        let a = run_sweep(&tiny_grid(), 1).unwrap();
        let b = run_sweep(&tiny_grid(), 4).unwrap();
        assert_eq!(a.scenarios, b.scenarios);
        assert_eq!(a.cells, b.cells);
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
    }

    #[test]
    fn trace_sharing_matches_legacy_bytes() {
        // THE trace-sharing invariant at engine level: the (fused)
        // shared-trace engine and the per-scenario legacy path emit
        // identical bytes.
        let shared = run_sweep(&tiny_grid(), 2).unwrap();
        let legacy = run_sweep_legacy(&tiny_grid(), 2).unwrap();
        assert_eq!(
            shared.to_json().to_string_pretty(),
            legacy.to_json().to_string_pretty()
        );
    }

    #[test]
    fn fused_matches_unfused_and_legacy_bytes() {
        // The fusion invariant at engine level: fused (default),
        // unfused (per-method trace-shared) and legacy (per-scenario)
        // all emit identical bytes — on a grid that includes a
        // fixed-chunk method so cross-method kernel sharing is
        // exercised too.
        let mut cfg = tiny_grid();
        cfg.methods = vec![
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ];
        let fused = run_sweep(&cfg, 2).unwrap();
        let unfused_opts = SweepRunOptions { workers: 2, unfused: true, ..Default::default() };
        let unfused = run_sweep_with(&cfg, &unfused_opts).unwrap().report;
        let legacy = run_sweep_legacy(&cfg, 2).unwrap();
        let fused_json = fused.to_json().to_string_pretty();
        assert_eq!(fused_json, unfused.to_json().to_string_pretty());
        assert_eq!(fused_json, legacy.to_json().to_string_pretty());
    }

    #[test]
    fn fused_matches_unfused_under_fast_router() {
        // Same invariant on the fast-router sample: the sampler changes
        // the drawn trace, never the evaluation, so fused and unfused
        // still agree byte for byte.
        let fused_opts =
            SweepRunOptions { workers: 2, fast_router: true, ..Default::default() };
        let unfused_opts = SweepRunOptions {
            workers: 2,
            fast_router: true,
            unfused: true,
            ..Default::default()
        };
        let fused = run_sweep_with(&tiny_grid(), &fused_opts).unwrap().report;
        let unfused = run_sweep_with(&tiny_grid(), &unfused_opts).unwrap().report;
        assert_eq!(
            fused.to_json().to_string_pretty(),
            unfused.to_json().to_string_pretty()
        );
    }

    #[test]
    fn fast_router_is_deterministic_but_a_different_sample() {
        let opts = |w| SweepRunOptions { workers: w, fast_router: true, ..Default::default() };
        let a = run_sweep_with(&tiny_grid(), &opts(1)).unwrap();
        let b = run_sweep_with(&tiny_grid(), &opts(4)).unwrap();
        assert_eq!(
            a.report.to_json().to_string_pretty(),
            b.report.to_json().to_string_pretty()
        );
        let default = run_sweep(&tiny_grid(), 2).unwrap();
        // same grid shape, different drawn sample
        assert_eq!(a.report.scenarios.len(), default.scenarios.len());
        assert!(a
            .report
            .scenarios
            .iter()
            .zip(&default.scenarios)
            .any(|(f, s)| f.peak_act_bytes != s.peak_act_bytes));
    }

    #[test]
    fn shard_runs_partition_the_grid() {
        let cfg = tiny_grid();
        let shard = |i| SweepRunOptions {
            workers: 2,
            shard: Some(crate::config::ShardSpec { index: i, count: 2 }),
            ..Default::default()
        };
        let s0 = run_sweep_with(&cfg, &shard(0)).unwrap();
        let s1 = run_sweep_with(&cfg, &shard(1)).unwrap();
        assert_eq!(s0.executed + s1.executed, cfg.scenario_count());
        assert_eq!(s0.skipped, s1.executed);
        let mut indices: Vec<usize> = s0
            .report
            .scenarios
            .iter()
            .chain(&s1.report.scenarios)
            .map(|r| r.index)
            .collect();
        indices.sort_unstable();
        assert_eq!(indices, (0..cfg.scenario_count()).collect::<Vec<_>>());
    }

    #[test]
    fn limit_caps_executed_scenarios() {
        let cfg = tiny_grid();
        let opts = SweepRunOptions { workers: 1, limit: Some(3), ..Default::default() };
        let s = run_sweep_with(&cfg, &opts).unwrap();
        assert_eq!(s.executed, 3);
        assert_eq!(s.skipped, 1);
        assert_eq!(s.report.scenarios.len(), 3);
    }

    #[test]
    fn resume_without_checkpoint_errors() {
        let opts = SweepRunOptions { resume: true, ..Default::default() };
        assert!(run_sweep_with(&tiny_grid(), &opts).is_err());
    }

    #[test]
    fn default_workers_bounded() {
        assert!(default_workers(1) >= 1);
        assert!(default_workers(4) <= 4);
        assert!(default_workers(0) >= 1);
    }
}
