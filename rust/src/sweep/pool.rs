//! Deterministic worker pool for embarrassingly-parallel scenario
//! grids (the structured-parallelism idiom of ppl's `ThreadPool`,
//! reduced to std): a shared injector queue that idle workers pull
//! from, with results flowing back to the caller over an `mpsc`
//! channel tagged by job index.
//!
//! Scheduling order is nondeterministic by design (whichever worker is
//! free takes the next job), but the *output* is not: every job
//! carries its index, the caller reassembles results by index, and
//! jobs are pure functions of their input — so the returned `Vec` is
//! bit-identical for any worker count. The sweep engine's determinism
//! guarantee rests on exactly this property.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Mutex;

/// Map `f` over `items` on `workers` threads, preserving input order
/// in the output. `f` receives `(index, item)`. With `workers <= 1`
/// the map runs inline on the caller's thread (no spawn overhead) —
/// the parallel and serial paths produce identical results.
pub fn parallel_map_indexed<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    // Global injector: workers steal the next job when idle, so a slow
    // scenario never blocks the queue behind it (dynamic load balance
    // over a heterogeneous grid — method 1 runs cost ~2× method 3).
    let injector: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let injector = &injector;
    let f = &f;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            scope.spawn(move || loop {
                let job = injector.lock().unwrap().pop_front();
                match job {
                    Some((i, t)) => {
                        let r = f(i, t);
                        if tx.send((i, r)).is_err() {
                            break;
                        }
                    }
                    None => break,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            debug_assert!(out[i].is_none(), "job {i} delivered twice");
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|r| r.expect("every job delivers exactly one result"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map_indexed(items, 4, |i, x| {
            assert_eq!(i as u64, x);
            x * x
        });
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let work = |_: usize, x: u64| x.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17);
        let items: Vec<u64> = (0..64).collect();
        let serial = parallel_map_indexed(items.clone(), 1, work);
        for workers in [2, 3, 8, 64, 200] {
            let parallel = parallel_map_indexed(items.clone(), workers, work);
            assert_eq!(serial, parallel, "workers={workers}");
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<u64> = parallel_map_indexed(Vec::<u64>::new(), 8, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_item_more_workers_than_jobs() {
        let out = parallel_map_indexed(vec![41u64], 16, |_, x| x + 1);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn uneven_job_costs_all_complete() {
        // Jobs with wildly different costs: the injector rebalances and
        // every result still lands at its index.
        let items: Vec<u64> = (0..32).collect();
        let out = parallel_map_indexed(items, 4, |_, x| {
            let spin = if x % 7 == 0 { 200_000 } else { 10 };
            let mut acc = x;
            for i in 0..spin {
                acc = acc.wrapping_add(i).rotate_left(1);
            }
            (x, acc)
        });
        for (i, (x, _)) in out.iter().enumerate() {
            assert_eq!(i as u64, *x);
        }
    }
}
