//! End-to-end determinism contract of the sweep engine: a 3-method ×
//! 2-model × 4-seed grid (24 scenarios) run with 1 worker and with 8
//! workers must produce **bit-identical** aggregated JSON — thread
//! count and scheduling order are not allowed to leak into results.

use memfine::config::{derive_seeds, Method, SweepConfig};
use memfine::sweep;

fn grid_3x2x4() -> SweepConfig {
    SweepConfig {
        models: vec!["i".into(), "ii".into()],
        methods: vec![
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ],
        seeds: derive_seeds(7, 4),
        iterations: 10,
    }
}

#[test]
fn sweep_json_bit_identical_across_worker_counts() {
    let cfg = grid_3x2x4();
    assert_eq!(cfg.scenario_count(), 24);

    let serial = sweep::run_sweep(&cfg, 1).expect("serial sweep");
    let parallel = sweep::run_sweep(&cfg, 8).expect("parallel sweep");

    let json_1 = serial.to_json().to_string_pretty();
    let json_8 = parallel.to_json().to_string_pretty();
    assert_eq!(json_1, json_8, "worker count changed the sweep artifact");

    // the same holds compactly serialised and structurally
    assert_eq!(
        serial.to_json().to_string_compact(),
        parallel.to_json().to_string_compact()
    );
    assert_eq!(serial.scenarios, parallel.scenarios);
    assert_eq!(serial.cells, parallel.cells);
}

#[test]
fn sweep_artifact_reparses_and_covers_grid() {
    let cfg = grid_3x2x4();
    let report = sweep::run_sweep(&cfg, 8).expect("sweep");
    assert_eq!(report.scenarios.len(), 24);
    assert_eq!(report.cells.len(), 6); // 2 models × 3 methods

    // round-trip through the JSON parser: the artifact is valid JSON
    // and the config block reconstructs the input grid.
    let text = report.to_json().to_string_pretty();
    let parsed = memfine::json::parse(&text).expect("artifact parses");
    let cfg_back =
        SweepConfig::from_json(parsed.get("config").expect("config block")).unwrap();
    assert_eq!(cfg_back, cfg);

    // scenario indices are the contiguous grid enumeration
    for (i, s) in report.scenarios.iter().enumerate() {
        assert_eq!(s.index, i);
        assert_eq!(s.iterations, 10);
    }
}

#[test]
fn sweep_reproduces_paper_cell_relations() {
    // The aggregates must reproduce the Table 4 relations on every
    // seed: chunked methods never OOM on Model I, and both chunked
    // methods cut Method 1's activation peak (fixed c=8 the deepest).
    let report = sweep::run_sweep(&grid_3x2x4(), 8).expect("sweep");
    let cell = |model: &str, prefix: &str| {
        report
            .cells
            .iter()
            .find(|c| c.model == model && c.method.starts_with(prefix))
            .unwrap_or_else(|| panic!("missing cell {model}/{prefix}"))
    };
    for model in ["i", "ii"] {
        let m1 = cell(model, "method1");
        let m2 = cell(model, "method2");
        let m3 = cell(model, "method3");
        assert_eq!(m2.trained_runs, m2.runs, "model {model}: method 2 must train");
        assert_eq!(m3.trained_runs, m3.runs, "model {model}: method 3 must train");
        assert!(m2.peak_act_bytes < m1.peak_act_bytes);
        assert!(m3.peak_act_bytes < m1.peak_act_bytes);
        assert!(m2.peak_act_bytes <= m3.peak_act_bytes);
        assert!(m2.act_reduction_vs_m1_pct.unwrap() >= m3.act_reduction_vs_m1_pct.unwrap());
    }
}
