//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — the offline registry carries
//! no `thiserror`, so the derive is spelled out (same messages).

use std::fmt;

/// Unified error for every MemFine subsystem.
#[derive(Debug)]
pub enum Error {
    /// Configuration rejected by validation.
    Config(String),

    /// JSON parse/serialise failure (see [`crate::json`]).
    Json { offset: usize, msg: String },

    /// CLI argument error.
    Cli(String),

    /// A simulated or real device ran out of memory. Carries the
    /// requesting device and the attempted allocation so OOM tests can
    /// assert on the exact failure site.
    Oom {
        device: usize,
        requested: u64,
        used: u64,
        capacity: u64,
    },

    /// Violation of a scheduling invariant (pipeline, dispatch, chunk).
    Schedule(String),

    /// PJRT runtime failure (artifact load, compile, execute).
    Runtime(String),

    /// Artifact missing or malformed.
    Artifact(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Json { offset, msg } => {
                write!(f, "json error at byte {offset}: {msg}")
            }
            Error::Cli(msg) => write!(f, "cli error: {msg}"),
            Error::Oom { device, requested, used, capacity } => write!(
                f,
                "OOM on device {device}: requested {requested} B, \
                 used {used} B of {capacity} B"
            ),
            Error::Schedule(msg) => write!(f, "schedule error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor used across modules.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn schedule(msg: impl Into<String>) -> Self {
        Error::Schedule(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oom_message_carries_accounting() {
        let e = Error::Oom { device: 3, requested: 10, used: 60, capacity: 64 };
        let s = e.to_string();
        assert!(s.contains("device 3") && s.contains("10 B") && s.contains("64 B"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn io_error_display_is_transparent_and_sourced() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert_eq!(e.to_string(), "gone");
        assert!(e.source().is_some());
        assert!(Error::config("x").source().is_none());
    }
}
