//! # MemFine — memory-aware fine-grained scheduling for MoE training
//!
//! Rust + JAX + Pallas reproduction of *"MemFine: Memory-Aware
//! Fine-Grained Scheduling for MoE Training"* (ZTE AIH Team, CS.DC 2025).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): Pallas kernels for the
//!   expert FFN hot-spot and the top-k router, validated against
//!   pure-jnp oracles.
//! * **Layer 2** (`python/compile/model.py`): the MoE transformer in
//!   JAX, AOT-lowered once to HLO-text artifacts.
//! * **Layer 3** (this crate): everything the paper contributes —
//!   the fine-grained chunk distribution algorithm ([`chunk`]::Fcda),
//!   memory-aware chunk tuning ([`chunk`]::Mact), the theoretical
//!   memory cost model ([`memory`]), plus the distributed-training
//!   substrate it needs: routing simulation ([`router`]), all-to-all
//!   dispatch planning ([`dispatch`]), pipeline scheduling
//!   ([`pipeline`]), a simulated cluster ([`cluster`]), collective
//!   cost models ([`collective`]), a performance model ([`perf`]), a
//!   whole-training-run simulator ([`sim`]), a deterministic parallel
//!   scenario-sweep engine ([`sweep`]) that fans method × config ×
//!   seed grids over a worker pool — drawing each (model, seed) cell's
//!   routing trace once ([`trace`]::SharedRoutingTrace), caching drawn
//!   traces on disk keyed by sampler/RNG provenance
//!   ([`trace`]::store, [`trace`]::provenance), reducing results as a
//!   stream, and checkpointing by scenario content hash for
//!   resumable/sharded grids — a shard [`orchestrator`] that
//!   launches, supervises, heals and auto-merges multi-process sweep
//!   fleets (`memfine launch`), a sidecar telemetry plane ([`obs`]:
//!   per-campaign JSON-lines event log, mergeable log-bucketed
//!   histograms, `memfine status`/`memfine events`), a fault plane
//!   (seeded scripted chaos drills via [`orchestrator`]`::chaos`, an
//!   injectable IO-fault seam [`faultfs`], a policy-driven supervisor
//!   with episode-scoped retry budgets and quarantine, and an acting
//!   watchdog [`obs`]`::watch` that raises alert events), and a
//!   real-execution coordinator
//!   ([`coordinator`]) that drives the AOT artifacts through the PJRT
//!   runtime ([`runtime`], behind the `pjrt` feature).
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! JAX entry points once, and this crate is self-contained afterwards.
//!
//! Entry points: the `memfine` binary (`memfine --help`), the
//! `examples/` drivers, and the `rust/benches/` harnesses that
//! regenerate every table and figure of the paper (DESIGN.md §4).

pub mod bench;
pub mod chunk;
pub mod cli;
pub mod cluster;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dispatch;
pub mod error;
pub mod faultfs;
pub mod json;
pub mod logging;
pub mod memory;
pub mod metrics;
pub mod obs;
pub mod orchestrator;
pub mod perf;
pub mod pipeline;
pub mod prop;
pub mod router;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod trace;
pub mod util;

pub use error::{Error, Result};
