//! Simulated cluster substrate: devices with tracked memory, process
//! groups, and OOM detection.
//!
//! The paper ran on 32 × 64 GB GPUs; this module gives the simulator
//! and the real-execution coordinator a common memory-accounting layer
//! with the same semantics a CUDA allocator presents: explicit
//! alloc/free, a high-water mark, and a hard capacity that turns
//! over-allocation into an [`Error::Oom`] event instead of a crash.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Tracked memory of one device.
#[derive(Clone, Debug)]
pub struct MemoryTracker {
    device: usize,
    capacity: u64,
    used: u64,
    peak: u64,
    /// Live allocations: id → bytes.
    allocs: HashMap<u64, u64>,
    next_id: u64,
    /// Count of rejected allocations (OOM events survived).
    pub oom_events: u64,
}

impl MemoryTracker {
    pub fn new(device: usize, capacity: u64) -> Self {
        MemoryTracker {
            device,
            capacity,
            used: 0,
            peak: 0,
            allocs: HashMap::new(),
            next_id: 0,
            oom_events: 0,
        }
    }

    /// Allocate `bytes`; returns a handle for `free`. Fails with
    /// [`Error::Oom`] when the capacity would be exceeded.
    pub fn alloc(&mut self, bytes: u64) -> Result<u64> {
        if self.used + bytes > self.capacity {
            self.oom_events += 1;
            return Err(Error::Oom {
                device: self.device,
                requested: bytes,
                used: self.used,
                capacity: self.capacity,
            });
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        let id = self.next_id;
        self.next_id += 1;
        self.allocs.insert(id, bytes);
        Ok(id)
    }

    /// Free a previous allocation.
    pub fn free(&mut self, id: u64) -> Result<()> {
        match self.allocs.remove(&id) {
            Some(bytes) => {
                self.used -= bytes;
                Ok(())
            }
            None => Err(Error::schedule(format!(
                "double free / unknown alloc id {id} on device {}",
                self.device
            ))),
        }
    }

    pub fn used(&self) -> u64 {
        self.used
    }
    pub fn peak(&self) -> u64 {
        self.peak
    }
    pub fn capacity(&self) -> u64 {
        self.capacity
    }
    pub fn available(&self) -> u64 {
        self.capacity - self.used
    }

    /// Reset the high-water mark (e.g. per iteration) keeping live
    /// allocations.
    pub fn reset_peak(&mut self) {
        self.peak = self.used;
    }
}

/// A process-group view of the cluster: `ep × pp` devices with
/// per-device trackers, addressed by (pp_rank, ep_rank).
#[derive(Clone, Debug)]
pub struct Cluster {
    pub ep: u64,
    pub pp: u64,
    trackers: Vec<MemoryTracker>,
}

impl Cluster {
    pub fn new(ep: u64, pp: u64, capacity_per_device: u64) -> Self {
        let n = (ep * pp) as usize;
        let trackers = (0..n)
            .map(|d| MemoryTracker::new(d, capacity_per_device))
            .collect();
        Cluster { ep, pp, trackers }
    }

    pub fn device_index(&self, pp_rank: u64, ep_rank: u64) -> usize {
        assert!(pp_rank < self.pp && ep_rank < self.ep);
        (pp_rank * self.ep + ep_rank) as usize
    }

    pub fn tracker(&mut self, pp_rank: u64, ep_rank: u64) -> &mut MemoryTracker {
        let i = self.device_index(pp_rank, ep_rank);
        &mut self.trackers[i]
    }

    pub fn tracker_ref(&self, pp_rank: u64, ep_rank: u64) -> &MemoryTracker {
        &self.trackers[self.device_index(pp_rank, ep_rank)]
    }

    /// EP group of one pipeline stage.
    pub fn ep_group(&self, pp_rank: u64) -> Vec<usize> {
        (0..self.ep).map(|e| self.device_index(pp_rank, e)).collect()
    }

    /// Highest peak across all devices (the cluster's memory headline).
    pub fn max_peak(&self) -> u64 {
        self.trackers.iter().map(|t| t.peak()).max().unwrap_or(0)
    }

    /// Total OOM events across devices.
    pub fn oom_events(&self) -> u64 {
        self.trackers.iter().map(|t| t.oom_events).sum()
    }

    pub fn device_count(&self) -> usize {
        self.trackers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut t = MemoryTracker::new(0, 100);
        let a = t.alloc(40).unwrap();
        let b = t.alloc(60).unwrap();
        assert_eq!(t.used(), 100);
        assert_eq!(t.available(), 0);
        t.free(a).unwrap();
        assert_eq!(t.used(), 60);
        t.free(b).unwrap();
        assert_eq!(t.used(), 0);
        assert_eq!(t.peak(), 100);
    }

    #[test]
    fn oom_is_reported_not_fatal() {
        let mut t = MemoryTracker::new(3, 50);
        t.alloc(40).unwrap();
        match t.alloc(20) {
            Err(Error::Oom { device, requested, used, capacity }) => {
                assert_eq!((device, requested, used, capacity), (3, 20, 40, 50));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(t.oom_events, 1);
        assert_eq!(t.used(), 40); // state unchanged after rejection
        t.alloc(10).unwrap(); // exact fit still works
    }

    #[test]
    fn double_free_rejected() {
        let mut t = MemoryTracker::new(0, 10);
        let a = t.alloc(5).unwrap();
        t.free(a).unwrap();
        assert!(t.free(a).is_err());
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut t = MemoryTracker::new(0, 100);
        let a = t.alloc(80).unwrap();
        t.free(a).unwrap();
        t.alloc(10).unwrap();
        assert_eq!(t.peak(), 80);
        t.reset_peak();
        assert_eq!(t.peak(), 10);
    }

    #[test]
    fn cluster_addressing() {
        let c = Cluster::new(32, 4, 64);
        assert_eq!(c.device_count(), 128);
        assert_eq!(c.device_index(0, 0), 0);
        assert_eq!(c.device_index(1, 0), 32);
        assert_eq!(c.device_index(3, 31), 127);
        assert_eq!(c.ep_group(2).len(), 32);
    }

    #[test]
    fn cluster_tracks_per_device() {
        let mut c = Cluster::new(2, 2, 100);
        c.tracker(0, 0).alloc(70).unwrap();
        c.tracker(1, 1).alloc(30).unwrap();
        assert_eq!(c.tracker_ref(0, 0).used(), 70);
        assert_eq!(c.tracker_ref(0, 1).used(), 0);
        assert_eq!(c.max_peak(), 70);
        assert!(c.tracker(0, 0).alloc(40).is_err());
        assert_eq!(c.oom_events(), 1);
    }
}
