//! `obs::watch` — a watchdog that *acts* on the campaign event
//! stream instead of only recording it.
//!
//! [`Watchdog`] tails `events.jsonl` incrementally (a byte cursor, the
//! capped line reader from [`super::read_events_from`]) and folds the
//! new events into a handful of campaign-health counters. When a
//! counter crosses its [`WatchConfig`] threshold the watchdog raises a
//! structured [`Alert`] — raised at most once per alert kind per
//! campaign — which the launch orchestrator appends back into the
//! event log as an `alert_*` event and `memfine status` renders.
//! Chaos drills assert on exactly these events.
//!
//! Like everything in [`crate::obs`], the watchdog is strictly
//! sidecar: scan failures are swallowed (the next scan retries from
//! the same cursor), alerts never interrupt supervision, and nothing
//! here participates in campaign identity or artifact bytes.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::json::{self, Value};

/// Alert kind tag: one shard relaunched `flap_attempts`+ times.
pub const ALERT_SHARD_FLAPPING: &str = "alert_shard_flapping";
/// Alert kind tag: the fleet accumulated `stall_burst`+ stall kills.
pub const ALERT_STALL_BURST: &str = "alert_stall_burst";
/// Alert kind tag: the pool reported `steal_storm`+ steals.
pub const ALERT_STEAL_STORM: &str = "alert_steal_storm";
/// Alert kind tag: `degrade_burst`+ degraded IO writes (checkpoint
/// records lost to the ladder, or cells that fell back to uncached
/// trace generation after a store failure).
pub const ALERT_IO_DEGRADE_BURST: &str = "alert_io_degrade_burst";
/// Alert kind tag: `host_loss`+ whole hosts declared lost (lease
/// expired; their shards were reassigned to survivors).
pub const ALERT_HOST_LOST: &str = "alert_host_lost";

/// Thresholds for raising alerts. All are inclusive (`count >=
/// threshold` raises); a threshold of 0 disables that alert.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WatchConfig {
    /// Spawn attempts on one shard before it counts as flapping.
    pub flap_attempts: u64,
    /// Fleet-wide stall kills before a stall burst.
    pub stall_burst: u64,
    /// Fleet-wide pool steals before a steal storm.
    pub steal_storm: u64,
    /// Degraded IO writes before an IO degrade burst.
    pub degrade_burst: u64,
    /// Hosts declared lost before the host-loss alert. Losing even one
    /// whole host is remarkable, so the default threshold is 1.
    pub host_loss: u64,
}

impl Default for WatchConfig {
    fn default() -> Self {
        WatchConfig {
            flap_attempts: 3,
            stall_burst: 3,
            steal_storm: 100_000,
            degrade_burst: 1,
            host_loss: 1,
        }
    }
}

/// One raised alert: the `alert_*` event tag, a human line for the
/// launch log, and the structured fields for the event log.
#[derive(Debug, Clone)]
pub struct Alert {
    pub kind: &'static str,
    pub message: String,
    pub fields: Vec<(&'static str, Value)>,
}

/// Incremental event-stream watcher. Create once per campaign, call
/// [`Watchdog::scan`] whenever supervision observes activity and once
/// after the merge; each scan reads only bytes appended since the
/// last one.
#[derive(Debug)]
pub struct Watchdog {
    cfg: WatchConfig,
    cursor: u64,
    stalls: u64,
    steals: u64,
    degrades: u64,
    lost_hosts: Vec<String>,
    max_attempt: BTreeMap<u64, u64>,
    skipped: usize,
    raised: BTreeSet<&'static str>,
}

impl Watchdog {
    pub fn new(cfg: WatchConfig) -> Self {
        Watchdog {
            cfg,
            cursor: 0,
            stalls: 0,
            steals: 0,
            degrades: 0,
            lost_hosts: Vec::new(),
            max_attempt: BTreeMap::new(),
            skipped: 0,
            raised: BTreeSet::new(),
        }
    }

    /// Lines the capped reader dropped across all scans so far.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Tail `path` from the cursor, fold new events, and return any
    /// newly raised alerts. Missing files and read errors are quietly
    /// treated as "nothing new" — the next scan retries.
    pub fn scan(&mut self, path: &Path) -> Vec<Alert> {
        let Ok((events, skipped, next)) = super::read_events_from(path, self.cursor) else {
            return Vec::new();
        };
        self.cursor = next;
        self.skipped += skipped;
        for ev in &events {
            match ev.kind.as_str() {
                "shard_spawned" => {
                    let shard = ev.field_u64("shard").unwrap_or(0);
                    let attempt = ev.field_u64("attempt").unwrap_or(1);
                    let slot = self.max_attempt.entry(shard).or_insert(0);
                    *slot = (*slot).max(attempt);
                }
                "shard_stalled" => self.stalls += 1,
                "sweep_done" => {
                    self.steals = self
                        .steals
                        .saturating_add(ev.field_u64("steals").unwrap_or(0));
                }
                "checkpoint_degraded" => self.degrades += 1,
                "cell_eval" => {
                    if ev.field_str("cache") == Some("degrade") {
                        self.degrades += 1;
                    }
                }
                "shard_host_lost" => {
                    self.lost_hosts
                        .push(ev.field_str("host").unwrap_or("?").to_string());
                }
                _ => {}
            }
        }
        self.collect_alerts()
    }

    fn collect_alerts(&mut self) -> Vec<Alert> {
        let mut alerts = Vec::new();
        if self.cfg.flap_attempts > 0 && !self.raised.contains(ALERT_SHARD_FLAPPING) {
            if let Some((&shard, &attempts)) = self
                .max_attempt
                .iter()
                .find(|(_, &a)| a >= self.cfg.flap_attempts)
            {
                self.raised.insert(ALERT_SHARD_FLAPPING);
                alerts.push(Alert {
                    kind: ALERT_SHARD_FLAPPING,
                    message: format!("shard {shard} is flapping ({attempts} spawn attempts)"),
                    fields: vec![
                        ("shard", json::num(shard as f64)),
                        ("attempts", json::num(attempts as f64)),
                    ],
                });
            }
        }
        if self.cfg.stall_burst > 0
            && self.stalls >= self.cfg.stall_burst
            && self.raised.insert(ALERT_STALL_BURST)
        {
            alerts.push(Alert {
                kind: ALERT_STALL_BURST,
                message: format!("stall burst: {} stall kills across the fleet", self.stalls),
                fields: vec![("stalls", json::num(self.stalls as f64))],
            });
        }
        if self.cfg.steal_storm > 0
            && self.steals >= self.cfg.steal_storm
            && self.raised.insert(ALERT_STEAL_STORM)
        {
            alerts.push(Alert {
                kind: ALERT_STEAL_STORM,
                message: format!("steal storm: {} pool steals reported", self.steals),
                fields: vec![("steals", json::num(self.steals as f64))],
            });
        }
        if self.cfg.degrade_burst > 0
            && self.degrades >= self.cfg.degrade_burst
            && self.raised.insert(ALERT_IO_DEGRADE_BURST)
        {
            alerts.push(Alert {
                kind: ALERT_IO_DEGRADE_BURST,
                message: format!("IO degrade burst: {} degraded writes", self.degrades),
                fields: vec![("degraded", json::num(self.degrades as f64))],
            });
        }
        if self.cfg.host_loss > 0
            && self.lost_hosts.len() as u64 >= self.cfg.host_loss
            && self.raised.insert(ALERT_HOST_LOST)
        {
            alerts.push(Alert {
                kind: ALERT_HOST_LOST,
                message: format!(
                    "host lost: lease expired on {} (shards reassigned to survivors)",
                    self.lost_hosts.join(", ")
                ),
                fields: vec![
                    ("host", json::s(self.lost_hosts.join(","))),
                    ("hosts_lost", json::num(self.lost_hosts.len() as f64)),
                ],
            });
        }
        alerts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::EventLog;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("memfine-watch-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn stall_burst_raises_once_across_incremental_scans() {
        let path = tmp("stalls.jsonl");
        let log = EventLog::open(&path);
        let mut dog = Watchdog::new(WatchConfig::default());
        log.emit("shard_stalled", vec![("shard", json::num(0.0))]);
        log.emit("shard_stalled", vec![("shard", json::num(1.0))]);
        assert!(dog.scan(&path).is_empty(), "2 stalls < burst of 3");
        // the third stall arrives later; the cursor makes the second
        // scan read only the new line, yet the counter is cumulative
        log.emit("shard_stalled", vec![("shard", json::num(0.0))]);
        let alerts = dog.scan(&path);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].kind, ALERT_STALL_BURST);
        log.emit("shard_stalled", vec![("shard", json::num(2.0))]);
        assert!(dog.scan(&path).is_empty(), "raised at most once");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn flapping_shard_is_named_in_the_alert() {
        let path = tmp("flap.jsonl");
        let log = EventLog::open(&path);
        let mut dog = Watchdog::new(WatchConfig::default());
        for attempt in 1..=3u32 {
            log.emit(
                "shard_spawned",
                vec![
                    ("shard", json::num(2.0)),
                    ("attempt", json::num(f64::from(attempt))),
                ],
            );
        }
        let alerts = dog.scan(&path);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].kind, ALERT_SHARD_FLAPPING);
        assert!(alerts[0].message.contains("shard 2"));
        assert!(alerts[0]
            .fields
            .iter()
            .any(|(k, v)| *k == "shard" && v.as_u64() == Some(2)));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn steal_storm_and_degrade_burst_thresholds() {
        let path = tmp("storm.jsonl");
        let log = EventLog::open(&path);
        let cfg = WatchConfig {
            steal_storm: 100,
            ..WatchConfig::default()
        };
        let mut dog = Watchdog::new(cfg);
        log.emit("sweep_done", vec![("steals", json::num(60.0))]);
        log.emit("sweep_done", vec![("steals", json::num(60.0))]);
        log.emit("checkpoint_degraded", vec![("shard", json::num(0.0))]);
        log.emit("cell_eval", vec![("cache", json::s("degrade"))]);
        log.emit("cell_eval", vec![("cache", json::s("hit"))]);
        let alerts = dog.scan(&path);
        let kinds: Vec<&str> = alerts.iter().map(|a| a.kind).collect();
        assert!(kinds.contains(&ALERT_STEAL_STORM), "{kinds:?}");
        assert!(kinds.contains(&ALERT_IO_DEGRADE_BURST), "{kinds:?}");
        assert!(!kinds.contains(&ALERT_STALL_BURST), "{kinds:?}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_and_alert_events_are_ignored() {
        let mut dog = Watchdog::new(WatchConfig::default());
        assert!(dog.scan(Path::new("/definitely/not/here.jsonl")).is_empty());
        // alert events already in the log must not feed the counters
        let path = tmp("selffeed.jsonl");
        let log = EventLog::open(&path);
        log.emit(ALERT_STALL_BURST, vec![("stalls", json::num(99.0))]);
        assert!(dog.scan(&path).is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn host_loss_raises_once_and_names_the_host() {
        let path = tmp("hostloss.jsonl");
        let log = EventLog::open(&path);
        let mut dog = Watchdog::new(WatchConfig::default());
        log.emit(
            "shard_host_lost",
            vec![("shard", json::num(1.0)), ("host", json::s("h1"))],
        );
        let alerts = dog.scan(&path);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].kind, ALERT_HOST_LOST);
        assert!(alerts[0].message.contains("h1"), "{}", alerts[0].message);
        assert!(alerts[0]
            .fields
            .iter()
            .any(|(k, v)| *k == "host" && v.as_str() == Some("h1")));
        // a second loss does not re-raise
        log.emit(
            "shard_host_lost",
            vec![("shard", json::num(3.0)), ("host", json::s("h2"))],
        );
        assert!(dog.scan(&path).is_empty(), "raised at most once");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn zero_threshold_disables_an_alert() {
        let path = tmp("disabled.jsonl");
        let log = EventLog::open(&path);
        let mut dog = Watchdog::new(WatchConfig {
            stall_burst: 0,
            ..WatchConfig::default()
        });
        for _ in 0..10 {
            log.emit("shard_stalled", vec![]);
        }
        assert!(dog.scan(&path).is_empty());
        std::fs::remove_file(&path).unwrap();
    }
}
