//! Whole-training-run simulator: the engine behind every paper table
//! and figure.
//!
//! The run is split into two phases with a hard boundary between them:
//!
//! 1. **Trace generation** — the routed-token stream per (iteration,
//!    MoE layer) is drawn by [`crate::router::GatingSim`] into a
//!    [`SharedRoutingTrace`]. The stream depends only on (model,
//!    gating, seed) — never on the method — so one draw serves every
//!    method of a paired-comparison cell ([`run_scenario_on_trace`]).
//! 2. **Method evaluation** — per iteration, (a) apply the configured
//!    method's chunking decision ([`crate::chunk::Mact`] for
//!    Method 3), (b) evaluate the memory model per pipeline stage to
//!    detect OOM (Eq. 2/3), and (c) compose per-layer timing into an
//!    iteration time and TGS (Eq. 10). Evaluation never touches the
//!    RNG.
//!
//! Outputs are the traces the benches print: Table 4's memory rows,
//! Fig. 2's distribution slice, Fig. 4's TGS series and Fig. 5's
//! chunk grid.

use crate::chunk::Mact;
use crate::config::{Method, RunConfig};
use crate::error::Error;
use crate::memory::{ActivationModel, StaticModel};
use crate::perf::PerfModel;
use crate::router::GatingSim;
pub mod ablation;
pub mod repro;

use crate::trace::{ChunkRecord, ChunkTrace, RoutingRecord, RoutingTrace, SharedRoutingTrace};

/// Outcome of one MoE layer in one iteration.
#[derive(Clone, Copy, Debug)]
pub struct LayerOutcome {
    pub layer: u64,
    /// Coldest rank's received copies.
    pub min_recv: u64,
    /// Mean received copies across the EP group.
    pub mean_recv: f64,
    /// Hottest rank's received copies (`s''`).
    pub max_recv: u64,
    /// Chunk count the method applied.
    pub chunks: u64,
    /// Peak activation bytes of the hottest rank for this layer.
    pub act_bytes: u64,
}

/// Outcome of one iteration.
#[derive(Clone, Debug)]
pub struct IterationOutcome {
    pub iteration: u64,
    pub layers: Vec<LayerOutcome>,
    /// Peak activation bytes across stages (hottest layer).
    pub peak_act_bytes: u64,
    /// Static + activation peak across stages.
    pub peak_total_bytes: u64,
    /// True when Eq. 3 is violated on some stage.
    pub oom: bool,
    pub iteration_s: f64,
    pub tgs: f64,
}

/// Aggregate of a full simulated run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub method: Method,
    pub iterations: Vec<IterationOutcome>,
    pub routing: RoutingTrace,
    pub chunks: ChunkTrace,
    /// Mean TGS over non-OOM iterations (0 if all OOM).
    pub avg_tgs: f64,
    pub oom_iterations: u64,
    /// Worst-case activation bytes observed anywhere in the run.
    pub peak_act_bytes: u64,
    /// Static bytes of the heaviest stage.
    pub static_bytes: u64,
}

impl RunOutcome {
    pub fn trained(&self) -> bool {
        self.oom_iterations == 0
    }
}

/// Run one scenario as a pure function of its inputs: clone the base
/// envelope, substitute the method and seed, draw the trace, evaluate.
/// No shared mutable state — the [`Simulator`] holds only per-run
/// models and every stochastic draw forks a fresh RNG from `(seed,
/// iteration, layer)` — so calls are bit-reproducible and safe to
/// execute from any thread in any order. This is the reference
/// (trace-per-scenario) execution path; the sweep engine shares one
/// trace across a cell's methods via [`run_scenario_on_trace`] and is
/// pinned bit-identical to this path.
pub fn run_scenario(base: &RunConfig, method: Method, seed: u64) -> crate::Result<RunOutcome> {
    let mut run = base.clone();
    run.method = method;
    run.seed = seed;
    Ok(Simulator::new(run)?.run_all())
}

/// Evaluate one method against an already-drawn routing trace: the
/// trace-shared half of [`run_scenario`]. The scenario's seed is the
/// trace's seed (a trace *is* a seed's routed-token stream). For a
/// trace drawn with the default sampler, the outcome is bit-identical
/// to `run_scenario(base, method, trace.seed)` — the
/// paired-comparison invariant the sweep engine's determinism
/// contract rests on. A trace drawn with
/// [`crate::router::GatingSim::with_fast_multinomial`] is a
/// *different* (equally valid) sample of the same distribution, so
/// its outcomes are deterministic but not byte-equal to the
/// default-sampler path.
pub fn run_scenario_on_trace(
    base: &RunConfig,
    method: Method,
    trace: &SharedRoutingTrace,
) -> crate::Result<RunOutcome> {
    let mut run = base.clone();
    run.method = method;
    run.seed = trace.seed;
    let sim = Simulator::new(run)?;
    // The records encode (model, parallel)-specific per-rank statistics
    // — any geometry difference (EP width, expert count, sequence/batch
    // shape, layer counts) silently corrupts chunk decisions and OOM
    // verdicts, so the whole identity must match, not just layer
    // counts.
    if trace.model != sim.run.model || trace.parallel != sim.run.parallel {
        return Err(Error::config(
            "trace was drawn for a different (model, parallel) configuration than the run",
        ));
    }
    if trace.iterations < sim.run.iterations {
        return Err(Error::config(format!(
            "trace covers {} iterations, run needs {}",
            trace.iterations, sim.run.iterations
        )));
    }
    Ok(sim.run_on_trace(trace))
}

/// The simulator.
pub struct Simulator {
    pub run: RunConfig,
    gating: GatingSim,
    act: ActivationModel,
    sta: StaticModel,
    perf: PerfModel,
    mact: Option<Mact>,
}

impl Simulator {
    pub fn new(run: RunConfig) -> crate::Result<Self> {
        run.validate()?;
        let gating = GatingSim::new(run.model.clone(), run.parallel.clone(), run.seed);
        let act = ActivationModel::new(&run);
        let sta = StaticModel::new(&run);
        let perf = PerfModel::new(run.model.clone(), run.parallel.clone(), run.dtype_bytes);
        let mact = match &run.method {
            Method::Mact(bins) => Some(Mact::new(&run, bins.clone())),
            _ => None,
        };
        Ok(Simulator { run, gating, act, sta, perf, mact })
    }

    /// Pipeline stage hosting `layer`.
    fn stage_of(&self, layer: u64) -> u64 {
        let per = self.run.parallel.layers_per_stage(self.run.model.layers);
        (layer / per).min(self.run.parallel.pp - 1)
    }

    /// The method's chunk decision for (stage, s'').
    pub fn chunks_for(&self, stage: u64, max_recv: u64) -> u64 {
        match &self.run.method {
            Method::FullRecompute => 1,
            Method::FixedChunk(c) => *c,
            Method::Mact(_) => {
                self.mact.as_ref().expect("mact built").decide(stage, max_recv).chosen_c
            }
        }
    }

    /// Can MemFine skip attention recomputation on this stage
    /// (*selective* recomputation)? Only if storing the dense part of
    /// all the stage's layers for every in-flight micro-batch — plus
    /// the chunked MoE peak — still fits the budget (Eq. 3). This is
    /// the throughput edge of Methods 2/3 over full recomputation.
    fn selective_fits(&self, stage: u64, moe_chunk_peak: u64, budget: u64) -> bool {
        let m_g = self.run.parallel.m_g(stage);
        let layers_here = self.run.parallel.layers_per_stage(self.run.model.layers);
        let stored_dense = m_g * layers_here * self.act.dense_bytes();
        self.sta.bytes_on_rank(stage) + stored_dense + moe_chunk_peak <= budget
    }

    /// Simulate one iteration, drawing its routing directly (the
    /// standalone path; [`Simulator::run_on_trace`] evaluates against
    /// a pre-drawn trace instead, with bit-identical results).
    pub fn iteration(&self, it: u64) -> IterationOutcome {
        let model = &self.run.model;
        let stats: Vec<RoutingRecord> = (model.dense_layers..model.layers)
            .map(|layer| {
                let routing = self.gating.route(it, layer);
                let s = routing.summary();
                RoutingRecord {
                    iteration: it,
                    layer,
                    min_recv: routing.min_received(),
                    mean_recv: s.mean(),
                    max_recv: routing.max_received(),
                }
            })
            .collect();
        self.iteration_stats(it, &stats)
    }

    /// Evaluate one iteration of the configured method against the
    /// given per-MoE-layer routing statistics (ascending layer order).
    /// Pure method evaluation: no RNG is touched here, which is what
    /// lets a cell's methods share one drawn trace.
    fn iteration_stats(&self, it: u64, moe_stats: &[RoutingRecord]) -> IterationOutcome {
        let model = &self.run.model;
        let pp = self.run.parallel.pp as usize;
        let budget = (self.run.alpha * self.run.gpu_mem_bytes as f64) as u64;
        let method1 = matches!(self.run.method, Method::FullRecompute);
        debug_assert_eq!(
            moe_stats.len(),
            (model.layers - model.dense_layers) as usize
        );

        // Pass 1: chunk decision per MoE layer from the routing stats.
        struct MoeLayer {
            layer: u64,
            stage: usize,
            min_recv: u64,
            mean_recv: f64,
            max_recv: u64,
            chunks: u64,
        }
        let mut moe_layers = Vec::with_capacity(model.layers as usize);
        for rec in moe_stats {
            debug_assert_eq!(rec.iteration, it);
            let layer = rec.layer;
            let stage = self.stage_of(layer) as usize;
            let max_recv = rec.max_recv;
            let chunks = self.chunks_for(stage as u64, max_recv);
            moe_layers.push(MoeLayer {
                layer,
                stage,
                min_recv: rec.min_recv,
                mean_recv: rec.mean_recv,
                max_recv,
                chunks,
            });
        }

        // Per-stage chunked-MoE peaks decide selective recompute.
        let mut moe_chunk_peak = vec![0u64; pp];
        for l in &moe_layers {
            let chunked = self
                .act
                .layer(l.max_recv.div_ceil(l.chunks))
                .moe_part();
            moe_chunk_peak[l.stage] = moe_chunk_peak[l.stage].max(chunked);
        }
        let selective: Vec<bool> = (0..pp)
            .map(|s| {
                !method1
                    && self.run.allow_selective_recompute
                    && self.selective_fits(s as u64, moe_chunk_peak[s], budget)
            })
            .collect();

        // Pass 2: memory + time accumulation.
        let mut layers = Vec::with_capacity(moe_layers.len());
        let mut per_stage_time = vec![0.0f64; pp];
        let mut per_stage_act_peak = vec![0u64; pp];
        for layer in 0..model.dense_layers {
            let stage = self.stage_of(layer) as usize;
            per_stage_time[stage] += self.perf.dense_layer(!selective[stage]).total();
            per_stage_act_peak[stage] =
                per_stage_act_peak[stage].max(self.act.dense_bytes());
        }
        for l in &moe_layers {
            let stage = l.stage;
            let act_bytes = if method1 {
                self.act.peak_bytes(stage as u64, l.max_recv, true)
            } else if selective[stage] {
                // stored dense part of the whole stage + this layer's
                // chunked MoE transient
                let m_g = self.run.parallel.m_g(stage as u64);
                let layers_here =
                    self.run.parallel.layers_per_stage(self.run.model.layers);
                m_g * layers_here * self.act.dense_bytes()
                    + self.act.layer(l.max_recv.div_ceil(l.chunks)).moe_part()
            } else {
                self.act
                    .peak_bytes_chunked(stage as u64, l.max_recv, l.chunks, true)
            };
            per_stage_act_peak[stage] = per_stage_act_peak[stage].max(act_bytes);
            per_stage_time[stage] += if method1 {
                self.perf.moe_layer_method1(l.max_recv).total()
            } else {
                self.perf
                    .moe_layer_memfine(l.max_recv, l.chunks, !selective[stage])
                    .total()
            };
            layers.push(LayerOutcome {
                layer: l.layer,
                min_recv: l.min_recv,
                mean_recv: l.mean_recv,
                max_recv: l.max_recv,
                chunks: l.chunks,
                act_bytes,
            });
        }

        let mut oom = false;
        let mut peak_total = 0u64;
        let mut peak_act = 0u64;
        for stage in 0..self.run.parallel.pp {
            let total = self.sta.bytes_on_rank(stage) + per_stage_act_peak[stage as usize];
            peak_total = peak_total.max(total);
            peak_act = peak_act.max(per_stage_act_peak[stage as usize]);
            if total > budget {
                oom = true;
            }
        }

        let iteration_s = self
            .perf
            .iteration_time(&per_stage_time, self.run.parallel.micro_batches());
        let tgs = self.perf.tgs(iteration_s);
        IterationOutcome {
            iteration: it,
            layers,
            peak_act_bytes: peak_act,
            peak_total_bytes: peak_total,
            oom,
            iteration_s,
            tgs,
        }
    }

    /// Draw this run's full routing trace (phase 1 of the run). The
    /// trace depends only on (model, gating, seed) — callers holding
    /// several methods of one cell draw it once and evaluate each via
    /// [`Simulator::run_on_trace`] / [`run_scenario_on_trace`].
    pub fn draw_trace(&self) -> SharedRoutingTrace {
        SharedRoutingTrace::generate(&self.gating, self.run.iterations)
    }

    /// Simulate the configured number of iterations, producing traces.
    ///
    /// Like the real system, an OOM iteration contributes no TGS sample
    /// (the job would have crashed); the bench reports `trained = ×`
    /// when any iteration OOMs — matching Table 4's "training" column.
    pub fn run_all(&self) -> RunOutcome {
        self.run_on_trace(&self.draw_trace())
    }

    /// Evaluate the configured method against a pre-drawn routing
    /// trace (phase 2 of the run). Bit-identical to
    /// [`Simulator::run_all`] when
    /// the trace was drawn from this run's seed: evaluation consumes
    /// only the per-(iteration, layer) statistics, which
    /// [`SharedRoutingTrace::generate`] draws through the very same
    /// stateless `route()` streams.
    ///
    /// Panics (debug) if the trace shape does not match the run; use
    /// [`run_scenario_on_trace`] for a validated entry point.
    pub fn run_on_trace(&self, trace: &SharedRoutingTrace) -> RunOutcome {
        debug_assert_eq!(trace.model, self.run.model);
        debug_assert_eq!(trace.parallel, self.run.parallel);
        debug_assert!(trace.iterations >= self.run.iterations);
        let mut iterations = Vec::new();
        let mut routing = RoutingTrace::default();
        let mut chunks = ChunkTrace::default();
        let mut tgs_sum = 0.0;
        let mut tgs_n = 0u64;
        let mut oom_iterations = 0;
        let mut peak_act = 0u64;

        for it in 0..self.run.iterations {
            let out = self.iteration_stats(it, trace.iteration(it));
            for l in &out.layers {
                chunks.push(ChunkRecord {
                    iteration: it,
                    layer: l.layer,
                    chosen_c: l.chunks,
                });
            }
            for l in &out.layers {
                routing.push(RoutingRecord {
                    iteration: it,
                    layer: l.layer,
                    min_recv: l.min_recv,
                    mean_recv: l.mean_recv,
                    max_recv: l.max_recv,
                });
            }
            if out.oom {
                oom_iterations += 1;
            } else {
                tgs_sum += out.tgs;
                tgs_n += 1;
            }
            peak_act = peak_act.max(out.peak_act_bytes);
            iterations.push(out);
        }
        RunOutcome {
            method: self.run.method.clone(),
            iterations,
            routing,
            chunks,
            avg_tgs: if tgs_n > 0 { tgs_sum / tgs_n as f64 } else { 0.0 },
            oom_iterations,
            peak_act_bytes: peak_act,
            static_bytes: self.sta.max_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, model_ii, paper_run, Method};

    fn outcome(model: crate::config::ModelConfig, method: Method) -> RunOutcome {
        let mut run = paper_run(model, method);
        run.iterations = 20;
        Simulator::new(run).unwrap().run_all()
    }

    #[test]
    fn method1_model_i_ooms_table4() {
        let o = outcome(model_i(), Method::FullRecompute);
        assert!(!o.trained(), "Table 4: Method 1 on Model I must OOM");
    }

    #[test]
    fn memfine_rescues_model_i_table4() {
        let o2 = outcome(model_i(), Method::FixedChunk(8));
        assert!(o2.trained(), "Method 2 must train");
        let o3 = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        assert!(o3.trained(), "Method 3 must train");
    }

    #[test]
    fn activation_ordering_m2_lt_m3_lt_m1() {
        // Table 4: c=8 saves most activation; MACT sits between.
        let m1 = outcome(model_i(), Method::FullRecompute).peak_act_bytes;
        let m2 = outcome(model_i(), Method::FixedChunk(8)).peak_act_bytes;
        let m3 = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8])).peak_act_bytes;
        assert!(m2 < m3, "m2 {m2} !< m3 {m3}");
        assert!(m3 < m1, "m3 {m3} !< m1 {m1}");
    }

    #[test]
    fn model_ii_method1_trains_table4() {
        let o = outcome(model_ii(), Method::FullRecompute);
        assert!(o.trained(), "Table 4: Method 1 on Model II trains");
    }

    #[test]
    fn fig4_model_ii_ordering() {
        // Model II average TGS: Method 3 > Method 1 > Method 2.
        let m1 = outcome(model_ii(), Method::FullRecompute).avg_tgs;
        let m2 = outcome(model_ii(), Method::FixedChunk(8)).avg_tgs;
        let m3 = outcome(model_ii(), Method::Mact(vec![1, 2, 4, 8])).avg_tgs;
        assert!(m3 > m1, "m3 {m3} !> m1 {m1}");
        assert!(m1 > m2, "m1 {m1} !> m2 {m2}");
    }

    #[test]
    fn fig4_model_i_m3_beats_m2() {
        let m2 = outcome(model_i(), Method::FixedChunk(8)).avg_tgs;
        let m3 = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8])).avg_tgs;
        assert!(m3 > m2, "m3 {m3} !> m2 {m2}");
    }

    #[test]
    fn fig5_chunk_trend_bump() {
        // Mean MACT chunk value rises into the chaos window then falls.
        let o = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        let means = o.chunks.mean_per_iteration(20);
        let early = means[0];
        let peak = means[5..12].iter().cloned().fold(0.0, f64::max);
        let late = means[19];
        assert!(peak > early, "peak {peak} !> early {early}");
        assert!(peak > late, "peak {peak} !> late {late}");
    }

    #[test]
    fn fig5_deep_layers_get_larger_chunks() {
        let o = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        let grid = o.chunks.grid(16, 20);
        let shallow: u64 = (3..8).map(|l| grid[l][7]).sum();
        let deep: u64 = (11..16).map(|l| grid[l][7]).sum();
        assert!(deep >= shallow, "deep {deep} < shallow {shallow}");
    }

    #[test]
    fn deterministic_runs() {
        let a = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        let b = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        assert_eq!(a.peak_act_bytes, b.peak_act_bytes);
        assert_eq!(a.avg_tgs, b.avg_tgs);
        assert_eq!(a.chunks.records, b.chunks.records);
    }

    #[test]
    fn run_scenario_pure_and_matches_simulator() {
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 8;
        let a = run_scenario(&base, Method::Mact(vec![1, 2, 4, 8]), 11).unwrap();
        let b = run_scenario(&base, Method::Mact(vec![1, 2, 4, 8]), 11).unwrap();
        assert_eq!(a.chunks.records, b.chunks.records);
        assert_eq!(a.peak_act_bytes, b.peak_act_bytes);
        assert_eq!(a.avg_tgs, b.avg_tgs);
        // the base envelope is input, not state: untouched
        assert_eq!(base.method, Method::FullRecompute);
        assert_eq!(base.seed, 7);
        // and equals the direct Simulator path
        let mut direct = base.clone();
        direct.method = Method::Mact(vec![1, 2, 4, 8]);
        direct.seed = 11;
        let c = Simulator::new(direct).unwrap().run_all();
        assert_eq!(a.chunks.records, c.chunks.records);
        assert_eq!(a.avg_tgs, c.avg_tgs);
    }

    #[test]
    fn trace_sharing_bit_identical_to_per_scenario_runs() {
        // The paired-comparison invariant: every method evaluated
        // against one shared trace must equal its own full
        // run_scenario (which re-draws the same trace from the seed).
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 8;
        let seed = 11u64;
        let mut probe = base.clone();
        probe.seed = seed;
        let trace = Simulator::new(probe).unwrap().draw_trace();
        for method in [
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ] {
            let shared = run_scenario_on_trace(&base, method.clone(), &trace).unwrap();
            let direct = run_scenario(&base, method.clone(), seed).unwrap();
            assert_eq!(shared.chunks.records, direct.chunks.records);
            assert_eq!(shared.routing.records, direct.routing.records);
            assert_eq!(shared.peak_act_bytes, direct.peak_act_bytes);
            assert_eq!(shared.oom_iterations, direct.oom_iterations);
            assert_eq!(shared.avg_tgs, direct.avg_tgs);
        }
    }

    #[test]
    fn run_on_trace_rejects_mismatched_trace() {
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 8;
        let mut probe = base.clone();
        probe.seed = 11;
        // trace too short for the run
        let mut short = probe.clone();
        short.iterations = 4;
        let trace = Simulator::new(short).unwrap().draw_trace();
        assert!(run_scenario_on_trace(&base, Method::FullRecompute, &trace).is_err());
        // trace drawn for a different model shape
        let mut other = paper_run(model_ii(), Method::FullRecompute);
        other.iterations = 8;
        other.seed = 11;
        let trace_ii = Simulator::new(other).unwrap().draw_trace();
        assert!(run_scenario_on_trace(&base, Method::FullRecompute, &trace_ii).is_err());
        // trace drawn under a different EP width (same layer counts —
        // the per-rank statistics still belong to the wrong topology)
        let mut narrow = probe.clone();
        narrow.parallel.ep = 16;
        let trace_ep = Simulator::new(narrow).unwrap().draw_trace();
        assert!(run_scenario_on_trace(&base, Method::FullRecompute, &trace_ep).is_err());
    }

    #[test]
    fn routing_trace_covers_moe_layers() {
        let o = outcome(model_i(), Method::FullRecompute);
        // 13 MoE layers × 20 iterations
        assert_eq!(o.routing.records.len(), 13 * 20);
        assert!(o.routing.peak_recv() > 0);
    }
}
