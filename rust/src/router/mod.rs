//! Routing simulation: the stochastic token-to-expert process that
//! drives every memory/throughput experiment.
//!
//! The paper's Fig. 2 shows the phenomenology this module reproduces:
//! with drop-free top-k routing, deep layers develop strongly
//! non-uniform expert popularity, and during early-training iterations
//! (~5–15) the distribution is most chaotic — the max tokens received
//! by one rank approaches the theoretical peak `e·s·b·t_k` while other
//! ranks receive almost nothing. After ~10+ iterations the router
//! stabilises (Fig. 5 discussion).
//!
//! Model: per (iteration, layer) the expert popularity vector is drawn
//! from `Dirichlet(α·1)` where the concentration α shrinks with layer
//! depth and follows a chaos schedule over iterations. Token copies
//! (`e·s·b` tokens × `t_k` choices) are then multinomially assigned.
//! All draws are seeded forks — identical traces for identical seeds.

use crate::config::{ModelConfig, ParallelConfig};
use crate::trace::provenance::{RngVersion, RouterSampler};
use crate::util::rng::{self, Rng};
use crate::util::stats::Summary;

pub mod baselines;

/// v2 key salts separating the popularity and token-assignment
/// streams: under the counter-based generator the two draw families of
/// a (seed, iteration, layer) site live under distinct Philox keys
/// (`[seed, SALT]`), the v2 analogue of v1's `seed ^ 0x5EED_0001`
/// routing-seed split. Stable forever — they are part of what
/// `rng_version: 2` means.
const RNG2_POPULARITY_SALT: u64 = 0x4D46_504F_5055_4C41; // "MFPOPULA"
const RNG2_ROUTE_SALT: u64 = 0x4D46_524F_5554_4531; // "MFROUTE1"

/// Parameters of the imbalance process. Defaults are calibrated so the
/// Fig. 2-style trace at iteration 7 reaches ~50–65 % of the
/// theoretical peak on the deepest layers (paper: "approaching the
/// theoretical peak").
#[derive(Clone, Debug)]
pub struct GatingParams {
    /// Baseline Dirichlet concentration for layer 0 at a calm
    /// iteration. Larger ⇒ more uniform.
    pub base_alpha: f64,
    /// How much depth sharpens imbalance: α is divided by
    /// `1 + depth_slope · (layer / max(1, L-1))`.
    pub depth_slope: f64,
    /// Center of the early-training chaos bump (iterations).
    pub chaos_peak_iter: f64,
    /// Width (std dev) of the chaos bump.
    pub chaos_width: f64,
    /// Peak multiplier of imbalance intensity at the bump.
    pub chaos_gain: f64,
    /// Intensity decay rate after stabilisation begins.
    pub stabilize_rate: f64,
}

impl Default for GatingParams {
    fn default() -> Self {
        GatingParams {
            base_alpha: 0.55,
            depth_slope: 9.0,
            chaos_peak_iter: 8.0,
            chaos_width: 4.5,
            chaos_gain: 10.0,
            stabilize_rate: 0.12,
        }
    }
}

/// The routing process for one training job.
#[derive(Clone, Debug)]
pub struct GatingSim {
    pub model: ModelConfig,
    pub parallel: ParallelConfig,
    /// Private so it can only change through
    /// [`GatingSim::with_params`], which rebuilds `layer_depth` —
    /// direct mutation would silently leave the cache stale.
    params: GatingParams,
    seed: u64,
    /// Per-layer cache of the depth component of the imbalance
    /// intensity (`1 + slope·(l/(L-1))²`): it depends only on the layer
    /// and the gating params, so the trace generator computes it once
    /// per job instead of once per (iteration, layer) draw.
    layer_depth: Vec<f64>,
    /// Which multinomial assigns token copies
    /// ([`crate::util::rng::Rng::multinomial`] vs
    /// [`Rng::multinomial_split`]). Same distribution, different
    /// stream consumption — two equally valid samples, so the choice
    /// is part of every trace identity ([`RouterSampler`] provenance).
    /// [`GatingSim::new`] starts **sequential** (the low-level API
    /// keeps its historical bits); the sweep engine sets its own
    /// default — split, since the trace-store PR — explicitly via
    /// [`GatingSim::with_sampler`].
    sampler: RouterSampler,
    /// Which generator draws the streams: v1 (sequential xoshiro
    /// forks — the default, the historical bits) or v2 (counter-based
    /// Philox — O(1) stream access, lane-oblivious wide draws). Like
    /// the sampler, part of every trace identity.
    rng: RngVersion,
}

/// Reusable draw buffers for the trace-generation hot loop: the
/// probability vector, per-expert counts and per-rank counts of one
/// routing draw. [`GatingSim::route`] allocates all three per call;
/// [`GatingSim::route_stats`] fills these instead, so a cell's whole
/// trace reuses one set of buffers across every (iteration, layer).
#[derive(Clone, Debug)]
pub struct RouteScratch {
    probs: Vec<f64>,
    per_expert: Vec<u64>,
    per_rank: Vec<u64>,
}

impl RouteScratch {
    /// Buffers shaped for the given job (n_experts / ep).
    pub fn new(model: &ModelConfig, parallel: &ParallelConfig) -> Self {
        RouteScratch {
            probs: vec![0.0; model.n_experts as usize],
            per_expert: vec![0; model.n_experts as usize],
            per_rank: vec![0; parallel.ep as usize],
        }
    }

    /// Per-rank received counts of the most recent draw.
    pub fn per_rank(&self) -> &[u64] {
        &self.per_rank
    }
}

/// Per-layer routing outcome for one iteration.
#[derive(Clone, Debug)]
pub struct LayerRouting {
    /// Token copies received by each expert (len = n_experts).
    pub per_expert: Vec<u64>,
    /// Token copies received by each EP rank (len = ep).
    pub per_rank: Vec<u64>,
}

impl LayerRouting {
    /// `s''` of the hottest rank — the input to MACT (Eq. 9).
    pub fn max_received(&self) -> u64 {
        self.per_rank.iter().copied().max().unwrap_or(0)
    }

    pub fn min_received(&self) -> u64 {
        self.per_rank.iter().copied().min().unwrap_or(0)
    }

    pub fn summary(&self) -> Summary {
        Summary::from_iter(self.per_rank.iter().map(|&c| c as f64))
    }
}

/// Depth factors for every layer — exactly the expression the
/// per-draw path historically evaluated, hoisted to construction time.
fn depth_cache(model: &ModelConfig, params: &GatingParams) -> Vec<f64> {
    (0..model.layers)
        .map(|layer| {
            let l_frac = if model.layers <= 1 {
                0.0
            } else {
                layer as f64 / (model.layers - 1) as f64
            };
            1.0 + params.depth_slope * l_frac * l_frac
        })
        .collect()
}

impl GatingSim {
    pub fn new(model: ModelConfig, parallel: ParallelConfig, seed: u64) -> Self {
        let params = GatingParams::default();
        let layer_depth = depth_cache(&model, &params);
        GatingSim {
            model,
            parallel,
            params,
            seed,
            layer_depth,
            sampler: RouterSampler::Sequential,
            rng: RngVersion::V1,
        }
    }

    pub fn with_params(mut self, params: GatingParams) -> Self {
        self.layer_depth = depth_cache(&self.model, &params);
        self.params = params;
        self
    }

    /// Select the token-assignment sampler. Identical distribution and
    /// determinism guarantees either way, different bit-stream: traces
    /// drawn under the two samplers are two different (equally valid)
    /// samples, so the choice is part of the scenario identity in
    /// checkpointed sweeps ([`crate::trace::TraceProvenance`]).
    pub fn with_sampler(mut self, sampler: RouterSampler) -> Self {
        self.sampler = sampler;
        self
    }

    /// In-place form of [`GatingSim::with_sampler`].
    pub fn set_sampler(&mut self, sampler: RouterSampler) {
        self.sampler = sampler;
    }

    /// The sampler traces are drawn with.
    pub fn sampler(&self) -> RouterSampler {
        self.sampler
    }

    /// Historical bool form of [`GatingSim::with_sampler`]
    /// (`true` = splitting multinomial).
    pub fn with_fast_multinomial(self, on: bool) -> Self {
        self.with_sampler(RouterSampler::from_fast_flag(on))
    }

    /// Select the generator version the streams are drawn with
    /// (default v1, the historical bits). v2 is a different (equally
    /// valid) sample, recorded in provenance like the sampler.
    pub fn with_rng(mut self, rng: RngVersion) -> Self {
        self.rng = rng;
        self
    }

    /// In-place form of [`GatingSim::with_rng`].
    pub fn set_rng(&mut self, rng: RngVersion) {
        self.rng = rng;
    }

    /// The generator version streams are drawn with.
    pub fn rng(&self) -> RngVersion {
        self.rng
    }

    /// The job seed the trace streams derive from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The gating parameters in effect (set via
    /// [`GatingSim::with_params`]).
    pub fn params(&self) -> &GatingParams {
        &self.params
    }

    /// Imbalance intensity ≥ 1 for (iteration, layer); α = base/intensity.
    fn intensity(&self, iteration: u64, layer: u64) -> f64 {
        let p = &self.params;
        let depth = self.layer_depth[layer as usize];
        let it = iteration as f64;
        let bump = ((it - p.chaos_peak_iter) / p.chaos_width).powi(2);
        let chaos = 1.0 + p.chaos_gain * (-0.5 * bump).exp();
        // Post-bump stabilisation: intensity decays toward 1.
        let settle = if it > p.chaos_peak_iter {
            (-(it - p.chaos_peak_iter) * p.stabilize_rate).exp()
        } else {
            1.0
        };
        1.0 + (depth * chaos - 1.0) * settle.max(0.05)
    }

    /// Expert popularity vector for (iteration, layer): Dirichlet draw
    /// with depth/iteration-dependent concentration. Dense layers
    /// (`layer < dense_layers`) return a uniform vector (no routing).
    /// Delegates to [`GatingSim::expert_popularity_into`], so the
    /// allocating and buffer-reusing paths are one implementation.
    pub fn expert_popularity(&self, iteration: u64, layer: u64) -> Vec<f64> {
        let mut out = vec![0.0; self.model.n_experts as usize];
        self.expert_popularity_into(iteration, layer, &mut out);
        out
    }

    /// Total token copies entering every MoE layer per micro-batch
    /// across the EP group: `e · s · b · t_k`.
    pub fn total_copies(&self) -> u64 {
        self.parallel.ep
            * self.model.seq
            * self.parallel.micro_batch
            * self.model.top_k
    }

    /// Buffer-filling form of [`GatingSim::expert_popularity`] (which
    /// delegates here): same forked stream, same batched-gamma
    /// Dirichlet, no allocation. `out.len()` must be `n_experts`.
    pub fn expert_popularity_into(&self, iteration: u64, layer: u64, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.model.n_experts as usize);
        if layer < self.model.dense_layers {
            let e_n = out.len() as f64;
            out.fill(1.0 / e_n);
            return;
        }
        let alpha = (self.params.base_alpha / self.intensity(iteration, layer))
            .max(1e-3);
        match self.rng {
            RngVersion::V1 => {
                let mut rng = Rng::new(self.seed)
                    .fork(iteration.wrapping_mul(1_000_003).wrapping_add(layer));
                rng.dirichlet_symmetric_into(alpha, out);
            }
            RngVersion::V2 => rng::dirichlet_symmetric2(
                [self.seed, RNG2_POPULARITY_SALT],
                [iteration, layer],
                alpha,
                out,
            ),
        }
    }

    /// Route one (iteration, layer): returns per-expert and per-rank
    /// received counts. Conservation: counts sum to `total_copies()`.
    pub fn route(&self, iteration: u64, layer: u64) -> LayerRouting {
        let probs = self.expert_popularity(iteration, layer);
        let mut per_expert = vec![0u64; probs.len()];
        self.assign_tokens(iteration, layer, &probs, &mut per_expert);
        let per_rank = per_rank_from_experts(&per_expert, self.parallel.ep);
        LayerRouting { per_expert, per_rank }
    }

    /// The token-assignment multinomial shared by [`GatingSim::route`]
    /// and [`GatingSim::route_stats`]: one implementation per (rng,
    /// sampler) pair, so the two call paths cannot drift apart.
    fn assign_tokens(&self, iteration: u64, layer: u64, probs: &[f64], out: &mut [u64]) {
        let n = self.total_copies();
        match self.rng {
            RngVersion::V1 => {
                let mut rng = Rng::new(self.seed ^ 0x5EED_0001)
                    .fork(iteration.wrapping_mul(7_368_787).wrapping_add(layer));
                match self.sampler {
                    RouterSampler::Split => rng.multinomial_split_into(n, probs, out),
                    RouterSampler::Sequential => rng.multinomial_into(n, probs, out),
                }
            }
            RngVersion::V2 => {
                let key = [self.seed, RNG2_ROUTE_SALT];
                let site = [iteration, layer];
                match self.sampler {
                    RouterSampler::Split => {
                        rng::multinomial_split_into2(key, site, n, probs, out)
                    }
                    RouterSampler::Sequential => {
                        rng::multinomial_into2(key, site, n, probs, out)
                    }
                }
            }
        }
    }

    /// The trace generator's form of [`GatingSim::route`]: the same
    /// draw through caller-owned scratch buffers, reduced straight to
    /// the per-(iteration, layer) statistics `(min_recv, mean_recv,
    /// max_recv)` the [`crate::trace::SharedRoutingTrace`] records.
    /// Bit-identical to `route()` + `min_received()/summary().mean()/
    /// max_received()` — only the allocations differ, which the
    /// trace-level tests pin.
    pub fn route_stats(
        &self,
        iteration: u64,
        layer: u64,
        scratch: &mut RouteScratch,
    ) -> (u64, f64, u64) {
        self.expert_popularity_into(iteration, layer, &mut scratch.probs);
        self.assign_tokens(iteration, layer, &scratch.probs, &mut scratch.per_expert);
        per_rank_from_experts_into(&scratch.per_expert, &mut scratch.per_rank);
        // same reductions as min_received / Summary::mean / max_received,
        // in the same per-rank order (mean sums f64 left to right)
        debug_assert!(!scratch.per_rank.is_empty());
        let mut min = u64::MAX;
        let mut max = 0u64;
        let mut sum = 0.0f64;
        for &c in &scratch.per_rank {
            min = min.min(c);
            max = max.max(c);
            sum += c as f64;
        }
        (min, sum / scratch.per_rank.len() as f64, max)
    }

    /// Fig. 2 data: per-layer (min, mean, max) received tokens at one
    /// iteration.
    pub fn iteration_profile(&self, iteration: u64) -> Vec<(u64, f64, u64)> {
        (0..self.model.layers)
            .map(|l| {
                let r = self.route(iteration, l);
                let s = r.summary();
                (r.min_received(), s.mean(), r.max_received())
            })
            .collect()
    }
}

/// Sum per-expert counts into per-EP-rank counts (block layout:
/// rank k hosts experts [k·E/ep, (k+1)·E/ep)). Matches Megatron's
/// contiguous expert placement.
pub fn per_rank_from_experts(per_expert: &[u64], ep: u64) -> Vec<u64> {
    let mut out = vec![0u64; ep as usize];
    per_rank_from_experts_into(per_expert, &mut out);
    out
}

/// Buffer-filling form of [`per_rank_from_experts`] (which delegates
/// here): `out.len()` is the EP width.
pub fn per_rank_from_experts_into(per_expert: &[u64], out: &mut [u64]) {
    let ep = out.len() as u64;
    let e_n = per_expert.len() as u64;
    assert!(ep > 0 && e_n % ep == 0, "experts {e_n} not divisible by ep {ep}");
    let per = (e_n / ep) as usize;
    for (slot, chunk) in out.iter_mut().zip(per_expert.chunks(per)) {
        *slot = chunk.iter().sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, paper_parallel};

    fn sim() -> GatingSim {
        GatingSim::new(model_i(), paper_parallel(), 7)
    }

    #[test]
    fn conservation_every_layer() {
        let s = sim();
        for layer in [0, 3, 8, 15] {
            let r = s.route(7, layer);
            assert_eq!(r.per_expert.iter().sum::<u64>(), s.total_copies());
            assert_eq!(r.per_rank.iter().sum::<u64>(), s.total_copies());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = sim().route(7, 10);
        let b = sim().route(7, 10);
        assert_eq!(a.per_expert, b.per_expert);
        let c = GatingSim::new(model_i(), paper_parallel(), 8).route(7, 10);
        assert_ne!(a.per_expert, c.per_expert);
    }

    #[test]
    fn dense_layers_route_uniformly() {
        let s = sim();
        let p = s.expert_popularity(7, 0); // layer 0 is dense (d_l = 3)
        let first = p[0];
        assert!(p.iter().all(|&x| (x - first).abs() < 1e-12));
    }

    #[test]
    fn depth_increases_imbalance() {
        // Fig. 2: deeper layers more imbalanced. Compare CV of received
        // tokens at a shallow vs deep MoE layer, averaged over seeds.
        let (mut shallow, mut deep) = (0.0, 0.0);
        for seed in 0..10 {
            let s = GatingSim::new(model_i(), paper_parallel(), seed);
            shallow += s.route(7, 3).summary().cv();
            deep += s.route(7, 15).summary().cv();
        }
        assert!(deep > shallow, "deep {deep:.2} <= shallow {shallow:.2}");
    }

    #[test]
    fn chaos_bump_then_stabilise() {
        // Imbalance at iteration ~8 must exceed both iteration 0 and
        // iteration 24 (Fig. 5: stabilises after ~10 iterations).
        let (mut early, mut peak, mut late) = (0.0, 0.0, 0.0);
        for seed in 0..10 {
            let s = GatingSim::new(model_i(), paper_parallel(), seed);
            early += s.route(0, 15).summary().cv();
            peak += s.route(8, 15).summary().cv();
            late += s.route(24, 15).summary().cv();
        }
        assert!(peak > early, "peak {peak:.2} <= early {early:.2}");
        assert!(peak > late, "peak {peak:.2} <= late {late:.2}");
    }

    #[test]
    fn peak_iteration_approaches_theoretical_max() {
        // At the chaos peak the hottest rank should receive a large
        // fraction of all copies on deep layers (Fig. 2's outliers).
        let s = sim();
        let total = s.total_copies() as f64;
        let max_frac = (5..=15)
            .map(|l| s.route(7, l).max_received() as f64 / total)
            .fold(0.0, f64::max);
        assert!(max_frac > 0.35, "max fraction {max_frac:.2} too balanced");
    }

    #[test]
    fn profile_has_layer_rows() {
        let prof = sim().iteration_profile(7);
        assert_eq!(prof.len(), 16);
        for (min, mean, max) in prof {
            assert!(min as f64 <= mean && mean <= max as f64);
        }
    }

    #[test]
    fn per_rank_block_layout() {
        let per_expert = vec![1, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(per_rank_from_experts(&per_expert, 4), vec![3, 7, 11, 15]);
        assert_eq!(per_rank_from_experts(&per_expert, 2), vec![10, 26]);
    }

    #[test]
    #[should_panic]
    fn per_rank_requires_divisibility() {
        per_rank_from_experts(&[1, 2, 3], 2);
    }

    #[test]
    fn total_copies_matches_paper() {
        assert_eq!(sim().total_copies(), 32 * 4096 * 8);
    }

    #[test]
    fn sampler_selection_and_historical_default() {
        use crate::trace::provenance::RouterSampler;
        // the low-level constructor keeps the historical sequential
        // bits; with_sampler/with_fast_multinomial agree
        let s = sim();
        assert_eq!(s.sampler(), RouterSampler::Sequential);
        let fast = sim().with_sampler(RouterSampler::Split);
        assert_eq!(fast.sampler(), RouterSampler::Split);
        assert_eq!(
            fast.route(7, 10).per_expert,
            sim().with_fast_multinomial(true).route(7, 10).per_expert
        );
        let mut inplace = sim();
        inplace.set_sampler(RouterSampler::Split);
        assert_eq!(inplace.route(7, 10).per_expert, fast.route(7, 10).per_expert);
        // and the two samplers really are different samples
        assert_ne!(fast.route(7, 10).per_expert, s.route(7, 10).per_expert);
    }

    #[test]
    fn fast_multinomial_conserves_and_is_deterministic() {
        let fast = sim().with_fast_multinomial(true);
        for layer in [3, 10, 15] {
            let r = fast.route(7, layer);
            assert_eq!(r.per_expert.iter().sum::<u64>(), fast.total_copies());
            assert_eq!(r.per_rank.iter().sum::<u64>(), fast.total_copies());
        }
        let a = fast.route(8, 12);
        let b = sim().with_fast_multinomial(true).route(8, 12);
        assert_eq!(a.per_expert, b.per_expert);
    }

    #[test]
    fn fast_multinomial_same_popularity_same_imbalance_regime() {
        // The fast sampler assigns tokens over the *same* popularity
        // vector (popularity is drawn before the sampler runs), so the
        // imbalance regime matches the default path even though the
        // individual draw differs.
        let (mut slow_cv, mut fast_cv) = (0.0, 0.0);
        for seed in 0..10 {
            let s = GatingSim::new(model_i(), paper_parallel(), seed);
            let f = GatingSim::new(model_i(), paper_parallel(), seed)
                .with_fast_multinomial(true);
            slow_cv += s.route(7, 15).summary().cv();
            fast_cv += f.route(7, 15).summary().cv();
        }
        let ratio = fast_cv / slow_cv;
        assert!(
            (0.5..2.0).contains(&ratio),
            "imbalance regimes diverged: slow {slow_cv:.2} fast {fast_cv:.2}"
        );
    }

    #[test]
    fn route_stats_bit_identical_to_route_under_both_samplers() {
        // The buffered trace-generation path must reproduce route()'s
        // statistics exactly — min/max as u64, mean to the bit — and a
        // dirty reused scratch must not leak between draws.
        for fast in [false, true] {
            let s = sim().with_fast_multinomial(fast);
            let mut scratch = RouteScratch::new(&s.model, &s.parallel);
            for (it, layer) in [(0u64, 3u64), (7, 10), (7, 15), (24, 8)] {
                let r = s.route(it, layer);
                let (min, mean, max) = s.route_stats(it, layer, &mut scratch);
                assert_eq!(min, r.min_received(), "fast={fast} it={it} l={layer}");
                assert_eq!(max, r.max_received(), "fast={fast} it={it} l={layer}");
                assert_eq!(
                    mean.to_bits(),
                    r.summary().mean().to_bits(),
                    "fast={fast} it={it} l={layer}"
                );
                assert_eq!(scratch.per_rank(), r.per_rank.as_slice());
            }
        }
    }

    #[test]
    fn expert_popularity_into_matches_allocating_path() {
        let s = sim();
        let mut buf = vec![9.9; s.model.n_experts as usize];
        for (it, layer) in [(0u64, 0u64), (7, 3), (7, 15)] {
            s.expert_popularity_into(it, layer, &mut buf);
            assert_eq!(buf, s.expert_popularity(it, layer), "it={it} l={layer}");
        }
    }

    #[test]
    fn rng_v2_selection_and_distinct_sample() {
        // default is v1 (the historical bits)...
        let v1 = sim();
        assert_eq!(v1.rng(), RngVersion::V1);
        // ...and v2 is a different deterministic sample of the same
        // conserving process
        let v2 = sim().with_rng(RngVersion::V2);
        assert_eq!(v2.rng(), RngVersion::V2);
        let a = v2.route(7, 10);
        assert_eq!(a.per_expert.iter().sum::<u64>(), v2.total_copies());
        assert_ne!(a.per_expert, v1.route(7, 10).per_expert);
        let b = sim().with_rng(RngVersion::V2).route(7, 10);
        assert_eq!(a.per_expert, b.per_expert);
        let mut inplace = sim();
        inplace.set_rng(RngVersion::V2);
        assert_eq!(inplace.route(7, 10).per_expert, a.per_expert);
        // seed sensitivity under v2
        let other = GatingSim::new(model_i(), paper_parallel(), 8).with_rng(RngVersion::V2);
        assert_ne!(other.route(7, 10).per_expert, a.per_expert);
    }

    #[test]
    fn rng_v2_popularity_is_a_simplex_and_site_sensitive() {
        let v2 = sim().with_rng(RngVersion::V2);
        let p = v2.expert_popularity(7, 10);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert_ne!(p, v2.expert_popularity(7, 11));
        assert_ne!(p, v2.expert_popularity(8, 10));
        // dense layers stay uniform under every rng version
        let d = v2.expert_popularity(7, 0);
        assert!(d.iter().all(|&x| (x - d[0]).abs() < 1e-12));
    }

    #[test]
    fn rng_v2_route_stats_bit_identical_to_route_under_both_samplers() {
        // The trace-generation path must match route() under v2 too —
        // same invariant the v1 path pins, now over counter streams.
        for sampler in [RouterSampler::Sequential, RouterSampler::Split] {
            let s = sim().with_sampler(sampler).with_rng(RngVersion::V2);
            let mut scratch = RouteScratch::new(&s.model, &s.parallel);
            for (it, layer) in [(0u64, 3u64), (7, 10), (7, 15), (24, 8)] {
                let r = s.route(it, layer);
                let (min, mean, max) = s.route_stats(it, layer, &mut scratch);
                assert_eq!(min, r.min_received(), "{sampler:?} it={it} l={layer}");
                assert_eq!(max, r.max_received(), "{sampler:?} it={it} l={layer}");
                assert_eq!(
                    mean.to_bits(),
                    r.summary().mean().to_bits(),
                    "{sampler:?} it={it} l={layer}"
                );
            }
        }
    }

    #[test]
    fn rng_v2_same_imbalance_regime_as_v1() {
        // v2 draws the same Dirichlet/multinomial process, so the
        // imbalance statistics must land in the same regime even
        // though the individual bits differ.
        let (mut v1_cv, mut v2_cv) = (0.0, 0.0);
        for seed in 0..10 {
            v1_cv += GatingSim::new(model_i(), paper_parallel(), seed)
                .route(7, 15)
                .summary()
                .cv();
            v2_cv += GatingSim::new(model_i(), paper_parallel(), seed)
                .with_rng(RngVersion::V2)
                .route(7, 15)
                .summary()
                .cv();
        }
        let ratio = v2_cv / v1_cv;
        assert!(
            (0.5..2.0).contains(&ratio),
            "imbalance regimes diverged: v1 {v1_cv:.2} v2 {v2_cv:.2}"
        );
    }

    #[test]
    fn depth_cache_matches_direct_formula() {
        let s = sim();
        let p = GatingParams::default();
        for layer in 0..16u64 {
            let l_frac = layer as f64 / 15.0;
            let want = 1.0 + p.depth_slope * l_frac * l_frac;
            assert_eq!(s.layer_depth[layer as usize], want);
        }
    }
}
