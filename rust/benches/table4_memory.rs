//! `cargo bench --bench table4_memory` — regenerates the paper's
//! Table 4 (memory comparison of Methods 1/2/3 on Models I/II) and
//! times the full simulation pipeline that produces it.
//!
//! Expected shape (paper): Method 1 OOMs on Model I; fixed c=8 cuts
//! activation ~84 %; MACT cuts ~48 % and keeps the best throughput.

use memfine::bench::{fmt_time, time_fn};
use memfine::config::{model_i, paper_run, Method};
use memfine::sim::{repro, Simulator};

fn main() {
    memfine::logging::init();
    repro::table4(7).expect("table4 repro");

    // Timing: a full 25-iteration Model-I MACT simulation.
    let t = time_fn("simulate model-I mact 25 iters", 1, 5, || {
        let mut run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        run.iterations = 25;
        Simulator::new(run).unwrap().run_all().peak_act_bytes
    });
    println!(
        "\n[bench] {}: median {} (p10 {} / p90 {})",
        t.name,
        fmt_time(t.median_s),
        fmt_time(t.p10_s),
        fmt_time(t.p90_s)
    );
}
