//! End-to-end determinism contract of the sweep engine: a 3-method ×
//! 2-model × 4-seed grid (24 scenarios) run with 1 worker, with 8
//! workers, as two checkpointed shards merged by a resume run, and as
//! a killed-then-resumed sweep must all produce **bit-identical**
//! aggregated JSON — thread count, scheduling order, shard splits and
//! resume points are not allowed to leak into results.

use std::path::PathBuf;

use memfine::config::{derive_seeds, Method, ShardSpec, SweepConfig};
use memfine::sweep::{self, SweepRunOptions};

fn grid_3x2x4() -> SweepConfig {
    SweepConfig {
        models: vec!["i".into(), "ii".into()],
        methods: vec![
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ],
        seeds: derive_seeds(7, 4),
        iterations: 10,
    }
}

/// Unique scratch path in the OS temp dir (tests run in one process,
/// so pid + name is enough).
fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("memfine-it-sweep-{}-{name}", std::process::id()));
    p
}

#[test]
fn sweep_json_bit_identical_across_worker_counts() {
    let cfg = grid_3x2x4();
    assert_eq!(cfg.scenario_count(), 24);

    let serial = sweep::run_sweep(&cfg, 1).expect("serial sweep");
    let parallel = sweep::run_sweep(&cfg, 8).expect("parallel sweep");

    let json_1 = serial.to_json().to_string_pretty();
    let json_8 = parallel.to_json().to_string_pretty();
    assert_eq!(json_1, json_8, "worker count changed the sweep artifact");

    // the same holds compactly serialised and structurally
    assert_eq!(
        serial.to_json().to_string_compact(),
        parallel.to_json().to_string_compact()
    );
    assert_eq!(serial.scenarios, parallel.scenarios);
    assert_eq!(serial.cells, parallel.cells);
}

#[test]
fn sweep_json_bit_identical_across_shard_merge() {
    let cfg = grid_3x2x4();
    let direct = sweep::run_sweep(&cfg, 8).expect("direct sweep");
    let direct_json = direct.to_json().to_string_pretty();

    // two shard runs, each checkpointing its half of the grid
    let shard0 = tmp("shard0.jsonl");
    let shard1 = tmp("shard1.jsonl");
    for (index, path) in [(0u64, &shard0), (1u64, &shard1)] {
        let opts = SweepRunOptions {
            workers: 4,
            checkpoint: vec![path.clone()],
            shard: Some(ShardSpec { index, count: 2 }),
            ..Default::default()
        };
        let summary = sweep::run_sweep_with(&cfg, &opts).expect("shard sweep");
        assert_eq!(summary.executed, 12, "shard {index} owns half the grid");
        assert_eq!(summary.skipped, 12);
        // the shard's own artifact is the partial grid it ran
        assert_eq!(summary.report.scenarios.len(), 12);
    }

    // merge: a resume run reading both shard files finds every
    // scenario done and emits the full artifact — byte-identical to
    // the direct run
    let merge = SweepRunOptions {
        workers: 4,
        checkpoint: vec![shard0.clone(), shard1.clone()],
        resume: true,
        ..Default::default()
    };
    let merged = sweep::run_sweep_with(&cfg, &merge).expect("merge sweep");
    assert_eq!(merged.resumed, 24);
    assert_eq!(merged.executed, 0);
    assert_eq!(
        merged.report.to_json().to_string_pretty(),
        direct_json,
        "2-shard merge changed the artifact"
    );
    std::fs::remove_file(&shard0).ok();
    std::fs::remove_file(&shard1).ok();
}

#[test]
fn killed_sweep_resumes_to_identical_bytes() {
    let cfg = grid_3x2x4();
    let direct_json = sweep::run_sweep(&cfg, 1)
        .expect("direct sweep")
        .to_json()
        .to_string_pretty();

    // run the first 7 scenarios with checkpointing, as if the sweep
    // was killed mid-grid
    let ck = tmp("kill.jsonl");
    let first = SweepRunOptions {
        workers: 2,
        checkpoint: vec![ck.clone()],
        limit: Some(7),
        ..Default::default()
    };
    let killed = sweep::run_sweep_with(&cfg, &first).expect("limited sweep");
    assert_eq!(killed.executed, 7);

    // make the kill realistic: tear the final checkpoint line in half,
    // as if the process died mid-write
    let text = std::fs::read_to_string(&ck).expect("checkpoint readable");
    let mut lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 8); // provenance header + 7 records
    let last = lines.pop().expect("has lines");
    let torn = format!("{}\n{}", lines.join("\n"), &last[..last.len() / 2]);
    std::fs::write(&ck, torn).expect("tear checkpoint");

    // resume: 6 intact lines fold from the checkpoint, the torn line's
    // scenario re-runs with the other 17
    let resume = SweepRunOptions {
        workers: 8,
        checkpoint: vec![ck.clone()],
        resume: true,
        ..Default::default()
    };
    let resumed = sweep::run_sweep_with(&cfg, &resume).expect("resumed sweep");
    assert_eq!(resumed.resumed, 6);
    assert_eq!(resumed.executed, 18);
    assert_eq!(resumed.skipped_checkpoint_lines, 1);
    assert_eq!(
        resumed.report.to_json().to_string_pretty(),
        direct_json,
        "kill-and-resume changed the artifact"
    );

    // the resumed run completed the checkpoint: a third run has
    // nothing left to execute
    let third = sweep::run_sweep_with(&cfg, &resume).expect("third sweep");
    assert_eq!(third.resumed, 24);
    assert_eq!(third.executed, 0);
    assert_eq!(third.report.to_json().to_string_pretty(), direct_json);
    std::fs::remove_file(&ck).ok();
}

#[test]
fn resumed_capped_slices_always_make_limit_progress() {
    // `--limit` budgets *newly executed* scenarios only: resumed rows
    // folded from the checkpoint never count against it, so a capped
    // campaign (`--resume --limit N` in a loop) always advances by
    // min(N, remaining) per slice and terminates. This pins the
    // documented SweepRunOptions::limit contract against regressions.
    let cfg = grid_3x2x4();
    let direct_json = sweep::run_sweep(&cfg, 2)
        .expect("direct sweep")
        .to_json()
        .to_string_pretty();
    let ck = tmp("capped.jsonl");

    // first slice creates the checkpoint
    let first = SweepRunOptions {
        workers: 2,
        checkpoint: vec![ck.clone()],
        limit: Some(9),
        ..Default::default()
    };
    let s = sweep::run_sweep_with(&cfg, &first).expect("first slice");
    assert_eq!(s.executed, 9);
    assert_eq!(s.skipped, 15);

    // every later slice resumes and must execute exactly
    // min(limit, remaining) — never less because of resumed rows
    let mut done = 9;
    while done < 24 {
        let slice = SweepRunOptions {
            workers: 2,
            checkpoint: vec![ck.clone()],
            resume: true,
            limit: Some(9),
            ..Default::default()
        };
        let s = sweep::run_sweep_with(&cfg, &slice).expect("capped slice");
        assert_eq!(s.resumed, done, "slice must fold all prior work");
        assert_eq!(s.executed, 9.min(24 - done), "capped slice must make full progress");
        done += s.executed;
    }
    assert_eq!(done, 24);

    // the finished checkpoint folds to the direct artifact
    let merge = SweepRunOptions {
        workers: 4,
        checkpoint: vec![ck.clone()],
        resume: true,
        ..Default::default()
    };
    let merged = sweep::run_sweep_with(&cfg, &merge).expect("final fold");
    assert_eq!(merged.executed, 0);
    assert_eq!(merged.resumed, 24);
    assert_eq!(
        merged.report.to_json().to_string_pretty(),
        direct_json,
        "capped campaign changed the artifact"
    );
    std::fs::remove_file(&ck).ok();
}

#[test]
fn trace_cached_shard_merge_is_byte_identical() {
    // The trace cache composes with sharding and resume: two cached
    // shard runs plus a warm-cache merge must emit the direct
    // (uncached, unsharded) artifact byte for byte — and the merge
    // pass, which executes nothing, reuses every cached cell it owns.
    let cfg = grid_3x2x4();
    let direct_json = sweep::run_sweep(&cfg, 4)
        .expect("direct sweep")
        .to_json()
        .to_string_pretty();
    let cache = tmp("trace-cache-dir");
    std::fs::remove_dir_all(&cache).ok();
    let ck0 = tmp("cached-shard0.jsonl");
    let ck1 = tmp("cached-shard1.jsonl");
    for (index, path) in [(0u64, &ck0), (1u64, &ck1)] {
        let opts = SweepRunOptions {
            workers: 2,
            checkpoint: vec![path.clone()],
            shard: Some(ShardSpec { index, count: 2 }),
            trace_cache: Some(cache.clone()),
            ..Default::default()
        };
        let s = sweep::run_sweep_with(&cfg, &opts).expect("cached shard");
        // every owned cell was cold this first time around
        assert_eq!(s.traces_cached, 0);
        assert!(s.traces_generated > 0);
    }
    let merge = SweepRunOptions {
        workers: 2,
        checkpoint: vec![ck0.clone(), ck1.clone()],
        resume: true,
        trace_cache: Some(cache.clone()),
        ..Default::default()
    };
    let merged = sweep::run_sweep_with(&cfg, &merge).expect("merge");
    assert_eq!(merged.resumed, 24);
    assert_eq!(merged.executed, 0);
    assert_eq!(
        merged.report.to_json().to_string_pretty(),
        direct_json,
        "cached shard merge diverged from the direct artifact"
    );
    // a fresh full run over the warm cache re-executes everything from
    // cached traces and still matches
    let warm = SweepRunOptions {
        workers: 4,
        trace_cache: Some(cache.clone()),
        ..Default::default()
    };
    let warm_run = sweep::run_sweep_with(&cfg, &warm).expect("warm full run");
    assert_eq!(warm_run.traces_generated, 0);
    assert_eq!(warm_run.traces_cached, 8); // 2 models × 4 seeds cells
    assert_eq!(
        warm_run.report.to_json().to_string_pretty(),
        direct_json,
        "warm-cache full run diverged from the direct artifact"
    );
    std::fs::remove_dir_all(&cache).ok();
    std::fs::remove_file(&ck0).ok();
    std::fs::remove_file(&ck1).ok();
}

#[test]
fn pool_schedule_channel_and_pinning_never_perturb_artifacts() {
    // The acceptance grid of the work-stealing runtime: {stealing,
    // injector} × {1, 8 workers} × {pinned, unpinned} (both channel
    // backends covered across the cells) must all emit the serial
    // run's exact bytes — scheduling, backpressure, and core affinity
    // are execution knobs, never identity.
    use memfine::sweep::{ChannelKind, Schedule};
    let cfg = grid_3x2x4();
    let direct_json = sweep::run_sweep(&cfg, 1)
        .expect("direct sweep")
        .to_json()
        .to_string_pretty();
    for schedule in [Schedule::Stealing, Schedule::Injector] {
        for workers in [1usize, 8] {
            for pin_cores in [false, true] {
                // alternate the channel backend across the grid so
                // both carry real traffic in this test
                let channel = if workers == 8 && pin_cores {
                    ChannelKind::StdMpsc
                } else {
                    ChannelKind::Bounded
                };
                let opts = SweepRunOptions {
                    workers,
                    pool: schedule,
                    channel,
                    pin_cores,
                    ..Default::default()
                };
                let run = sweep::run_sweep_with(&cfg, &opts).expect("pool-knob sweep");
                assert_eq!(
                    run.report.to_json().to_string_pretty(),
                    direct_json,
                    "{}/{} workers={workers} pinned={pin_cores} changed the artifact",
                    schedule.tag(),
                    channel.tag(),
                );
                assert_eq!(run.pool.jobs_total() as usize, 8); // 2 models × 4 seeds cells
                assert_eq!(run.pool.schedule, schedule);
            }
        }
    }
}

#[test]
fn sweep_artifact_reparses_and_covers_grid() {
    let cfg = grid_3x2x4();
    let report = sweep::run_sweep(&cfg, 8).expect("sweep");
    assert_eq!(report.scenarios.len(), 24);
    assert_eq!(report.cells.len(), 6); // 2 models × 3 methods

    // round-trip through the JSON parser: the artifact is valid JSON
    // and the config block reconstructs the input grid.
    let text = report.to_json().to_string_pretty();
    let parsed = memfine::json::parse(&text).expect("artifact parses");
    let cfg_back =
        SweepConfig::from_json(parsed.get("config").expect("config block")).unwrap();
    assert_eq!(cfg_back, cfg);

    // scenario indices are the contiguous grid enumeration
    for (i, s) in report.scenarios.iter().enumerate() {
        assert_eq!(s.index, i);
        assert_eq!(s.iterations, 10);
    }
}

#[test]
fn sweep_reproduces_paper_cell_relations() {
    // The aggregates must reproduce the Table 4 relations on every
    // seed: chunked methods never OOM on Model I, and both chunked
    // methods cut Method 1's activation peak (fixed c=8 the deepest).
    let report = sweep::run_sweep(&grid_3x2x4(), 8).expect("sweep");
    let cell = |model: &str, prefix: &str| {
        report
            .cells
            .iter()
            .find(|c| c.model == model && c.method.starts_with(prefix))
            .unwrap_or_else(|| panic!("missing cell {model}/{prefix}"))
    };
    for model in ["i", "ii"] {
        let m1 = cell(model, "method1");
        let m2 = cell(model, "method2");
        let m3 = cell(model, "method3");
        assert_eq!(m2.trained_runs, m2.runs, "model {model}: method 2 must train");
        assert_eq!(m3.trained_runs, m3.runs, "model {model}: method 3 must train");
        assert!(m2.peak_act_bytes < m1.peak_act_bytes);
        assert!(m3.peak_act_bytes < m1.peak_act_bytes);
        assert!(m2.peak_act_bytes <= m3.peak_act_bytes);
        assert!(m2.act_reduction_vs_m1_pct.unwrap() >= m3.act_reduction_vs_m1_pct.unwrap());
    }
}
