//! Host abstraction for multi-host launches: where a shard child
//! runs, how its host proves liveness, and what happens when a whole
//! machine disappears.
//!
//! The supervisor was already generic over the *spawner*
//! ([`crate::orchestrator::supervise`] takes any
//! `FnMut(&ShardPlan, attempt) -> Result<Child>`); a [`HostPool`]
//! lifts that seam one level: each [`HostSpec`] owns a boxed spawner
//! of the same shape (local `Command` today, an `ssh`-wrapped command
//! for remote hosts, a scripted closure for `SimHost`-style tests),
//! plus a shard→host assignment the supervisor can rewrite when a
//! host is lost.
//!
//! Liveness is a **lease file** per host in the shared campaign dir
//! (`host-<id>.lease`), renewed by bumping a monotone counter and
//! atomically renaming a pid-unique tmp into place. Expiry is
//! *clock-skew tolerant by construction*: the observing
//! [`LeaseMonitor`] never compares wall-clock timestamps across
//! machines — it watches the renewal **counter** for change against
//! its own monotonic clock, so a host whose clock is hours off still
//! holds its lease as long as it keeps renewing, and a dead host
//! expires exactly `timeout` after its last observed renewal no
//! matter what any mtime says.
//!
//! Losing a host is survivable, not fatal: the supervisor reassigns
//! its shards to surviving hosts under the normal retry budgets, and
//! the merge catch-up heals anything the dead host never wrote — the
//! campaign artifact stays byte-identical to a single-process sweep
//! (pinned by the `HostLossSpec` chaos drills).

use std::path::{Path, PathBuf};
use std::process::Child;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::json;
use crate::logging;
use crate::orchestrator::plan::ShardPlan;

/// Where a host's shard children actually execute.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HostKind {
    /// Spawn on this machine (also the `SimHost` vehicle in tests:
    /// a scripted local spawner stands in for the remote side).
    Local,
    /// Spawn through `ssh <target> '<quoted command>'`; the campaign
    /// dir must be shared storage visible to the target.
    Ssh { target: String },
}

/// One host in a launch: a stable id (position-derived, `h0`, `h1`,
/// ...) plus where it runs commands.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostSpec {
    pub id: String,
    pub kind: HostKind,
}

impl HostSpec {
    /// Parse `LaunchConfig.hosts` entries: `"local"` or
    /// `"ssh:user@machine"`. Ids are positional (`h0`..) so a config
    /// edit that reorders hosts renames them — deterministic, and the
    /// lease files say which is which.
    pub fn parse_list(specs: &[String]) -> Result<Vec<HostSpec>> {
        specs
            .iter()
            .enumerate()
            .map(|(i, raw)| {
                let kind = match raw.trim() {
                    "local" => HostKind::Local,
                    s if s.starts_with("ssh:") => {
                        let target = s["ssh:".len()..].trim().to_string();
                        if target.is_empty() {
                            return Err(Error::config(format!(
                                "host spec '{raw}': ssh target is empty"
                            )));
                        }
                        HostKind::Ssh { target }
                    }
                    other => {
                        return Err(Error::config(format!(
                            "unknown host spec '{other}' (local|ssh:<target>)"
                        )))
                    }
                };
                Ok(HostSpec { id: format!("h{i}"), kind })
            })
            .collect()
    }
}

/// Quote one argv word for `sh` on the remote side of an ssh hop.
/// Plain words pass through; anything else is single-quoted with the
/// standard `'\''` escape.
pub fn shell_quote(s: &str) -> String {
    let plain = !s.is_empty()
        && s.bytes().all(|b| {
            b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b'-' | b'/' | b'=' | b':' | b',' | b'@')
        });
    if plain {
        s.to_string()
    } else {
        format!("'{}'", s.replace('\'', "'\\''"))
    }
}

/// Build the `ssh` invocation that runs `argv` (program + args) on
/// `target`, with an optional `VAR=value` environment prefix (ssh
/// does not forward the local environment).
pub fn ssh_command(
    target: &str,
    argv: &[String],
    env: Option<(&str, &str)>,
) -> std::process::Command {
    let mut remote = String::new();
    if let Some((k, v)) = env {
        remote.push_str(k);
        remote.push('=');
        remote.push_str(&shell_quote(v));
        remote.push(' ');
    }
    for (i, a) in argv.iter().enumerate() {
        if i > 0 {
            remote.push(' ');
        }
        remote.push_str(&shell_quote(a));
    }
    let mut cmd = std::process::Command::new("ssh");
    cmd.arg("-oBatchMode=yes").arg(target).arg(remote);
    cmd
}

/// The lease file for `host` inside the campaign dir. The `.lease`
/// extension keeps these out of every campaign-state glob (`*.jsonl`).
pub fn lease_path(dir: &Path, host: &str) -> PathBuf {
    dir.join(format!("host-{host}.lease"))
}

/// Writer side of one host lease: a renewal counter persisted by
/// atomic tmp+rename, so readers never see a torn file.
#[derive(Debug)]
pub struct Lease {
    path: PathBuf,
    host: String,
    renewals: u64,
}

impl Lease {
    /// Write the first renewal (counter 1) and return the live lease.
    pub fn acquire(dir: &Path, host: &str) -> Result<Lease> {
        let mut lease = Lease {
            path: lease_path(dir, host),
            host: host.to_string(),
            renewals: 0,
        };
        lease.renew()?;
        Ok(lease)
    }

    /// Bump the counter and republish the file. Each write goes
    /// through a pid-unique tmp name, so two supervisors fighting
    /// over the same dir corrupt nothing (the last rename wins).
    pub fn renew(&mut self) -> Result<()> {
        self.renewals += 1;
        let body = json::obj(vec![
            ("host", json::s(self.host.clone())),
            ("pid", json::num(f64::from(std::process::id()))),
            ("renewals", json::num(self.renewals as f64)),
        ]);
        let tmp = self.path.with_file_name(format!(
            "host-{}.lease.tmp.{}",
            self.host,
            std::process::id()
        ));
        std::fs::write(&tmp, format!("{}\n", body.to_string_compact()))?;
        std::fs::rename(&tmp, &self.path).map_err(Error::Io)
    }

    pub fn renewals(&self) -> u64 {
        self.renewals
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Read a lease file's renewal counter; `None` for missing or
/// unparsable files (a torn or garbage lease reads as "no renewal
/// observed", which only ever *delays* expiry detection by one poll).
pub fn read_renewals(path: &Path) -> Option<u64> {
    let text = std::fs::read_to_string(path).ok()?;
    json::parse(&text).ok()?.as_obj()?.get("renewals")?.as_u64()
}

/// Observer side of one lease: tracks the last seen renewal counter
/// against the *observer's* monotonic clock. Cross-host wall-clock
/// skew cannot touch it — only "the counter stopped changing for
/// `timeout` of my own time" expires a lease.
#[derive(Clone, Debug)]
pub struct LeaseMonitor {
    last: Option<u64>,
    changed_at: Instant,
}

impl LeaseMonitor {
    pub fn new(now: Instant) -> Self {
        LeaseMonitor { last: None, changed_at: now }
    }

    /// Record an observation; returns whether the counter changed
    /// (any change — including the file appearing or vanishing —
    /// counts as liveness evidence and resets the expiry clock).
    pub fn observe(&mut self, renewals: Option<u64>, now: Instant) -> bool {
        if renewals != self.last {
            self.last = renewals;
            self.changed_at = now;
            true
        } else {
            false
        }
    }

    /// Time since the last observed counter change.
    pub fn idle(&self, now: Instant) -> Duration {
        now.saturating_duration_since(self.changed_at)
    }

    pub fn expired(&self, timeout: Duration, now: Instant) -> bool {
        self.idle(now) >= timeout
    }
}

/// One host slot in the pool: its spec, its spawner, and whether the
/// supervisor has declared it lost.
pub struct HostSlot<'a> {
    pub spec: HostSpec,
    spawn: Box<dyn FnMut(&ShardPlan, u32) -> Result<Child> + 'a>,
    lost: bool,
}

impl<'a> HostSlot<'a> {
    pub fn new(
        spec: HostSpec,
        spawn: Box<dyn FnMut(&ShardPlan, u32) -> Result<Child> + 'a>,
    ) -> Self {
        HostSlot { spec, spawn, lost: false }
    }
}

/// The lease plane: writer leases for hosts this process renews
/// in-process (local hosts), remote renewer children for ssh hosts,
/// and one monitor per host. `None` writer = renewal stopped (chaos
/// pause, declared loss, or a remote renews instead).
struct LeasePlane {
    timeout: Duration,
    paths: Vec<PathBuf>,
    writers: Vec<Option<Lease>>,
    renewers: Vec<Option<Child>>,
    monitors: Vec<LeaseMonitor>,
}

impl Drop for LeasePlane {
    fn drop(&mut self) {
        for child in self.renewers.iter_mut().filter_map(Option::take) {
            let mut child = child;
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// The fleet's view of its hosts: per-host spawners, the live
/// shard→host assignment, and (in multi-host mode) the lease plane.
///
/// A single-host pool without leases is the exact legacy supervision
/// path: `HostPool::single_local` is what the source-compatible
/// `supervise()` wrapper builds, and it adds no file traffic and no
/// events.
pub struct HostPool<'a> {
    slots: Vec<HostSlot<'a>>,
    assignment: Vec<usize>,
    lease: Option<LeasePlane>,
}

impl<'a> HostPool<'a> {
    pub fn new(slots: Vec<HostSlot<'a>>) -> Result<Self> {
        if slots.is_empty() {
            return Err(Error::config("a host pool needs at least one host"));
        }
        Ok(HostPool { slots, assignment: Vec::new(), lease: None })
    }

    /// The legacy seam: one anonymous local host, no lease plane.
    pub fn single_local(
        spawn: Box<dyn FnMut(&ShardPlan, u32) -> Result<Child> + 'a>,
    ) -> Self {
        HostPool {
            slots: vec![HostSlot::new(
                HostSpec { id: "h0".into(), kind: HostKind::Local },
                spawn,
            )],
            assignment: Vec::new(),
            lease: None,
        }
    }

    /// Install the lease plane: acquire one lease per host in `dir`
    /// (local hosts renew in-process each tick; ssh hosts get a
    /// remote renewer loop spawned over ssh) and start the expiry
    /// monitors at `now`.
    pub fn with_leases(
        &mut self,
        dir: &Path,
        timeout: Duration,
        now: Instant,
    ) -> Result<()> {
        if timeout.is_zero() {
            return Err(Error::config("lease timeout must be positive"));
        }
        let mut paths = Vec::new();
        let mut writers = Vec::new();
        let mut renewers = Vec::new();
        let mut monitors = Vec::new();
        for slot in &self.slots {
            let path = lease_path(dir, &slot.spec.id);
            match &slot.spec.kind {
                HostKind::Local => {
                    writers.push(Some(Lease::acquire(dir, &slot.spec.id)?));
                    renewers.push(None);
                }
                HostKind::Ssh { target } => {
                    // the remote renews its own lease, so the lease
                    // proves the *host* (and the shared mount) is
                    // alive, not merely this supervisor
                    writers.push(None);
                    let interval = (timeout / 4).max(Duration::from_millis(10));
                    let script = format!(
                        "n=0; while :; do n=$((n+1)); \
                         printf '{{\"host\":\"%s\",\"renewals\":%d}}\\n' {id} $n \
                         > {tmp} && mv {tmp} {lease}; sleep {s}; done",
                        id = shell_quote(&slot.spec.id),
                        // `$$` must sit outside the quoting to expand
                        tmp = format!(
                            "{}.tmp.$$",
                            shell_quote(&path.display().to_string())
                        ),
                        lease = shell_quote(&path.display().to_string()),
                        s = interval.as_secs_f64().max(0.01),
                    );
                    let child = std::process::Command::new("ssh")
                        .arg("-oBatchMode=yes")
                        .arg(target)
                        .arg(script)
                        .stdin(std::process::Stdio::null())
                        .stdout(std::process::Stdio::null())
                        .stderr(std::process::Stdio::null())
                        .spawn()
                        .map_err(Error::Io)?;
                    renewers.push(Some(child));
                }
            }
            paths.push(path);
            monitors.push(LeaseMonitor::new(now));
        }
        self.lease = Some(LeasePlane { timeout, paths, writers, renewers, monitors });
        Ok(())
    }

    pub fn has_leases(&self) -> bool {
        self.lease.is_some()
    }

    pub fn n_hosts(&self) -> usize {
        self.slots.len()
    }

    pub fn host_id(&self, host: usize) -> &str {
        &self.slots[host].spec.id
    }

    pub fn is_lost(&self, host: usize) -> bool {
        self.slots[host].lost
    }

    /// Round-robin the shards over the hosts (the initial placement;
    /// host loss rewrites entries via [`HostPool::reassign`]).
    pub fn init_assignment(&mut self, n_shards: usize) {
        self.assignment = (0..n_shards).map(|s| s % self.slots.len()).collect();
    }

    pub fn host_of(&self, shard: usize) -> usize {
        self.assignment.get(shard).copied().unwrap_or(0)
    }

    /// Spawn `shard` on its currently assigned host.
    pub fn spawn(
        &mut self,
        shard: usize,
        plan: &ShardPlan,
        attempt: u32,
    ) -> Result<Child> {
        let host = self.host_of(shard);
        (self.slots[host].spawn)(plan, attempt)
    }

    /// Stop renewing a host's lease (the chaos drill's "the machine
    /// went dark": children are killed separately, and the lease now
    /// ages toward expiry like a real dead host's would).
    pub fn pause_lease(&mut self, host: usize) {
        if let Some(plane) = &mut self.lease {
            plane.writers[host] = None;
            if let Some(mut child) = plane.renewers[host].take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }

    /// One supervision tick: renew every live in-process lease, then
    /// observe all lease files and return hosts whose leases *newly*
    /// expired (they are marked lost here; callers do the shard
    /// reassignment).
    pub fn tick(&mut self, now: Instant) -> Vec<usize> {
        let Some(plane) = &mut self.lease else { return Vec::new() };
        let mut newly_lost = Vec::new();
        for h in 0..self.slots.len() {
            if self.slots[h].lost {
                continue;
            }
            if let Some(w) = plane.writers[h].as_mut() {
                if let Err(e) = w.renew() {
                    logging::warn(
                        "host",
                        format!("lease renew for {} failed: {e}", self.slots[h].spec.id),
                    );
                }
            }
            let seen = read_renewals(&plane.paths[h]);
            plane.monitors[h].observe(seen, now);
            if plane.monitors[h].expired(plane.timeout, now) {
                self.slots[h].lost = true;
                newly_lost.push(h);
            }
        }
        newly_lost
    }

    /// Age of a host's lease as this pool's monitor sees it.
    pub fn lease_idle(&self, host: usize, now: Instant) -> Option<Duration> {
        self.lease.as_ref().map(|p| p.monitors[host].idle(now))
    }

    /// Move `shard` to a surviving host (deterministic: round-robin
    /// by shard index over the survivors). `None` when every host is
    /// lost.
    pub fn reassign(&mut self, shard: usize) -> Option<usize> {
        let survivors: Vec<usize> =
            (0..self.slots.len()).filter(|&h| !self.slots[h].lost).collect();
        if survivors.is_empty() {
            return None;
        }
        let to = survivors[shard % survivors.len()];
        if let Some(slot) = self.assignment.get_mut(shard) {
            *slot = to;
        }
        Some(to)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-host-{}-{name}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn host_specs_parse_and_get_positional_ids() {
        let specs = HostSpec::parse_list(&[
            "local".to_string(),
            "ssh:user@node7".to_string(),
        ])
        .unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].id, "h0");
        assert_eq!(specs[0].kind, HostKind::Local);
        assert_eq!(specs[1].id, "h1");
        assert_eq!(
            specs[1].kind,
            HostKind::Ssh { target: "user@node7".into() }
        );
        assert!(HostSpec::parse_list(&["pbs:queue".to_string()]).is_err());
        assert!(HostSpec::parse_list(&["ssh:".to_string()]).is_err());
        assert!(HostSpec::parse_list(&[]).unwrap().is_empty());
    }

    #[test]
    fn shell_quoting_protects_metacharacters() {
        assert_eq!(shell_quote("plain-word_1.0"), "plain-word_1.0");
        assert_eq!(shell_quote("/a/b,c:d@e"), "/a/b,c:d@e");
        assert_eq!(shell_quote("two words"), "'two words'");
        assert_eq!(shell_quote("a'b"), "'a'\\''b'");
        assert_eq!(shell_quote(""), "''");
        assert_eq!(shell_quote("$(rm -rf /)"), "'$(rm -rf /)'");
    }

    #[test]
    fn ssh_command_wraps_and_quotes_the_remote_argv() {
        let cmd = ssh_command(
            "user@node7",
            &["memfine".into(), "sweep".into(), "--out".into(), "a b".into()],
            Some(("MEMFINE_FAULTS", "x;y")),
        );
        assert_eq!(cmd.get_program(), "ssh");
        let args: Vec<String> = cmd
            .get_args()
            .map(|a| a.to_string_lossy().into_owned())
            .collect();
        assert_eq!(args[0], "-oBatchMode=yes");
        assert_eq!(args[1], "user@node7");
        assert_eq!(args[2], "MEMFINE_FAULTS='x;y' memfine sweep --out 'a b'");
    }

    #[test]
    fn lease_roundtrips_and_tolerates_garbage() {
        let dir = tmp_dir("lease-rt");
        let mut lease = Lease::acquire(&dir, "h3").unwrap();
        assert_eq!(read_renewals(lease.path()), Some(1));
        lease.renew().unwrap();
        lease.renew().unwrap();
        assert_eq!(read_renewals(lease.path()), Some(3));
        assert_eq!(
            lease.path().extension().and_then(|e| e.to_str()),
            Some("lease"),
            "lease files must stay invisible to the *.jsonl campaign globs"
        );
        // garbage and absence both read as "nothing observed"
        std::fs::write(lease.path(), "not json at all").unwrap();
        assert_eq!(read_renewals(lease.path()), None);
        assert_eq!(read_renewals(&dir.join("host-h9.lease")), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn monitor_expires_exactly_at_the_idle_boundary() {
        let t0 = Instant::now();
        let timeout = 200 * MS;
        let mut m = LeaseMonitor::new(t0);
        // the file appearing is itself a change
        assert!(m.observe(Some(1), t0 + 5 * MS));
        assert!(!m.observe(Some(1), t0 + 10 * MS));
        assert!(!m.expired(timeout, t0 + 5 * MS + 199 * MS));
        assert!(m.expired(timeout, t0 + 5 * MS + 200 * MS));
        // a renewal resets the clock
        assert!(m.observe(Some(2), t0 + 100 * MS));
        assert!(!m.expired(timeout, t0 + 299 * MS));
        assert!(m.expired(timeout, t0 + 300 * MS));
        // the file vanishing also counts as a change (one last grace
        // period before the host is declared dead)
        assert!(m.observe(None, t0 + 310 * MS));
        assert!(!m.expired(timeout, t0 + 509 * MS));
        assert!(m.expired(timeout, t0 + 510 * MS));
    }

    #[test]
    fn monitor_expiry_is_renewal_driven_under_arbitrary_skew() {
        // Property: feed the monitor a schedule of observation gaps
        // with renewals that stop at some point; it must stay live
        // through every gap < timeout while renewals continue, and
        // expire exactly once the post-stop idle time reaches the
        // timeout — regardless of the (simulated) wall-clock skew,
        // which never enters the computation at all.
        let timeout = 1_000 * MS;
        let gen = crate::prop::PairGen(
            crate::prop::VecGen(crate::prop::U64Range(1, 999), 12),
            crate::prop::U64Range(0, 11),
        );
        crate::prop::assert_prop(11, 200, &gen, |(gaps, stop_at)| {
            let t0 = Instant::now();
            let mut m = LeaseMonitor::new(t0);
            let mut t = t0;
            let mut counter = 0u64;
            let mut last_change = t0;
            for (i, gap) in gaps.iter().enumerate() {
                t += *gap as u32 * MS;
                if (i as u64) < *stop_at {
                    counter += 1;
                }
                if m.observe(Some(counter), t) {
                    last_change = t;
                }
                let renewed_this_step = (i as u64) < *stop_at;
                if renewed_this_step && m.expired(timeout, t) {
                    return Err(format!(
                        "expired immediately after renewal {counter} at step {i}"
                    ));
                }
            }
            // idle grows from the last counter change: still live one
            // tick before the timeout boundary, dead exactly at it
            if m.expired(timeout, last_change + 999 * MS) {
                return Err("expired before the idle boundary".into());
            }
            if !m.expired(timeout, last_change + 1_000 * MS) {
                return Err("still live at the idle boundary".into());
            }
            Ok(())
        });
    }

    fn sh_slot(id: &str, script: &'static str) -> HostSlot<'static> {
        HostSlot::new(
            HostSpec { id: id.into(), kind: HostKind::Local },
            Box::new(move |_, _| {
                std::process::Command::new("sh")
                    .arg("-c")
                    .arg(script)
                    .stdin(std::process::Stdio::null())
                    .stdout(std::process::Stdio::null())
                    .stderr(std::process::Stdio::null())
                    .spawn()
                    .map_err(Error::Io)
            }),
        )
    }

    #[test]
    fn pool_assigns_round_robin_and_reassigns_off_lost_hosts() {
        let mut pool = HostPool::new(vec![
            sh_slot("h0", "true"),
            sh_slot("h1", "true"),
            sh_slot("h2", "true"),
        ])
        .unwrap();
        pool.init_assignment(5);
        assert_eq!(
            (0..5).map(|s| pool.host_of(s)).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1]
        );
        pool.slots[1].lost = true;
        // survivors [0, 2]: shard 1 -> survivors[1 % 2] = h2
        assert_eq!(pool.reassign(1), Some(2));
        assert_eq!(pool.host_of(1), 2);
        assert_eq!(pool.reassign(4), Some(0));
        pool.slots[0].lost = true;
        pool.slots[2].lost = true;
        assert_eq!(pool.reassign(1), None);
        assert!(HostPool::new(vec![]).is_err());
    }

    #[test]
    #[cfg(unix)]
    fn lease_plane_declares_a_paused_host_lost_after_timeout() {
        let dir = tmp_dir("lease-plane");
        let mut pool =
            HostPool::new(vec![sh_slot("h0", "true"), sh_slot("h1", "true")])
                .unwrap();
        pool.init_assignment(2);
        let t0 = Instant::now();
        pool.with_leases(&dir, 120 * MS, t0).unwrap();
        assert!(pool.has_leases());
        assert!(lease_path(&dir, "h0").exists());
        assert!(lease_path(&dir, "h1").exists());
        // both hosts renew: ticks well past the timeout lose nobody
        for step in 1..=8u32 {
            assert!(pool.tick(t0 + step * 30 * MS).is_empty());
        }
        // h1 goes dark; h0 keeps renewing
        pool.pause_lease(1);
        let t1 = t0 + 8 * 30 * MS;
        let mut lost = Vec::new();
        for step in 1..=6u32 {
            lost.extend(pool.tick(t1 + step * 30 * MS));
        }
        assert_eq!(lost, vec![1], "exactly h1 expires, exactly once");
        assert!(pool.is_lost(1));
        assert!(!pool.is_lost(0));
        // already-lost hosts never re-expire
        assert!(pool.tick(t1 + 7 * 30 * MS).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
