//! Resumable sweeps: a JSON-lines checkpoint of completed scenarios,
//! keyed by content hash, mergeable across shards and hosts.
//!
//! Every scenario is identified by [`scenario_hash`] — FNV-1a 64 over
//! the canonical compact JSON of its fully-resolved
//! [`RunConfig`](crate::config::RunConfig) plus the router-sampler tag.
//! The hash therefore captures *what will be simulated* (model,
//! parallelism, method, seed, iterations, memory envelope, sampler)
//! and deliberately excludes *how it is executed* (worker count,
//! shard split, grid position): two hosts running different shards of
//! the same grid, or re-runs of a reordered/extended grid, agree on
//! every hash.
//!
//! The file format is one line per completed scenario:
//!
//! ```text
//! {"hash":"94fd0a31c7e02b44","result":{...ScenarioResult row...}}
//! ```
//!
//! appended and flushed as each scenario finishes, so a killed sweep
//! loses at most the in-flight cells. Loading tolerates a torn final
//! line (the kill-mid-write case) by skipping lines that fail to
//! parse and reporting the count; merging is file concatenation or
//! passing several `--checkpoint` paths — duplicate hashes collapse
//! (results are deterministic, so duplicates are identical).
//!
//! On resume the stored row's `index` is re-derived from the *current*
//! grid (hashes are position-independent), which keeps the final
//! artifact byte-identical to an uninterrupted run of that grid — the
//! kill-and-resume integration test pins this.
//!
//! Rows are engine-agnostic: the fused cell evaluator
//! ([`crate::sim::evaluate_cell`], the default) and the per-method
//! path (`--unfused`) emit byte-identical
//! [`ScenarioResult`](crate::sweep::report::ScenarioResult) lines, so
//! checkpoints written under either engine resume under the other —
//! the CLI tests and the CI smoke cross-merge them deliberately.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::sweep::report::ScenarioResult;
use crate::util::fnv1a_64;

/// Content hash of one scenario: FNV-1a 64 (16 hex chars) over the
/// canonical run JSON plus the router-sampler tag. `fast_router`
/// changes the drawn trace (same distribution, different bits), so it
/// is part of the identity — a checkpoint written with one sampler
/// never silently satisfies a sweep run with the other.
pub fn scenario_hash(run: &RunConfig, fast_router: bool) -> String {
    let doc = json::obj(vec![
        ("router", json::s(if fast_router { "split" } else { "seq" }.to_string())),
        ("run", run.to_json()),
    ]);
    format!("{:016x}", fnv1a_64(doc.to_string_compact().as_bytes()))
}

/// Completed scenarios loaded from checkpoint files, keyed by hash.
#[derive(Debug, Default)]
pub struct CheckpointSet {
    map: BTreeMap<String, ScenarioResult>,
    /// Lines that failed to parse (torn tail of a killed run, stray
    /// garbage) — skipped, surfaced so the CLI can report them.
    pub skipped_lines: usize,
    /// Files that existed and were read.
    pub loaded_files: usize,
    /// Non-blank lines seen across all files.
    pub total_lines: usize,
    /// Parseable records that duplicated an already-loaded hash
    /// (identical by the determinism contract; later files win).
    pub duplicate_records: usize,
}

impl CheckpointSet {
    pub fn empty() -> Self {
        CheckpointSet::default()
    }

    /// Load and merge checkpoint files. Missing files are fine (a
    /// shard that never started); unreadable lines are skipped and
    /// counted. Later files win on duplicate hashes — by the
    /// determinism contract duplicates carry identical results, so
    /// the choice is immaterial.
    pub fn load(paths: &[PathBuf]) -> Result<Self> {
        let mut set = CheckpointSet::empty();
        for path in paths {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => {
                    return Err(Error::Io(std::io::Error::new(
                        e.kind(),
                        format!("checkpoint {}: {e}", path.display()),
                    )))
                }
            };
            set.loaded_files += 1;
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                set.total_lines += 1;
                match Self::parse_line(line) {
                    Ok((hash, result)) => {
                        if set.map.insert(hash, result).is_some() {
                            set.duplicate_records += 1;
                        }
                    }
                    Err(_) => set.skipped_lines += 1,
                }
            }
        }
        Ok(set)
    }

    fn parse_line(line: &str) -> Result<(String, ScenarioResult)> {
        let v = json::parse(line)?;
        let hash = v.req_str("hash")?.to_string();
        let result = ScenarioResult::from_json(
            v.get("result")
                .ok_or_else(|| Error::config("checkpoint line missing result"))?,
        )?;
        Ok((hash, result))
    }

    pub fn get(&self, hash: &str) -> Option<&ScenarioResult> {
        self.map.get(hash)
    }

    pub fn contains(&self, hash: &str) -> bool {
        self.map.contains_key(hash)
    }

    /// Records in canonical (ascending hash) order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &ScenarioResult)> {
        self.map.iter().map(|(h, r)| (h.as_str(), r))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// What [`compact`] read and wrote.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CompactStats {
    /// Input files read (all must exist — compaction of a missing
    /// checkpoint is an operator error, unlike resume's tolerance).
    pub files_in: usize,
    /// Non-blank input lines seen.
    pub lines_in: usize,
    /// Unparseable lines dropped (torn tails, stray garbage).
    pub dropped_lines: usize,
    /// Parseable records dropped as duplicates of an earlier hash
    /// (identical by the determinism contract).
    pub duplicate_records: usize,
    /// Records in the compacted output.
    pub records_out: usize,
}

/// Rewrite one or more checkpoint files as a single canonical file:
/// duplicate hashes collapse, torn/garbage lines are dropped, and
/// records are emitted in ascending hash order — so compacting the
/// same logical content always yields the same bytes, and re-running
/// compact on its own output is a fixpoint. The output is written to
/// `<output>.tmp` and renamed into place, so a kill mid-compaction
/// never corrupts an existing checkpoint (in-place compaction,
/// `output` ∈ `inputs`, is safe for the same reason: inputs are fully
/// read before the write starts).
pub fn compact(inputs: &[PathBuf], output: &Path) -> Result<CompactStats> {
    for path in inputs {
        if !path.exists() {
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("compact checkpoint {}: no such file", path.display()),
            )));
        }
    }
    let set = CheckpointSet::load(inputs)?;
    write_compacted(&set, output)
}

/// Write an already-loaded checkpoint set as a canonical compacted
/// file (the tail of [`compact`], split out so callers that hold a
/// [`CheckpointSet`] — the orchestrator's merge step audits one —
/// can compact without re-reading every shard file from disk).
pub fn write_compacted(set: &CheckpointSet, output: &Path) -> Result<CompactStats> {
    let mut tmp_name = output.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    {
        let mut w = CheckpointWriter::create(&tmp)?;
        for (hash, result) in set.iter() {
            w.record(hash, result)?;
        }
    }
    std::fs::rename(&tmp, output).map_err(|e| {
        Error::Io(std::io::Error::new(
            e.kind(),
            format!("rename {} -> {}: {e}", tmp.display(), output.display()),
        ))
    })?;
    Ok(CompactStats {
        files_in: set.loaded_files,
        lines_in: set.total_lines,
        dropped_lines: set.skipped_lines,
        duplicate_records: set.duplicate_records,
        records_out: set.len(),
    })
}

/// Result of checking a checkpoint set against the grid it claims to
/// cover (see [`audit_coverage`]).
#[derive(Clone, Debug)]
pub struct CoverageAudit {
    /// Scenarios the grid plans.
    pub planned: usize,
    /// Planned scenarios present in the checkpoint set.
    pub present: usize,
    /// Planned scenarios absent from the set: (grid index, hash),
    /// index-ascending.
    pub missing: Vec<(usize, String)>,
    /// Records in the set that belong to no planned scenario (another
    /// grid's rows, or rows written under the other router sampler).
    pub extra: usize,
}

impl CoverageAudit {
    /// Every planned scenario is present.
    pub fn complete(&self) -> bool {
        self.missing.is_empty()
    }
}

/// Audit a checkpoint set against a sweep grid: expand the grid,
/// derive every scenario's content hash under the given router
/// sampler, and report which planned scenarios are present, missing,
/// or foreign to the grid. This is how the orchestrator proves the
/// merged artifact covers every planned scenario before it publishes
/// a report (and how `memfine checkpoint audit` exposes the same
/// check standalone).
pub fn audit_coverage(
    cfg: &crate::config::SweepConfig,
    fast_router: bool,
    set: &CheckpointSet,
) -> Result<CoverageAudit> {
    let scenarios = crate::sweep::grid::expand(cfg)?;
    let planned: Vec<(usize, String)> = scenarios
        .iter()
        .map(|sc| (sc.index, scenario_hash(&sc.run, fast_router)))
        .collect();
    Ok(audit_planned(&planned, set))
}

/// [`audit_coverage`] against an already-derived planned hash set —
/// the orchestrator plans every scenario hash once up front
/// ([`crate::orchestrator::plan::LaunchPlan::planned`]) and audits
/// against it without re-expanding and re-hashing the grid.
pub fn audit_planned(planned: &[(usize, String)], set: &CheckpointSet) -> CoverageAudit {
    let mut present = 0usize;
    let mut missing = Vec::new();
    for (index, hash) in planned {
        if set.contains(hash) {
            present += 1;
        } else {
            missing.push((*index, hash.clone()));
        }
    }
    CoverageAudit {
        planned: planned.len(),
        present,
        missing,
        extra: set.len().saturating_sub(present),
    }
}

/// Appends one line per completed scenario, flushed immediately so a
/// kill loses at most in-flight work. `disabled()` is the no-op used
/// when no `--checkpoint` path is configured.
#[derive(Debug)]
pub struct CheckpointWriter {
    out: Option<std::fs::File>,
}

impl CheckpointWriter {
    pub fn disabled() -> Self {
        CheckpointWriter { out: None }
    }

    /// Start a fresh checkpoint (truncates an existing file — the
    /// non-`--resume` path).
    pub fn create(path: &Path) -> Result<Self> {
        let f = std::fs::File::create(path).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("create checkpoint {}: {e}", path.display()),
            ))
        })?;
        Ok(CheckpointWriter { out: Some(f) })
    }

    /// Append to an existing checkpoint (the `--resume` path; the file
    /// may not exist yet). If a previous run died mid-write the file
    /// ends in a torn fragment without a newline — terminate it first
    /// so the next record starts on its own line (the fragment stays
    /// unparseable and is skipped on load; its scenario simply re-runs).
    pub fn append(path: &Path) -> Result<Self> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = std::fs::File::options()
            .read(true)
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| {
                Error::Io(std::io::Error::new(
                    e.kind(),
                    format!("append checkpoint {}: {e}", path.display()),
                ))
            })?;
        if f.metadata().map_err(Error::Io)?.len() > 0 {
            f.seek(SeekFrom::End(-1)).map_err(Error::Io)?;
            let mut last = [0u8; 1];
            f.read_exact(&mut last).map_err(Error::Io)?;
            if last[0] != b'\n' {
                // append mode: the write lands at EOF regardless of
                // the read cursor
                f.write_all(b"\n").map_err(Error::Io)?;
            }
        }
        Ok(CheckpointWriter { out: Some(f) })
    }

    /// Record one completed scenario. One compact-JSON line, written
    /// and flushed atomically enough for the torn-line loader: a kill
    /// mid-write corrupts at most the final line.
    pub fn record(&mut self, hash: &str, result: &ScenarioResult) -> Result<()> {
        let Some(f) = self.out.as_mut() else {
            return Ok(());
        };
        let line = json::obj(vec![
            ("hash", json::s(hash.to_string())),
            ("result", result.to_json()),
        ])
        .to_string_compact();
        f.write_all(line.as_bytes())
            .and_then(|_| f.write_all(b"\n"))
            .and_then(|_| f.flush())
            .map_err(Error::Io)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, paper_run, Method};

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-ckpt-test-{}-{name}", std::process::id()));
        p
    }

    fn sample_result(index: usize, seed: u64) -> ScenarioResult {
        ScenarioResult {
            index,
            model: "i".into(),
            method: Method::FixedChunk(8).name(),
            seed,
            iterations: 10,
            trained: true,
            oom_iterations: 0,
            avg_tgs: 1234.5678901234,
            peak_act_bytes: 9_876_543_210,
            peak_total_bytes: 19_876_543_210,
            static_bytes: 5_000_000_000,
        }
    }

    #[test]
    fn hash_is_stable_and_content_sensitive() {
        let run = paper_run(model_i(), Method::FullRecompute);
        let h = scenario_hash(&run, false);
        assert_eq!(h.len(), 16);
        assert_eq!(h, scenario_hash(&run, false));
        // every identity-bearing field perturbs the hash
        let mut seed = run.clone();
        seed.seed += 1;
        assert_ne!(h, scenario_hash(&seed, false));
        let mut iters = run.clone();
        iters.iterations += 1;
        assert_ne!(h, scenario_hash(&iters, false));
        let mut method = run.clone();
        method.method = Method::FixedChunk(8);
        assert_ne!(h, scenario_hash(&method, false));
        let mut mem = run.clone();
        mem.gpu_mem_bytes /= 2;
        assert_ne!(h, scenario_hash(&mem, false));
        // the sampler tag is part of the identity
        assert_ne!(h, scenario_hash(&run, true));
    }

    #[test]
    fn writer_then_loader_roundtrip() {
        let path = tmp_path("roundtrip");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        let hash = scenario_hash(&run, false);
        let result = sample_result(3, 7);
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&hash, &result).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.skipped_lines, 0);
        let back = set.get(&hash).unwrap();
        assert_eq!(back, &result);
        assert_eq!(back.avg_tgs.to_bits(), result.avg_tgs.to_bits());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loader_skips_torn_final_line() {
        let path = tmp_path("torn");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        let hash = scenario_hash(&run, false);
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&hash, &sample_result(0, 7)).unwrap();
        }
        // simulate a kill mid-write: half a second line, no newline
        {
            use std::io::Write as _;
            let mut f = std::fs::File::options().append(true).open(&path).unwrap();
            f.write_all(b"{\"hash\":\"deadbeef\",\"resu").unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.skipped_lines, 1);
        assert!(set.get(&hash).is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loader_merges_files_and_missing_files_are_fine() {
        let a = tmp_path("merge-a");
        let b = tmp_path("merge-b");
        let run1 = paper_run(model_i(), Method::FullRecompute);
        let run2 = paper_run(model_i(), Method::FixedChunk(8));
        let (h1, h2) = (scenario_hash(&run1, false), scenario_hash(&run2, false));
        {
            let mut w = CheckpointWriter::create(&a).unwrap();
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        {
            let mut w = CheckpointWriter::create(&b).unwrap();
            w.record(&h2, &sample_result(1, 7)).unwrap();
            // duplicate of h1: collapses
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        let missing = tmp_path("never-written");
        let set =
            CheckpointSet::load(&[a.clone(), b.clone(), missing]).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.loaded_files, 2);
        assert!(set.get(&h1).is_some() && set.get(&h2).is_some());
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn append_terminates_torn_tail_before_writing() {
        let path = tmp_path("torn-append");
        let run1 = paper_run(model_i(), Method::FullRecompute);
        let run2 = paper_run(model_i(), Method::FixedChunk(8));
        let (h1, h2) = (scenario_hash(&run1, false), scenario_hash(&run2, false));
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::File::options().append(true).open(&path).unwrap();
            f.write_all(b"{\"hash\":\"torn").unwrap();
        }
        {
            let mut w = CheckpointWriter::append(&path).unwrap();
            w.record(&h2, &sample_result(1, 7)).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        // both complete records load; only the torn fragment is lost
        assert_eq!(set.len(), 2);
        assert_eq!(set.skipped_lines, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn create_truncates_append_preserves() {
        let path = tmp_path("trunc");
        let run = paper_run(model_i(), Method::FullRecompute);
        let hash = scenario_hash(&run, false);
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&hash, &sample_result(0, 7)).unwrap();
        }
        {
            let mut w = CheckpointWriter::append(&path).unwrap();
            let run2 = paper_run(model_i(), Method::FixedChunk(8));
            w.record(&scenario_hash(&run2, false), &sample_result(1, 7)).unwrap();
        }
        assert_eq!(CheckpointSet::load(std::slice::from_ref(&path)).unwrap().len(), 2);
        {
            let _w = CheckpointWriter::create(&path).unwrap();
        }
        assert!(CheckpointSet::load(std::slice::from_ref(&path)).unwrap().is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn disabled_writer_is_a_noop() {
        let mut w = CheckpointWriter::disabled();
        w.record("abc", &sample_result(0, 1)).unwrap();
    }

    #[test]
    fn compact_dedupes_drops_torn_tail_and_canonicalises() {
        let a = tmp_path("compact-a");
        let b = tmp_path("compact-b");
        let out = tmp_path("compact-out");
        let run1 = paper_run(model_i(), Method::FullRecompute);
        let run2 = paper_run(model_i(), Method::FixedChunk(8));
        let (h1, h2) = (scenario_hash(&run1, false), scenario_hash(&run2, false));
        {
            let mut w = CheckpointWriter::create(&a).unwrap();
            w.record(&h2, &sample_result(1, 7)).unwrap();
            w.record(&h1, &sample_result(0, 7)).unwrap();
            // duplicate of h1 within the same file
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        {
            let mut w = CheckpointWriter::create(&b).unwrap();
            // cross-file duplicate of h2, then a torn tail
            w.record(&h2, &sample_result(1, 7)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::File::options().append(true).open(&b).unwrap();
            f.write_all(b"{\"hash\":\"dead").unwrap();
        }
        let stats = compact(&[a.clone(), b.clone()], &out).unwrap();
        assert_eq!(stats.files_in, 2);
        assert_eq!(stats.lines_in, 5);
        assert_eq!(stats.dropped_lines, 1);
        assert_eq!(stats.duplicate_records, 2);
        assert_eq!(stats.records_out, 2);
        // the compacted file loads clean
        let set = CheckpointSet::load(std::slice::from_ref(&out)).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.skipped_lines, 0);
        // records come out hash-ascending
        let hashes: Vec<String> = set.iter().map(|(h, _)| h.to_string()).collect();
        let mut sorted = hashes.clone();
        sorted.sort();
        assert_eq!(hashes, sorted);
        // compaction is a fixpoint: recompacting its own output
        // (in-place) changes nothing
        let bytes = std::fs::read(&out).unwrap();
        let again = compact(&[out.clone()], &out).unwrap();
        assert_eq!(again.records_out, 2);
        assert_eq!(again.duplicate_records, 0);
        assert_eq!(again.dropped_lines, 0);
        assert_eq!(std::fs::read(&out).unwrap(), bytes);
        for p in [&a, &b, &out] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn compact_missing_input_is_an_error() {
        let missing = tmp_path("compact-missing");
        let out = tmp_path("compact-missing-out");
        assert!(compact(&[missing], &out).is_err());
    }

    #[test]
    fn audit_coverage_reports_present_missing_and_extra() {
        use crate::config::SweepConfig;
        let cfg = SweepConfig {
            models: vec!["i".into()],
            methods: vec![Method::FullRecompute, Method::FixedChunk(8)],
            seeds: vec![7],
            iterations: 10,
        };
        let scenarios = crate::sweep::grid::expand(&cfg).unwrap();
        assert_eq!(scenarios.len(), 2);
        let h0 = scenario_hash(&scenarios[0].run, false);

        let path = tmp_path("audit");
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&h0, &sample_result(0, 7)).unwrap();
            // a foreign record (other grid / other sampler)
            w.record("ffffffffffffffff", &sample_result(9, 9)).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        let audit = audit_coverage(&cfg, false, &set).unwrap();
        assert_eq!(audit.planned, 2);
        assert_eq!(audit.present, 1);
        assert_eq!(audit.extra, 1);
        assert!(!audit.complete());
        assert_eq!(audit.missing.len(), 1);
        assert_eq!(audit.missing[0].0, scenarios[1].index);
        assert_eq!(audit.missing[0].1, scenario_hash(&scenarios[1].run, false));

        // the same rows under the other sampler cover nothing: the
        // sampler tag is part of the identity
        let fast = audit_coverage(&cfg, true, &set).unwrap();
        assert_eq!(fast.present, 0);
        assert_eq!(fast.missing.len(), 2);
        assert_eq!(fast.extra, 2);

        // complete set audits clean
        {
            let mut w = CheckpointWriter::append(&path).unwrap();
            w.record(&scenario_hash(&scenarios[1].run, false), &sample_result(1, 7))
                .unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        let audit = audit_coverage(&cfg, false, &set).unwrap();
        assert!(audit.complete());
        assert_eq!(audit.present, 2);
        std::fs::remove_file(&path).ok();
    }
}
