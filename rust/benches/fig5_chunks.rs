//! `cargo bench --bench fig5_chunks` — regenerates Fig. 5 (MACT chunk
//! values per layer × iteration, Model I) and times the MACT decision
//! hot path (it runs once per MoE layer per micro-batch in the real
//! coordinator, so it must be cheap).

use memfine::bench::{fmt_time, time_fn};
use memfine::chunk::Mact;
use memfine::config::{model_i, paper_run, Method};
use memfine::sim::repro;

fn main() {
    memfine::logging::init();
    repro::fig5(7, 25).expect("fig5 repro");

    let run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
    let mact = Mact::new(&run, vec![1, 2, 4, 8]);
    let t = time_fn("MACT decide()", 1000, 50_000, || {
        mact.decide(1, 250_000).chosen_c
    });
    println!(
        "\n[bench] {}: median {} ({:.2}M decisions/s)",
        t.name,
        fmt_time(t.median_s),
        t.per_sec() / 1e6
    );
}
