//! `cargo bench --bench fig4_throughput` — regenerates Fig. 4 (TGS per
//! iteration, 3 methods × 2 models) with the headline deltas
//! (paper Model II: M3 +4.42 % vs M1, M2 −5.40 % vs M1; Model I:
//! M3 +18.26 % vs M2, M1 OOM), and times the per-iteration simulation.

use memfine::bench::{fmt_time, time_fn};
use memfine::config::{model_ii, paper_run, Method};
use memfine::sim::{repro, Simulator};

fn main() {
    memfine::logging::init();
    repro::fig4(7, 25).expect("fig4 repro");

    let mut run = paper_run(model_ii(), Method::Mact(vec![1, 2, 4, 8]));
    run.iterations = 1;
    let sim = Simulator::new(run).unwrap();
    let t = time_fn("simulate one iteration (model II, MACT)", 2, 20, || {
        sim.iteration(7).tgs
    });
    println!(
        "\n[bench] {}: median {} ({:.0} iterations/s)",
        t.name,
        fmt_time(t.median_s),
        t.per_sec()
    );
}
