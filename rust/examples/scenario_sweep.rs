//! Scenario sweep driver: the paper's comparison grid (Models I/II ×
//! Methods 1/2/3 × seeds) through the parallel sweep engine, printing
//! the per-cell aggregates and writing the deterministic JSON
//! artifact.
//!
//! This is the programmatic twin of `memfine sweep`; use it as the
//! template for custom grids (ablation bins, GPU sizes, imbalance
//! regimes, ...).
//!
//! Run: `cargo run --release --example scenario_sweep -- [n_seeds] [iters] [out.json]`

use memfine::config::SweepConfig;
use memfine::sweep;

fn main() -> memfine::Result<()> {
    memfine::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_seeds: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let iters: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(10);
    let out_path = args.get(2).cloned();

    let cfg = SweepConfig::paper_grid(7, n_seeds, iters);
    let workers = sweep::default_workers(cfg.scenario_count());
    println!(
        "running {} scenarios ({} models x {} methods x {} seeds, {} iters) on {} workers",
        cfg.scenario_count(),
        cfg.models.len(),
        cfg.methods.len(),
        cfg.seeds.len(),
        cfg.iterations,
        workers
    );

    let report = sweep::run_sweep(&cfg, workers)?;
    print!("{}", report.render_table());

    // The paper's qualitative claims, read off the aggregates: MACT
    // reduces Method 1's activation peak and never OOMs.
    let mact = report
        .cells
        .iter()
        .find(|c| c.method.starts_with("method3"))
        .expect("grid contains method 3");
    println!(
        "\nMACT on model {}: {:.1} % activation reduction vs method 1, {} / {} runs trained",
        mact.model,
        mact.act_reduction_vs_m1_pct.unwrap_or(0.0),
        mact.trained_runs,
        mact.runs
    );

    if let Some(path) = out_path {
        std::fs::write(&path, format!("{}\n", report.to_json().to_string_pretty()))?;
        println!("JSON artifact written to {path}");
    }
    Ok(())
}
