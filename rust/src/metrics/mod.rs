//! Metrics: counters, gauges, histograms, timers, and CSV emission.
//!
//! Deliberately simple — a `Registry` of named counters/gauges/
//! log-bucketed histograms ([`crate::obs::Histogram`]) plus a
//! `CsvWriter` with schema checking. Registries merge (sum counters,
//! add histogram buckets, last-writer gauges), so each shard/worker
//! owns one and the coordinator folds them; everything the benches
//! print comes through here so output formats stay consistent.

use std::collections::BTreeMap;
use std::io::Write;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::obs::Histogram;

/// A monotonically increasing counter.
#[derive(Clone, Debug, Default)]
pub struct Counter(u64);

impl Counter {
    pub fn inc(&mut self) {
        self.0 += 1;
    }
    pub fn add(&mut self, n: u64) {
        self.0 += n;
    }
    pub fn get(&self) -> u64 {
        self.0
    }
}

/// Named metrics registry (single-threaded by design: each worker owns
/// one and the coordinator merges).
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn count(&mut self, name: &str, n: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += n;
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record one observation in the named log-bucketed histogram.
    pub fn observe(&mut self, name: &str, v: u64) {
        self.histograms.entry(name.to_string()).or_default().observe(v);
    }

    /// Fold a pre-built histogram into the named one (bucketwise add).
    pub fn observe_histogram(&mut self, name: &str, h: &Histogram) {
        self.histograms.entry(name.to_string()).or_default().merge(h);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Merge another registry (summing counters and histogram
    /// buckets, last-writer gauges).
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Render as a JSON object (sorted keys — stable for goldens).
    /// Histograms flatten to `hist.<name>.{count,sum,mean,p50,p99}`.
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::{num, Value};
        let mut obj = std::collections::BTreeMap::new();
        for (k, v) in &self.counters {
            obj.insert(format!("counter.{k}"), num(*v as f64));
        }
        for (k, v) in &self.gauges {
            obj.insert(format!("gauge.{k}"), num(*v));
        }
        for (k, h) in &self.histograms {
            obj.insert(format!("hist.{k}.count"), num(h.count() as f64));
            obj.insert(format!("hist.{k}.sum"), num(h.sum() as f64));
            obj.insert(format!("hist.{k}.mean"), num(h.mean()));
            obj.insert(format!("hist.{k}.p50"), num(h.quantile(0.5) as f64));
            obj.insert(format!("hist.{k}.p99"), num(h.quantile(0.99) as f64));
        }
        Value::Obj(obj)
    }
}

/// Canonical `name{k=v,...}` key for a labelled metric — labels are
/// rendered in the given order, so callers keep them sorted when
/// stability matters.
pub fn labeled(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> = labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
    format!("{name}{{{}}}", body.join(","))
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// CSV writer with header schema enforcement.
pub struct CsvWriter<W: Write> {
    out: W,
    columns: usize,
}

impl<W: Write> CsvWriter<W> {
    pub fn new(mut out: W, header: &[&str]) -> Result<Self> {
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        if cells.len() != self.columns {
            return Err(Error::schedule(format!(
                "csv row has {} cells, header has {}",
                cells.len(),
                self.columns
            )));
        }
        writeln!(self.out, "{}", cells.join(","))?;
        Ok(())
    }

    pub fn into_inner(self) -> W {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let mut c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn registry_counts_and_gauges() {
        let mut r = Registry::new();
        r.count("tokens", 10);
        r.count("tokens", 5);
        r.gauge("loss", 3.5);
        assert_eq!(r.counter("tokens"), 15);
        assert_eq!(r.gauge_value("loss"), Some(3.5));
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn registry_merge() {
        let mut a = Registry::new();
        a.count("x", 1);
        a.gauge("g", 1.0);
        let mut b = Registry::new();
        b.count("x", 2);
        b.gauge("g", 2.0);
        a.merge(&b);
        assert_eq!(a.counter("x"), 3);
        assert_eq!(a.gauge_value("g"), Some(2.0));
    }

    #[test]
    fn registry_histograms_observe_and_merge() {
        let mut a = Registry::new();
        a.observe("stage.eval_ns", 100);
        a.observe("stage.eval_ns", 1000);
        let mut b = Registry::new();
        b.observe("stage.eval_ns", 10);
        a.merge(&b);
        let h = a.histogram("stage.eval_ns").unwrap();
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 1110);
        assert!(a.histogram("missing").is_none());
        let j = a.to_json().to_string_compact();
        assert!(j.contains("\"hist.stage.eval_ns.count\":3"));
        assert!(j.contains("\"hist.stage.eval_ns.sum\":1110"));
    }

    #[test]
    fn labeled_keys_render_canonically() {
        assert_eq!(labeled("cache", &[]), "cache");
        assert_eq!(
            labeled("cache", &[("kind", "hit"), ("shard", "2")]),
            "cache{kind=hit,shard=2}"
        );
    }

    #[test]
    fn registry_json_stable() {
        let mut r = Registry::new();
        r.count("b", 1);
        r.count("a", 2);
        let j = r.to_json().to_string_compact();
        assert!(j.find("counter.a").unwrap() < j.find("counter.b").unwrap());
    }

    #[test]
    fn csv_schema_enforced() {
        let mut w = CsvWriter::new(Vec::new(), &["a", "b"]).unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        let bytes = w.into_inner();
        assert_eq!(String::from_utf8(bytes).unwrap(), "a,b\n1,2\n");
    }

    #[test]
    fn timer_progresses() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_s() > 0.0);
    }
}
