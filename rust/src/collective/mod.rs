//! Collective-communication cost models (α–β) for the simulator.
//!
//! The paper's testbed fabric is undisclosed; we use the standard
//! latency–bandwidth (α–β) model with defaults in the NVLink/IB class.
//! Only *relative* timing matters for the Fig. 4 trends (chunking adds
//! per-chunk all-to-all launches; recompute doubles expert compute),
//! and those relations are structural, not constants.

/// Link/fabric parameters.
#[derive(Clone, Copy, Debug)]
pub struct Fabric {
    /// Per-message latency in seconds (α).
    pub alpha_s: f64,
    /// Per-byte time in seconds (1/bandwidth, β).
    pub beta_s_per_byte: f64,
}

impl Default for Fabric {
    fn default() -> Self {
        // 200 GB/s effective per-GPU all-to-all bandwidth (NVLink-class
        // intra-group fabric), 15 µs launch.
        Fabric { alpha_s: 15e-6, beta_s_per_byte: 1.0 / 200e9 }
    }
}

impl Fabric {
    /// Point-to-point send of `bytes`.
    pub fn p2p(&self, bytes: u64) -> f64 {
        self.alpha_s + bytes as f64 * self.beta_s_per_byte
    }

    /// All-to-all over `n` ranks where each rank exchanges
    /// `bytes_per_rank` with every peer: time of the bottleneck rank.
    /// Pairwise-exchange algorithm: (n−1) rounds of α plus the full
    /// egress volume at β.
    pub fn all_to_all(&self, n: u64, bytes_per_rank: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * self.alpha_s
            + ((n - 1) * bytes_per_rank) as f64 * self.beta_s_per_byte
    }

    /// Imbalanced all-to-all: the bottleneck is the rank with the
    /// largest ingress volume (`max_recv_bytes`); launch cost as above.
    pub fn all_to_all_imbalanced(&self, n: u64, max_recv_bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * self.alpha_s + max_recv_bytes as f64 * self.beta_s_per_byte
    }

    /// Ring all-reduce of `bytes` over `n` ranks: 2(n−1)/n of the data
    /// crosses each link, 2(n−1) launches.
    pub fn all_reduce(&self, n: u64, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        2.0 * (n - 1) as f64 * self.alpha_s
            + 2.0 * ((n - 1) as f64 / n as f64) * bytes as f64 * self.beta_s_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fab() -> Fabric {
        Fabric { alpha_s: 1e-5, beta_s_per_byte: 1e-9 }
    }

    #[test]
    fn p2p_is_affine() {
        let f = fab();
        assert!((f.p2p(0) - 1e-5).abs() < 1e-12);
        assert!((f.p2p(1_000_000) - (1e-5 + 1e-3)).abs() < 1e-9);
    }

    #[test]
    fn all_to_all_single_rank_free() {
        assert_eq!(fab().all_to_all(1, 123), 0.0);
        assert_eq!(fab().all_to_all_imbalanced(1, 123), 0.0);
    }

    #[test]
    fn all_to_all_scales_with_ranks_and_bytes() {
        let f = fab();
        let t1 = f.all_to_all(8, 1_000_000);
        let t2 = f.all_to_all(8, 2_000_000);
        let t3 = f.all_to_all(16, 1_000_000);
        assert!(t2 > t1 && t3 > t1);
        // doubling bytes roughly doubles the β term
        let beta1 = t1 - 7.0 * f.alpha_s;
        let beta2 = t2 - 7.0 * f.alpha_s;
        assert!((beta2 / beta1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn imbalanced_bottleneck_dominates() {
        let f = fab();
        // same total volume, hot rank receives it all → slower than the
        // balanced exchange of the per-rank share
        let balanced = f.all_to_all(32, 1_000_000 / 31);
        let hot = f.all_to_all_imbalanced(32, 1_000_000);
        assert!(hot > balanced);
    }

    #[test]
    fn all_reduce_volume_factor() {
        let f = fab();
        let n = 4;
        let t = f.all_reduce(n, 1_000_000);
        let beta = t - 2.0 * 3.0 * f.alpha_s;
        let want = 2.0 * 0.75 * 1_000_000.0 * f.beta_s_per_byte;
        assert!((beta - want).abs() < 1e-12);
    }

    #[test]
    fn chunking_adds_launch_overhead_only() {
        // c chunks of v/c bytes vs one launch of v bytes: β equal,
        // extra (c−1)(n−1)α — the MACT performance trade-off.
        let f = fab();
        let n = 32u64;
        let v = 8_000_000u64;
        let one = f.all_to_all(n, v);
        let c = 8u64;
        let chunked: f64 = (0..c).map(|_| f.all_to_all(n, v / c)).sum();
        let extra = chunked - one;
        let want = (c - 1) as f64 * (n - 1) as f64 * f.alpha_s;
        assert!((extra - want).abs() < 1e-9, "extra {extra} want {want}");
    }
}
