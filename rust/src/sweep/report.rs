//! Sweep reduction: per-scenario rows and per-(model, method) cell
//! aggregates, serialised as deterministic JSON.
//!
//! Everything here is computed from scenario results **sorted by grid
//! index**, with floating-point accumulation in that fixed order, and
//! serialised through the crate's sorted-key JSON writer — so the
//! emitted bytes are identical for any worker count or scheduling
//! order. The integration suite asserts this bit-for-bit.
//!
//! The aggregates are the paper's own headline quantities: average TGS
//! (Eq. 10) over trained runs, OOM rates (Eq. 3 violations), peak
//! activation bytes (Eq. 2), and the memory-model deltas of each
//! method against Method 1 (Table 4's reduction percentages).

use crate::bench::BenchReport;
use crate::config::SweepConfig;
use crate::json::{self, Value};
use crate::sim::RunOutcome;
use crate::sweep::grid::Scenario;
use crate::util::fmt_bytes;

/// Flat result of one scenario — everything the aggregation and the
/// JSON artifact need, nothing the thread scheduler could perturb.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioResult {
    pub index: usize,
    pub model: String,
    pub method: String,
    pub seed: u64,
    pub iterations: u64,
    pub trained: bool,
    pub oom_iterations: u64,
    pub avg_tgs: f64,
    pub peak_act_bytes: u64,
    pub peak_total_bytes: u64,
    pub static_bytes: u64,
}

impl ScenarioResult {
    pub fn new(scenario: &Scenario, out: &RunOutcome) -> Self {
        ScenarioResult {
            index: scenario.index,
            model: scenario.model.clone(),
            method: scenario.method.name(),
            seed: scenario.seed,
            iterations: out.iterations.len() as u64,
            trained: out.trained(),
            oom_iterations: out.oom_iterations,
            avg_tgs: out.avg_tgs,
            peak_act_bytes: out.peak_act_bytes,
            peak_total_bytes: out
                .iterations
                .iter()
                .map(|i| i.peak_total_bytes)
                .max()
                .unwrap_or(0),
            static_bytes: out.static_bytes,
        }
    }

    fn to_json(&self) -> Value {
        json::obj(vec![
            ("index", json::num(self.index as f64)),
            ("model", json::s(self.model.clone())),
            ("method", json::s(self.method.clone())),
            ("seed", json::num(self.seed as f64)),
            ("iterations", json::num(self.iterations as f64)),
            ("trained", Value::Bool(self.trained)),
            ("oom_iterations", json::num(self.oom_iterations as f64)),
            ("avg_tgs", json::num(self.avg_tgs)),
            ("peak_act_bytes", json::num(self.peak_act_bytes as f64)),
            ("peak_total_bytes", json::num(self.peak_total_bytes as f64)),
            ("static_bytes", json::num(self.static_bytes as f64)),
        ])
    }
}

/// Aggregate of one (model, method) cell across its seeds.
#[derive(Clone, Debug, PartialEq)]
pub struct CellStats {
    pub model: String,
    pub method: String,
    pub runs: u64,
    pub trained_runs: u64,
    /// Fraction of runs with at least one OOM iteration.
    pub oom_run_rate: f64,
    /// Fraction of simulated iterations that violated Eq. 3.
    pub oom_iteration_rate: f64,
    /// Mean of per-run average TGS over trained runs (0 if none).
    pub avg_tgs: f64,
    /// Worst activation peak across the cell's runs (Eq. 2).
    pub peak_act_bytes: u64,
    /// Worst total (static + activation) peak across runs.
    pub peak_total_bytes: u64,
    pub static_bytes: u64,
    /// Memory-model delta vs the same model's Method 1 cell:
    /// activation reduction in percent (Table 4's headline), when a
    /// Method 1 cell exists in the grid.
    pub act_reduction_vs_m1_pct: Option<f64>,
    /// TGS delta vs Method 1 in percent, when Method 1 trained.
    pub tgs_vs_m1_pct: Option<f64>,
}

impl CellStats {
    fn to_json(&self) -> Value {
        let opt = |v: Option<f64>| v.map(json::num).unwrap_or(Value::Null);
        json::obj(vec![
            ("model", json::s(self.model.clone())),
            ("method", json::s(self.method.clone())),
            ("runs", json::num(self.runs as f64)),
            ("trained_runs", json::num(self.trained_runs as f64)),
            ("oom_run_rate", json::num(self.oom_run_rate)),
            ("oom_iteration_rate", json::num(self.oom_iteration_rate)),
            ("avg_tgs", json::num(self.avg_tgs)),
            ("peak_act_bytes", json::num(self.peak_act_bytes as f64)),
            ("peak_total_bytes", json::num(self.peak_total_bytes as f64)),
            ("static_bytes", json::num(self.static_bytes as f64)),
            ("act_reduction_vs_m1_pct", opt(self.act_reduction_vs_m1_pct)),
            ("tgs_vs_m1_pct", opt(self.tgs_vs_m1_pct)),
        ])
    }
}

/// The aggregated outcome of a sweep. Note: the worker count is
/// deliberately NOT part of the report — identical grids must emit
/// identical bytes however they were scheduled.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub config: SweepConfig,
    pub scenarios: Vec<ScenarioResult>,
    pub cells: Vec<CellStats>,
}

impl SweepReport {
    /// Reduce scenario results (any order) into the report. Results
    /// are sorted by grid index first so every float accumulates in a
    /// fixed order.
    pub fn build(config: SweepConfig, mut results: Vec<ScenarioResult>) -> Self {
        results.sort_by_key(|r| r.index);
        // Cells follow the config's model × method enumeration order.
        let mut cells = Vec::with_capacity(config.models.len() * config.methods.len());
        for model in &config.models {
            for method in &config.methods {
                let name = method.name();
                let cell: Vec<&ScenarioResult> = results
                    .iter()
                    .filter(|r| &r.model == model && r.method == name)
                    .collect();
                if cell.is_empty() {
                    continue;
                }
                let runs = cell.len() as u64;
                let trained: Vec<&&ScenarioResult> =
                    cell.iter().filter(|r| r.trained).collect();
                let total_iters: u64 = cell.iter().map(|r| r.iterations).sum();
                let oom_iters: u64 = cell.iter().map(|r| r.oom_iterations).sum();
                let avg_tgs = if trained.is_empty() {
                    0.0
                } else {
                    trained.iter().map(|r| r.avg_tgs).sum::<f64>() / trained.len() as f64
                };
                cells.push(CellStats {
                    model: model.clone(),
                    method: name,
                    runs,
                    trained_runs: trained.len() as u64,
                    oom_run_rate: (runs - trained.len() as u64) as f64 / runs as f64,
                    oom_iteration_rate: if total_iters == 0 {
                        0.0
                    } else {
                        oom_iters as f64 / total_iters as f64
                    },
                    avg_tgs,
                    peak_act_bytes: cell.iter().map(|r| r.peak_act_bytes).max().unwrap_or(0),
                    peak_total_bytes: cell
                        .iter()
                        .map(|r| r.peak_total_bytes)
                        .max()
                        .unwrap_or(0),
                    static_bytes: cell.iter().map(|r| r.static_bytes).max().unwrap_or(0),
                    act_reduction_vs_m1_pct: None,
                    tgs_vs_m1_pct: None,
                });
            }
        }
        // Second pass: memory-model deltas vs each model's Method 1
        // cell (Table 4's reduction column).
        let m1_name = crate::config::Method::FullRecompute.name();
        let baselines: Vec<(String, u64, f64, u64)> = cells
            .iter()
            .filter(|c| c.method == m1_name)
            .map(|c| (c.model.clone(), c.peak_act_bytes, c.avg_tgs, c.trained_runs))
            .collect();
        for cell in &mut cells {
            if cell.method == m1_name {
                continue;
            }
            if let Some((_, m1_act, m1_tgs, m1_trained)) =
                baselines.iter().find(|(m, ..)| *m == cell.model)
            {
                if *m1_act > 0 {
                    cell.act_reduction_vs_m1_pct =
                        Some(100.0 * (1.0 - cell.peak_act_bytes as f64 / *m1_act as f64));
                }
                // a TGS delta needs throughput data on BOTH sides: a
                // cell that never trained has no measurement, not a
                // −100 % slowdown.
                if *m1_trained > 0 && *m1_tgs > 0.0 && cell.trained_runs > 0 {
                    cell.tgs_vs_m1_pct = Some(100.0 * (cell.avg_tgs / m1_tgs - 1.0));
                }
            }
        }
        SweepReport { config, scenarios: results, cells }
    }

    /// Deterministic JSON artifact (sorted keys, fixed array order).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("config", self.config.to_json()),
            (
                "scenarios",
                json::arr(self.scenarios.iter().map(ScenarioResult::to_json).collect()),
            ),
            (
                "cells",
                json::arr(self.cells.iter().map(CellStats::to_json).collect()),
            ),
        ])
    }

    /// Human-readable per-cell table for the CLI.
    pub fn render_table(&self) -> String {
        let mut report = BenchReport::new(
            &format!(
                "sweep — {} scenarios ({} models × {} methods × {} seeds, {} iters)",
                self.scenarios.len(),
                self.config.models.len(),
                self.config.methods.len(),
                self.config.seeds.len(),
                self.config.iterations
            ),
            &[
                "model", "method", "trained", "OOM iter %", "avg TGS", "peak act",
                "Δact vs m1", "ΔTGS vs m1",
            ],
        );
        for c in &self.cells {
            let pct = |v: Option<f64>| {
                v.map(|x| format!("{x:+.1} %")).unwrap_or_else(|| "-".into())
            };
            report.row(&[
                c.model.clone(),
                c.method.clone(),
                format!("{}/{}", c.trained_runs, c.runs),
                format!("{:.1}", 100.0 * c.oom_iteration_rate),
                format!("{:.0}", c.avg_tgs),
                fmt_bytes(c.peak_act_bytes),
                pct(c.act_reduction_vs_m1_pct),
                pct(c.tgs_vs_m1_pct),
            ]);
        }
        report.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    fn result(
        index: usize,
        model: &str,
        method: &Method,
        seed: u64,
        trained: bool,
        avg_tgs: f64,
        peak_act: u64,
    ) -> ScenarioResult {
        ScenarioResult {
            index,
            model: model.into(),
            method: method.name(),
            seed,
            iterations: 10,
            trained,
            oom_iterations: if trained { 0 } else { 4 },
            avg_tgs,
            peak_act_bytes: peak_act,
            peak_total_bytes: peak_act + 1000,
            static_bytes: 500,
        }
    }

    fn two_cell_config() -> SweepConfig {
        SweepConfig {
            models: vec!["i".into()],
            methods: vec![Method::FullRecompute, Method::FixedChunk(8)],
            seeds: vec![1, 2],
            iterations: 10,
        }
    }

    #[test]
    fn build_sorts_and_aggregates() {
        let m1 = Method::FullRecompute;
        let m2 = Method::FixedChunk(8);
        // shuffled input order — build must sort by index
        let results = vec![
            result(3, "i", &m2, 2, true, 120.0, 400),
            result(0, "i", &m1, 1, true, 100.0, 1000),
            result(2, "i", &m2, 1, true, 110.0, 500),
            result(1, "i", &m1, 2, false, 0.0, 1200),
        ];
        let report = SweepReport::build(two_cell_config(), results);
        assert_eq!(
            report.scenarios.iter().map(|r| r.index).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(report.cells.len(), 2);
        let c1 = &report.cells[0];
        assert_eq!(c1.method, m1.name());
        assert_eq!(c1.runs, 2);
        assert_eq!(c1.trained_runs, 1);
        assert_eq!(c1.oom_run_rate, 0.5);
        assert_eq!(c1.oom_iteration_rate, 4.0 / 20.0);
        assert_eq!(c1.avg_tgs, 100.0); // only the trained run counts
        assert_eq!(c1.peak_act_bytes, 1200);
        let c2 = &report.cells[1];
        assert_eq!(c2.avg_tgs, 115.0);
        assert_eq!(c2.peak_act_bytes, 500);
        // deltas vs m1: 500 vs 1200 → 58.33 % reduction
        let red = c2.act_reduction_vs_m1_pct.unwrap();
        assert!((red - 100.0 * (1.0 - 500.0 / 1200.0)).abs() < 1e-9);
        let tgs = c2.tgs_vs_m1_pct.unwrap();
        assert!((tgs - 15.0).abs() < 1e-9);
        assert!(c1.act_reduction_vs_m1_pct.is_none());
    }

    #[test]
    fn json_is_input_order_independent() {
        let m1 = Method::FullRecompute;
        let m2 = Method::FixedChunk(8);
        let a = vec![
            result(0, "i", &m1, 1, true, 100.0, 1000),
            result(1, "i", &m1, 2, true, 101.0, 1100),
            result(2, "i", &m2, 1, true, 110.0, 500),
            result(3, "i", &m2, 2, true, 120.0, 400),
        ];
        let mut b = a.clone();
        b.reverse();
        let ja = SweepReport::build(two_cell_config(), a).to_json().to_string_pretty();
        let jb = SweepReport::build(two_cell_config(), b).to_json().to_string_pretty();
        assert_eq!(ja, jb);
        // and the artifact reparses
        crate::json::parse(&ja).unwrap();
    }

    #[test]
    fn table_renders_all_cells() {
        let m1 = Method::FullRecompute;
        let results = vec![result(0, "i", &m1, 1, true, 100.0, 1000)];
        let mut cfg = two_cell_config();
        cfg.methods = vec![m1];
        cfg.seeds = vec![1];
        let table = SweepReport::build(cfg, results).render_table();
        assert!(table.contains("method1/full-recompute"));
        assert!(table.contains("1/1"));
    }
}
