//! Mini property-testing harness (no `proptest` in the offline
//! registry).
//!
//! `check(seed_cases, gen, prop)` draws `seed_cases` random inputs from
//! `gen` and asserts `prop` on each; on failure it attempts a bounded
//! greedy shrink via the generator's `shrink` candidates and reports
//! the smallest failing case. Enough machinery for the coordinator
//! invariants (routing conservation, dispatch round-trips, chunk
//! schedules, memory monotonicity) that the brief calls for.

use crate::util::rng::Rng;

/// A generator of random values with optional shrinking.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate simpler values (default: none).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform u64 in [lo, hi].
pub struct U64Range(pub u64, pub u64);

impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Vector of fixed length from an element generator.
pub struct VecGen<G: Gen>(pub G, pub usize);

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (0..self.1).map(|_| self.0.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        // shrink one element at a time (first shrinkable position)
        let mut out = Vec::new();
        for (i, elem) in v.iter().enumerate() {
            for cand in self.0.shrink(elem) {
                let mut copy = v.clone();
                copy[i] = cand;
                out.push(copy);
                if out.len() >= 8 {
                    return out;
                }
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairGen<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum Outcome<V> {
    Pass { cases: usize },
    Fail { case: V, shrunk: bool, message: String },
}

/// Run `cases` random checks of `prop`. Returns `Outcome::Fail` with a
/// (possibly shrunk) counterexample instead of panicking, so tests can
/// assert and report cleanly via [`assert_prop`].
pub fn check<G: Gen>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) -> Outcome<G::Value> {
    let mut rng = Rng::new(seed);
    for _ in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // bounded greedy shrink
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut shrunk = false;
            'outer: for _round in 0..64 {
                for cand in gen.shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        shrunk = true;
                        continue 'outer;
                    }
                }
                break;
            }
            return Outcome::Fail { case: best, shrunk, message: best_msg };
        }
    }
    Outcome::Pass { cases }
}

/// Panicking wrapper for use inside `#[test]`s.
pub fn assert_prop<G: Gen>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    match check(seed, cases, gen, prop) {
        Outcome::Pass { .. } => {}
        Outcome::Fail { case, shrunk, message } => {
            panic!("property failed (shrunk={shrunk}): {message}\ncase: {case:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        match check(1, 200, &U64Range(0, 100), |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        }) {
            Outcome::Pass { cases } => assert_eq!(cases, 200),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn failing_property_shrinks_toward_minimum() {
        // property "v < 50" fails for v ≥ 50; shrinking should walk
        // toward small failing values (not necessarily exactly 50, but
        // strictly smaller than an unshrunk random failure on average).
        match check(2, 500, &U64Range(0, 1000), |&v| {
            if v < 50 {
                Ok(())
            } else {
                Err(format!("{v} >= 50"))
            }
        }) {
            Outcome::Fail { case, .. } => assert!(case >= 50 && case <= 500),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn vec_gen_shapes() {
        let g = VecGen(U64Range(1, 5), 7);
        let mut rng = Rng::new(3);
        let v = g.generate(&mut rng);
        assert_eq!(v.len(), 7);
        assert!(v.iter().all(|&x| (1..=5).contains(&x)));
    }

    #[test]
    fn pair_gen_and_shrink() {
        let g = PairGen(U64Range(0, 10), U64Range(0, 10));
        let mut rng = Rng::new(4);
        let v = g.generate(&mut rng);
        let shrinks = g.shrink(&v);
        if v.0 > 0 || v.1 > 0 {
            assert!(!shrinks.is_empty());
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn assert_prop_panics_on_failure() {
        assert_prop(5, 100, &U64Range(0, 10), |&v| {
            if v < 5 {
                Ok(())
            } else {
                Err("nope".into())
            }
        });
    }
}
