//! Property test of the fused cell evaluator: across randomised
//! (model, memory envelope, iteration count, seed, sampler, method
//! set) cells, `sim::evaluate_cell` must be **bit-identical** to
//! per-method `sim::run_scenario_on_trace` — and transitively to the
//! per-scenario `sim::run_scenario_sampled` under the same sampler
//! (which re-draws the trace from the seed). Cases cover both router
//! samplers and OOM-heavy cells (budgets small enough that every
//! iteration violates Eq. 3), so both the trained and the all-OOM
//! aggregation paths are exercised.

use memfine::config::{model_i, model_ii, paper_run, Method, GB};
use memfine::prop::{assert_prop, Gen};
use memfine::router::GatingSim;
use memfine::sim::{evaluate_cell, run_scenario_on_trace, run_scenario_sampled, RunSummary};
use memfine::trace::{RouterSampler, SharedRoutingTrace};
use memfine::util::rng::Rng;

/// One randomised paired-comparison cell.
#[derive(Clone, Debug)]
struct Case {
    model_ii: bool,
    seed: u64,
    iterations: u64,
    gpu_mem_gb: u64,
    fast_router: bool,
    selective: bool,
    methods: Vec<Method>,
}

struct CaseGen;

impl Gen for CaseGen {
    type Value = Case;

    fn generate(&self, rng: &mut Rng) -> Case {
        // Method pool: always MACT (the interesting decision path),
        // plus a random subset of the others — duplicates included
        // sometimes (the fused path must treat each entry
        // independently).
        let mut methods = vec![Method::Mact(vec![1, 2, 4, 8])];
        if rng.below(2) == 1 {
            methods.push(Method::FullRecompute);
        }
        if rng.below(2) == 1 {
            methods.push(Method::FixedChunk(1 + rng.below(8)));
        }
        if rng.below(4) == 0 {
            methods.push(Method::Mact(vec![1, 2, 4, 8]));
        }
        Case {
            model_ii: rng.below(2) == 1,
            seed: rng.below(1 << 16),
            iterations: 3 + rng.below(5),
            // 24 GB sinks under static memory (all-OOM cells); 64/80 GB
            // are the paper's envelopes.
            gpu_mem_gb: [24u64, 48, 64, 80][rng.below(4) as usize],
            fast_router: rng.below(2) == 1,
            selective: rng.below(4) != 0,
            methods,
        }
    }
}

#[test]
fn prop_fused_cell_bit_identical_to_reference_paths() {
    assert_prop(113, 10, &CaseGen, |case: &Case| {
        let model = if case.model_ii { model_ii() } else { model_i() };
        let mut base = paper_run(model, Method::FullRecompute);
        base.iterations = case.iterations;
        base.gpu_mem_bytes = case.gpu_mem_gb * GB;
        base.allow_selective_recompute = case.selective;

        let gating = GatingSim::new(base.model.clone(), base.parallel.clone(), case.seed)
            .with_fast_multinomial(case.fast_router);
        let trace = SharedRoutingTrace::generate(&gating, case.iterations);

        let fused = evaluate_cell(&base, &case.methods, &trace)
            .map_err(|e| format!("evaluate_cell failed: {e}"))?;
        if fused.len() != case.methods.len() {
            return Err(format!(
                "{} outcomes for {} methods",
                fused.len(),
                case.methods.len()
            ));
        }
        for (outcome, method) in fused.iter().zip(&case.methods) {
            if &outcome.method != method {
                return Err(format!("method order broken at {method:?}"));
            }
            let on_trace = run_scenario_on_trace(&base, method.clone(), &trace)
                .map_err(|e| format!("run_scenario_on_trace failed: {e}"))?;
            let reference = RunSummary::of(&on_trace);
            if outcome.summary != reference {
                return Err(format!(
                    "fused != on-trace for {method:?}:\n  fused {:?}\n  ref   {:?}",
                    outcome.summary, reference
                ));
            }
            // float fields to the bit, not just PartialEq
            if outcome.summary.avg_tgs.to_bits() != reference.avg_tgs.to_bits() {
                return Err(format!("avg_tgs bits differ for {method:?}"));
            }
            for (a, b) in outcome
                .summary
                .chunk_mean_per_iteration
                .iter()
                .zip(&reference.chunk_mean_per_iteration)
            {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("chunk-mean bits differ for {method:?}"));
                }
            }
            // close the loop to the per-scenario reference (which
            // re-draws the same trace from the seed) under whichever
            // sampler this case drew with
            let direct = run_scenario_sampled(
                &base,
                method.clone(),
                case.seed,
                RouterSampler::from_fast_flag(case.fast_router),
            )
            .map_err(|e| format!("run_scenario_sampled failed: {e}"))?;
            if outcome.summary != RunSummary::of(&direct) {
                return Err(format!("fused != per-scenario for {method:?}"));
            }
        }
        Ok(())
    });
}
