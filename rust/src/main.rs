//! `memfine` — CLI for the MemFine reproduction.
//!
//! Subcommands map 1:1 onto the paper's artifacts (DESIGN.md §4):
//!
//! ```text
//! memfine plan    [--model i|ii]             memory model walkthrough (Eq. 1–3, 8)
//! memfine simulate [--model i|ii] [--method 1|2|3] [--iters N]
//! memfine sweep   [--models i,ii] [--methods 1,2,3] [--seeds N|a,b,...]
//!                 [--workers N] [--out FILE] [--checkpoint F[,F...]]
//!                 [--resume] [--shard i/n] [--limit N] [--router seq|split]
//!                 [--rng v1|v2] [--split-iters N] [--trace-cache DIR]
//!                 [--unfused] [--config FILE] [--events FILE]
//!                 [--pool stealing|injector] [--channel bounded|std]
//!                 [--pin-cores] [--pool-stats]
//!                 parallel scenario grid, resumable/shardable
//! memfine launch  [grid flags | --config FILE] [--procs N] [--dir DIR]
//!                 [--stall-timeout-ms N] [--poll-ms N] [--retries N]
//!                 [--hosts local,ssh:h1,...] [--lease-timeout-ms N]
//!                 [--trace-cache GLOBAL] [--chaos-kill] [--no-telemetry]
//!                 [--out FILE]
//!                 orchestrated multi-process sweep: spawn, supervise,
//!                 heal, auto-merge — optionally across hosts under
//!                 lease-based whole-host loss healing
//! memfine status  [DIR]                     campaign status: shard table,
//!                 coverage, cache hit rate, ETA (heartbeats + event log)
//! memfine events  [DIR|FILE] [--type T] [--shard N] [--hash H] [--summary]
//!                 filter or summarise a campaign's events.jsonl
//! memfine checkpoint compact FILE... [--out FILE]
//! memfine checkpoint audit FILE... --config FILE [--router seq|split] [--rng v1|v2]
//! memfine trace-cache stats|gc DIR [--max-age-h N]   shared cache upkeep
//! memfine repro   table4|fig2|fig4|fig5      regenerate a paper artifact
//! memfine train   [--steps N] [--artifacts DIR]  E2E mini-model training
//! memfine coord   [--policy mact|fixed] [--budget-mb N]  real EP layer pass
//! ```

use memfine::cli::{usage, Args, OptSpec};
use memfine::config::{
    derive_seeds, model_i, model_ii, paper_run, LaunchConfig, Method, ModelConfig,
    SweepConfig,
};
use memfine::trace::{RngVersion, RouterSampler, TraceProvenance};
use memfine::coordinator::ep::{ChunkPolicy, EpCoordinator};
use memfine::coordinator::train::TrainDriver;
use memfine::memory::{ActivationModel, StaticModel};
use memfine::orchestrator::LaunchOptions;
use memfine::runtime::ArtifactStore;
use memfine::sim::Simulator;
use memfine::util::fmt_bytes;

const VALUE_OPTS: &[&str] = &[
    "model", "method", "iters", "seed", "steps", "artifacts", "policy",
    "budget-mb", "bins", "chunk", "models", "methods", "seeds", "workers",
    "out", "checkpoint", "shard", "limit", "config", "procs", "dir",
    "stall-timeout-ms", "poll-ms", "retries", "campaign-retries",
    "backoff-ms", "chaos-plan", "chaos-seed", "router", "trace-cache",
    "pool", "channel", "rng", "split-iters", "events", "type", "hash",
    "hosts", "lease-timeout-ms", "max-age-h",
];

fn main() {
    memfine::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match Args::parse(args, VALUE_OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if parsed.command.is_none() || parsed.has_flag("help") {
        print_usage();
        return;
    }
    let cmd = parsed.command.clone().unwrap();
    let result = match cmd.as_str() {
        "plan" => cmd_plan(&parsed),
        "simulate" => cmd_simulate(&parsed),
        "sweep" => cmd_sweep(&parsed),
        "launch" => cmd_launch(&parsed),
        "status" => cmd_status(&parsed),
        "events" => cmd_events(&parsed),
        "checkpoint" => cmd_checkpoint(&parsed),
        "trace-cache" => cmd_trace_cache(&parsed),
        "repro" => cmd_repro(&parsed),
        "train" => cmd_train(&parsed),
        "coord" => cmd_coord(&parsed),
        other => {
            eprintln!("unknown command '{other}'");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    print!(
        "{}",
        usage(
            "memfine",
            "MemFine: memory-aware fine-grained scheduling for MoE training",
            &[
                ("plan", "memory model walkthrough (Eq. 1-3, Eq. 8)"),
                ("simulate", "simulate a training run (methods 1/2/3)"),
                ("sweep", "parallel scenario grid: models x methods x seeds"),
                ("launch", "orchestrated multi-process sweep: spawn, supervise, heal, merge"),
                ("status", "campaign status: shard table, coverage, cache hit rate, ETA"),
                ("events", "filter/summarise a campaign event log (events.jsonl)"),
                ("checkpoint", "checkpoint tools: compact FILE... | audit FILE... --config F"),
                ("trace-cache", "shared trace-cache tools: stats DIR | gc DIR --max-age-h N"),
                ("repro", "regenerate a paper artifact: table4|fig2|fig4|fig5"),
                ("train", "end-to-end mini-model training via PJRT"),
                ("coord", "real EP coordinator layer pass"),
            ],
            &[
                OptSpec { name: "model", help: "table-3 model: i or ii", takes_value: true, default: Some("i") },
                OptSpec { name: "method", help: "1=full-recompute 2=fixed-chunk 3=mact", takes_value: true, default: Some("3") },
                OptSpec { name: "chunk", help: "fixed chunk bin for method 2", takes_value: true, default: Some("8") },
                OptSpec { name: "iters", help: "iterations to simulate", takes_value: true, default: Some("25") },
                OptSpec { name: "steps", help: "training steps (train)", takes_value: true, default: Some("50") },
                OptSpec { name: "seed", help: "rng seed", takes_value: true, default: Some("7") },
                OptSpec { name: "models", help: "sweep models, comma-separated (i,ii)", takes_value: true, default: Some("i,ii") },
                OptSpec { name: "methods", help: "sweep methods: 1 | 2[:c] | 3[:b.b...]", takes_value: true, default: Some("1,2,3") },
                OptSpec { name: "seeds", help: "sweep seeds: a count (derived from --seed) or a,b,... list (trailing comma forces list)", takes_value: true, default: Some("4") },
                OptSpec { name: "workers", help: "sweep worker threads (0 = all cores); launch: threads per shard (>= 1)", takes_value: true, default: Some("0") },
                OptSpec { name: "out", help: "sweep JSON output path (- = stdout only)", takes_value: true, default: Some("-") },
                OptSpec { name: "checkpoint", help: "sweep checkpoint file(s), comma-separated; first is the write target", takes_value: true, default: None },
                OptSpec { name: "resume", help: "skip scenarios already in the checkpoint file(s)", takes_value: false, default: None },
                OptSpec { name: "shard", help: "run shard i of n (i/n) of the sweep grid", takes_value: true, default: None },
                OptSpec { name: "limit", help: "execute at most N sweep scenarios this run", takes_value: true, default: None },
                OptSpec { name: "router", help: "routing sampler: split (binomial-splitting, fast) or seq (pre-flip sequential; different sample, hash-distinct)", takes_value: true, default: Some("split") },
                OptSpec { name: "rng", help: "trace generator: v1 (sequential xoshiro forks; the frozen default) or v2 (counter-based Philox; O(1) stream access, enables intra-cell splitting; hash-distinct)", takes_value: true, default: Some("v1") },
                OptSpec { name: "split-iters", help: "sweep: force the v2 intra-cell split width (iterations per job; 0 = auto, v2 only)", takes_value: true, default: Some("0") },
                OptSpec { name: "trace-cache", help: "sweep: routed-trace cache DIR[,GLOBAL] (campaign tier, optional global tier); launch: cross-campaign GLOBAL root behind the campaign cache under --dir", takes_value: true, default: None },
                OptSpec { name: "pool", help: "sweep worker schedule: stealing (per-worker deques) or injector (shared queue); never changes artifact bytes", takes_value: true, default: Some("stealing") },
                OptSpec { name: "channel", help: "sweep result channel: bounded (backpressure, ~4x workers) or std (unbounded mpsc)", takes_value: true, default: Some("bounded") },
                OptSpec { name: "pin-cores", help: "sweep/launch: best-effort pin worker k to core k (Linux sched_setaffinity; no-op elsewhere)", takes_value: false, default: None },
                OptSpec { name: "pool-stats", help: "sweep: print the per-worker jobs/steals/depth table to stderr", takes_value: false, default: None },
                OptSpec { name: "fast-router", help: "deprecated alias for --router split (the default since the sampler flip)", takes_value: false, default: None },
                OptSpec { name: "unfused", help: "evaluate each method as its own pass over the shared trace (pre-fusion A/B path; identical artifacts)", takes_value: false, default: None },
                OptSpec { name: "config", help: "JSON grid/launch spec file (sweep/launch/checkpoint audit)", takes_value: true, default: None },
                OptSpec { name: "procs", help: "launch: shard processes (0 = cores / workers)", takes_value: true, default: Some("0") },
                OptSpec { name: "dir", help: "launch working dir (checkpoints, logs, merged.jsonl)", takes_value: true, default: Some("launch-run") },
                OptSpec { name: "stall-timeout-ms", help: "launch: kill a shard whose checkpoint stalls this long", takes_value: true, default: Some("30000") },
                OptSpec { name: "poll-ms", help: "launch: supervisor poll interval", takes_value: true, default: Some("100") },
                OptSpec { name: "retries", help: "launch: relaunches allowed per shard failure episode (resets on checkpoint progress)", takes_value: true, default: Some("2") },
                OptSpec { name: "campaign-retries", help: "launch: fleet-wide relaunch budget for the campaign (0 = unlimited)", takes_value: true, default: Some("16") },
                OptSpec { name: "backoff-ms", help: "launch: base relaunch backoff, doubling per relaunch with deterministic jitter (0 = none)", takes_value: true, default: Some("100") },
                OptSpec { name: "no-quarantine", help: "launch: keep a given-up shard's checkpoint in place instead of renaming it aside", takes_value: false, default: None },
                OptSpec { name: "hosts", help: "launch: comma-separated host specs (local | ssh:target); shards round-robin across them under the lease plane", takes_value: true, default: None },
                OptSpec { name: "lease-timeout-ms", help: "launch: declare a host lost when its lease stops renewing this long (multi-host only)", takes_value: true, default: Some("10000") },
                OptSpec { name: "max-age-h", help: "trace-cache gc: evict entries older than this many hours", takes_value: true, default: Some("168") },
                OptSpec { name: "chaos-kill", help: "launch: kill one progressing child once (recovery drill)", takes_value: false, default: None },
                OptSpec { name: "chaos-seed", help: "launch: run the seeded chaos drill (kill storm + checkpoint corruption + child ENOSPC), deterministic in seed+dir", takes_value: true, default: None },
                OptSpec { name: "chaos-plan", help: "launch: run the scripted chaos drill from a JSON fault-plan file", takes_value: true, default: None },
                OptSpec { name: "no-telemetry", help: "launch: skip the sidecar event log (artifact bytes are identical either way)", takes_value: false, default: None },
                OptSpec { name: "events", help: "sweep: append engine events to this sidecar JSON-lines log (launch manages its own under --dir)", takes_value: true, default: None },
                OptSpec { name: "type", help: "events: keep only this event type", takes_value: true, default: None },
                OptSpec { name: "hash", help: "events: keep only events whose scenario hash starts with this prefix", takes_value: true, default: None },
                OptSpec { name: "summary", help: "events: print per-type counts instead of event lines", takes_value: false, default: None },
                OptSpec { name: "artifacts", help: "artifact directory", takes_value: true, default: Some("artifacts") },
                OptSpec { name: "policy", help: "coord policy: mact or fixed", takes_value: true, default: Some("mact") },
                OptSpec { name: "budget-mb", help: "coord per-rank memory budget", takes_value: true, default: Some("48") },
            ],
        )
    );
}

fn model_arg(args: &Args) -> Result<ModelConfig, memfine::Error> {
    match args.get_or("model", "i").as_str() {
        "i" | "I" | "1" => Ok(model_i()),
        "ii" | "II" | "2" => Ok(model_ii()),
        other => Err(memfine::Error::Cli(format!("unknown model '{other}'"))),
    }
}

fn method_arg(args: &Args) -> Result<Method, memfine::Error> {
    match args.get_or("method", "3").as_str() {
        "1" => Ok(Method::FullRecompute),
        "2" => Ok(Method::FixedChunk(args.get_u64("chunk", 8)?)),
        "3" => Ok(Method::Mact(args.get_u64_list("bins", &[1, 2, 4, 8])?)),
        other => Err(memfine::Error::Cli(format!("unknown method '{other}'"))),
    }
}

fn cmd_plan(args: &Args) -> memfine::Result<()> {
    let model = model_arg(args)?;
    let run = paper_run(model, Method::Mact(vec![1, 2, 4, 8]));
    let act = ActivationModel::new(&run);
    let sta = StaticModel::new(&run);
    let budget = (run.alpha * run.gpu_mem_bytes as f64) as u64;
    println!(
        "MemFine memory plan — {} layers, e={}, p={}",
        run.model.layers, run.parallel.ep, run.parallel.pp
    );
    println!("GPU budget α·M = {}", fmt_bytes(budget));
    println!("theoretical peak s' = {}", act.s_prime_theoretical_peak());
    println!();
    println!(
        "{:>5} {:>12} {:>12} {:>14} {:>10}",
        "stage", "static", "dense act", "s'_max (Eq.8)", "ideal c"
    );
    for stage in 0..run.parallel.pp {
        let st = sta.bytes_on_rank(stage);
        let s_max = act.s_prime_max(stage, st, budget, true);
        let worst = act.s_prime_theoretical_peak();
        let need = worst.div_ceil(s_max.max(1));
        println!(
            "{:>5} {:>12} {:>12} {:>14} {:>10}",
            stage,
            fmt_bytes(st),
            fmt_bytes(act.dense_bytes()),
            s_max,
            need
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> memfine::Result<()> {
    let model = model_arg(args)?;
    let method = method_arg(args)?;
    let mut run = paper_run(model, method);
    run.iterations = args.get_u64("iters", 25)?;
    run.seed = args.get_u64("seed", 7)?;
    let sim = Simulator::new(run)?;
    let out = sim.run_all();
    println!("method: {}", out.method.name());
    println!("static memory (max stage): {}", fmt_bytes(out.static_bytes));
    println!("peak activation: {}", fmt_bytes(out.peak_act_bytes));
    println!("OOM iterations: {}/{}", out.oom_iterations, out.iterations.len());
    println!("avg TGS (non-OOM): {:.0}", out.avg_tgs);
    for it in &out.iterations {
        println!(
            "  iter {:>2}  act={}  t={:.2}s  TGS={:>7.0}{}",
            it.iteration,
            fmt_bytes(it.peak_act_bytes),
            it.iteration_s,
            it.tgs,
            if it.oom { "  ** OOM **" } else { "" }
        );
    }
    Ok(())
}

/// Build the sweep grid from the CLI flags (`--models/--methods/
/// --seeds/--iters`).
fn sweep_config_from_flags(args: &Args) -> memfine::Result<SweepConfig> {
    let models: Vec<String> = args
        .get_or("models", "i,ii")
        .split(',')
        .map(|m| m.trim().to_string())
        .filter(|m| !m.is_empty())
        .collect();
    let methods = args
        .get_or("methods", "1,2,3")
        .split(',')
        .map(Method::parse)
        .collect::<memfine::Result<Vec<Method>>>()?;
    // --seeds takes either a count (derived from --seed) or an
    // explicit comma-separated list; a trailing comma forces list
    // mode, so a single literal seed is expressible as `--seeds 42,`.
    let seeds_spec = args.get_or("seeds", "4");
    let seeds = if seeds_spec.contains(',') {
        seeds_spec
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(|p| {
                p.parse().map_err(|_| {
                    memfine::Error::Cli(format!("--seeds list has bad entry '{p}'"))
                })
            })
            .collect::<memfine::Result<Vec<u64>>>()?
    } else {
        let n: usize = seeds_spec.trim().parse().map_err(|_| {
            memfine::Error::Cli(format!("--seeds expects a count or list, got '{seeds_spec}'"))
        })?;
        derive_seeds(args.get_u64("seed", 7)?, n)
    };
    Ok(SweepConfig {
        models,
        methods,
        seeds,
        iterations: args.get_u64("iters", 25)?,
    })
}

/// Read and parse a `--config` JSON file.
fn parse_config_file(path: &str) -> memfine::Result<memfine::json::Value> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        memfine::Error::Io(std::io::Error::new(e.kind(), format!("--config {path}: {e}")))
    })?;
    memfine::json::parse(&text)
}

/// Extract a sweep grid from a parsed config document: a bare
/// `SweepConfig`, a `LaunchConfig` (its `sweep` block), or a sweep
/// report artifact (its `config` block) are all accepted — so a
/// checkpoint can be audited, resumed, or relaunched straight from
/// any artifact the tooling writes.
fn sweep_config_from_doc(doc: &memfine::json::Value) -> memfine::Result<SweepConfig> {
    let grid = doc.get("sweep").or_else(|| doc.get("config")).unwrap_or(doc);
    SweepConfig::from_json(grid)
}

/// The explicit sampler choice on the command line, if any: `--router
/// seq|split` is the current spelling; the pre-flip `--fast-router`
/// flag survives as an alias for `--router split`.
fn sampler_flag(args: &Args) -> memfine::Result<Option<RouterSampler>> {
    match args.get("router") {
        Some(tag) => Ok(Some(RouterSampler::parse(tag)?)),
        None if args.has_flag("fast-router") => Ok(Some(RouterSampler::Split)),
        None => Ok(None),
    }
}

/// The explicit generator choice on the command line, if any
/// (`--rng v1|v2`).
fn rng_flag(args: &Args) -> memfine::Result<Option<RngVersion>> {
    args.get("rng").map(|tag| RngVersion::parse(tag)).transpose()
}

/// Extract (grid, sampler, rng) from a parsed config doc: a
/// `LaunchConfig` carries its own sampler and rng choices — both are
/// part of every scenario hash, so resuming or auditing a campaign
/// from its launch.json must not silently fall back to other
/// defaults. Other doc shapes carry neither (resolution falls through
/// to flags, checkpoint headers, or the defaults).
fn grid_and_sampler_from_doc(
    doc: &memfine::json::Value,
) -> memfine::Result<(SweepConfig, Option<RouterSampler>, Option<RngVersion>)> {
    if doc.get("sweep").is_some() {
        let launch = LaunchConfig::from_json(doc)?;
        Ok((launch.sweep, Some(launch.sampler), Some(launch.rng)))
    } else {
        Ok((sweep_config_from_doc(doc)?, None, None))
    }
}

fn cmd_sweep(args: &Args) -> memfine::Result<()> {
    // --config wins over grid flags; a LaunchConfig file also carries
    // its sampler and rng choices (explicit flags override both)
    let (cfg, doc_sampler, doc_rng) = match args.get("config") {
        Some(path) => grid_and_sampler_from_doc(&parse_config_file(path)?)?,
        None => (sweep_config_from_flags(args)?, None, None),
    };
    let checkpoint: Vec<std::path::PathBuf> = args
        .get("checkpoint")
        .map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(std::path::PathBuf::from)
                .collect()
        })
        .unwrap_or_default();
    let shard = args
        .get("shard")
        .map(memfine::config::ShardSpec::parse)
        .transpose()?;
    let limit = args.get("limit").map(|_| args.get_u64("limit", 0)).transpose()?;
    // Sampler and rng resolution mirror `checkpoint audit`, field by
    // field: an explicit flag (or a launch.json's recorded choice)
    // wins; a resumed checkpoint's own provenance header comes next —
    // so a pre-flip campaign resumes under its recorded sampler (and a
    // v2 campaign under its recorded generator) instead of silently
    // re-running the whole grid under the defaults — and only then the
    // engine defaults. A surviving mismatch is warned about once, by
    // the engine itself.
    let resume = args.has_flag("resume");
    let recorded = if resume {
        memfine::sweep::checkpoint::CheckpointSet::peek_provenance(&checkpoint)
    } else {
        None
    };
    let sampler = match (sampler_flag(args)?.or(doc_sampler), &recorded) {
        (Some(s), _) => s,
        (None, Some(p)) => {
            eprintln!("sweep: resuming under the checkpoint's recorded router '{}'", p.tag());
            p.sampler
        }
        (None, None) => RouterSampler::default(),
    };
    let rng = match (rng_flag(args)?.or(doc_rng), &recorded) {
        (Some(v), _) => v,
        (None, Some(p)) => p.rng()?,
        (None, None) => RngVersion::default(),
    };
    // --trace-cache DIR[,GLOBAL]: the campaign tier, optionally backed
    // by a cross-campaign global root (how launch wires its children)
    let trace_cache_arg: Vec<std::path::PathBuf> = args
        .get("trace-cache")
        .map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(std::path::PathBuf::from)
                .collect()
        })
        .unwrap_or_default();
    let opts = memfine::sweep::SweepRunOptions {
        workers: args.get_u64("workers", 0)? as usize,
        checkpoint,
        resume,
        shard,
        limit: limit.map(|n| n as usize),
        sampler,
        rng,
        split_iters: args.get_u64("split-iters", 0)?,
        unfused: args.has_flag("unfused"),
        trace_cache: trace_cache_arg.first().cloned(),
        trace_cache_global: trace_cache_arg.get(1).cloned(),
        pool: memfine::sweep::Schedule::parse(&args.get_or("pool", "stealing"))?,
        channel: memfine::sweep::ChannelKind::parse(&args.get_or("channel", "bounded"))?,
        pin_cores: args.has_flag("pin-cores"),
        events: args.get("events").map(std::path::PathBuf::from),
    };
    eprintln!(
        "sweep: {} scenarios{}{}",
        cfg.scenario_count(),
        match opts.shard {
            Some(s) => format!(", shard {}/{}", s.index, s.count),
            None => String::new(),
        },
        if opts.resume { ", resuming" } else { "" },
    );
    let summary = memfine::sweep::run_sweep_with(&cfg, &opts)?;
    eprintln!(
        "sweep: {} executed, {} resumed, {} skipped (shard/limit){}",
        summary.executed,
        summary.resumed,
        summary.skipped,
        if summary.skipped_checkpoint_lines > 0 {
            format!(
                ", {} unreadable checkpoint line(s) ignored",
                summary.skipped_checkpoint_lines
            )
        } else {
            String::new()
        },
    );
    if opts.trace_cache.is_some() {
        eprintln!(
            "sweep: trace cache: {} cell(s) reused, {} generated, {} write(s) degraded",
            summary.traces_cached, summary.traces_generated, summary.traces_degraded
        );
    }
    if let Some(path) = &opts.events {
        let dropped = summary.metrics.counter("events.dropped");
        eprintln!(
            "sweep: event log: {}{}",
            path.display(),
            if dropped > 0 {
                format!(" ({dropped} event(s) dropped)")
            } else {
                String::new()
            },
        );
    }
    // Execution facts only — PoolStats never enter the JSON artifact.
    if args.has_flag("pool-stats") {
        eprint!("{}", memfine::sweep::report::render_pool_stats(&summary.pool));
    } else {
        eprintln!(
            "sweep: pool {}/{}: {} worker(s), {}/{} steals, {} blocked send(s), \
             tail latency {:.1} ms",
            summary.pool.schedule.tag(),
            summary.pool.channel.tag(),
            summary.pool.workers.len(),
            summary.pool.steals_succeeded(),
            summary.pool.steals_attempted(),
            summary.pool.blocked_sends,
            summary.pool.tail_latency_ns() as f64 / 1e6,
        );
    }
    let report = summary.report;
    // Human-readable table goes to stderr so stdout carries only the
    // JSON artifact — `memfine sweep | jq .` and `> sweep.json` both
    // see a clean, parseable document.
    eprint!("{}", report.render_table());
    let json = report.to_json().to_string_pretty();
    match args.get_or("out", "-").as_str() {
        "-" => println!("{json}"),
        path => {
            std::fs::write(path, format!("{json}\n"))?;
            eprintln!("report written to {path}");
        }
    }
    Ok(())
}

fn cmd_launch(args: &Args) -> memfine::Result<()> {
    // Full LaunchConfig files round-trip (`--config launch.json`);
    // explicit CLI flags override whatever the file carries.
    let mut cfg = match args.get("config") {
        Some(path) => {
            let doc = parse_config_file(path)?;
            if doc.get("sweep").is_some() {
                LaunchConfig::from_json(&doc)?
            } else {
                LaunchConfig::new(sweep_config_from_doc(&doc)?)
            }
        }
        None => LaunchConfig::new(sweep_config_from_flags(args)?),
    };
    if args.get("procs").is_some() {
        cfg.procs = args.get_u64("procs", 0)?;
    }
    if args.get("workers").is_some() {
        // unlike sweep, launch has no 0 = auto: workers here is the
        // per-shard thread count, so 0 is rejected by validate()
        cfg.workers_per_proc = args.get_u64("workers", 1)?;
    }
    if args.get("stall-timeout-ms").is_some() {
        cfg.stall_timeout_ms = args.get_u64("stall-timeout-ms", 30_000)?;
    }
    if args.get("poll-ms").is_some() {
        cfg.poll_ms = args.get_u64("poll-ms", 100)?;
    }
    if args.get("retries").is_some() {
        cfg.max_retries = args.get_u64("retries", 2)?;
    }
    if args.get("campaign-retries").is_some() {
        cfg.campaign_retries = args.get_u64("campaign-retries", 16)?;
    }
    if args.get("backoff-ms").is_some() {
        cfg.backoff_ms = args.get_u64("backoff-ms", 100)?;
    }
    if args.has_flag("no-quarantine") {
        cfg.quarantine = false;
    }
    if let Some(sampler) = sampler_flag(args)? {
        cfg.sampler = sampler;
    }
    if let Some(rng) = rng_flag(args)? {
        cfg.rng = rng;
    }
    if args.has_flag("pin-cores") {
        cfg.pin_cores = true;
    }
    if args.has_flag("no-telemetry") {
        cfg.telemetry = false;
    }
    if let Some(list) = args.get("hosts") {
        cfg.hosts = list
            .split(',')
            .map(str::trim)
            .filter(|h| !h.is_empty())
            .map(str::to_string)
            .collect();
    }
    if args.get("lease-timeout-ms").is_some() {
        cfg.lease_timeout_ms = args.get_u64("lease-timeout-ms", 10_000)?;
    }

    let dir = std::path::PathBuf::from(args.get_or("dir", "launch-run"));
    // Chaos drill sources, in precedence order: an explicit plan file,
    // a seed (expanded against the campaign dir), the legacy one-shot
    // kill flag.
    let fault_plan = if let Some(path) = args.get("chaos-plan") {
        let text = std::fs::read_to_string(path).map_err(|e| {
            memfine::Error::Io(std::io::Error::new(
                e.kind(),
                format!("chaos plan {path}: {e}"),
            ))
        })?;
        Some(memfine::orchestrator::FaultPlan::from_json(
            &memfine::json::parse(&text)?,
        )?)
    } else if args.get("chaos-seed").is_some() {
        Some(memfine::orchestrator::FaultPlan::from_seed(
            args.get_u64("chaos-seed", 0)?,
            &dir,
        ))
    } else if args.has_flag("chaos-kill") {
        Some(memfine::orchestrator::FaultPlan::kill_one())
    } else {
        None
    };
    let opts = LaunchOptions {
        dir,
        binary: None,
        fault_plan,
        quiet: false,
        // launch's --trace-cache is the cross-campaign global root; the
        // campaign tier always lives under --dir
        trace_cache_global: args.get("trace-cache").map(std::path::PathBuf::from),
    };
    let launched = memfine::orchestrator::launch(&cfg, &opts)?;

    // Per-shard summary table to stderr (stdout carries the artifact,
    // exactly like `memfine sweep`).
    let mut table = memfine::bench::BenchReport::new(
        &format!(
            "launch — {} scenarios over {} shard proc(s), {} worker(s) each",
            launched.plan.total_scenarios,
            launched.plan.procs,
            cfg.workers_per_proc
        ),
        &["shard", "cells", "scenarios", "spawns", "stalls", "crashes", "chaos", "outcome"],
    );
    for (o, p) in launched.outcomes.iter().zip(&launched.plan.shards) {
        table.row(&[
            o.shard.to_string(),
            p.cells.to_string(),
            p.scenarios.to_string(),
            o.spawns.to_string(),
            o.stalls.to_string(),
            o.crashes.to_string(),
            o.chaos_kills.to_string(),
            if o.completed {
                "completed".into()
            } else if o.quarantined {
                "quarantined (healed in merge)".into()
            } else {
                "gave up (healed in merge)".into()
            },
        ]);
    }
    eprint!("{}", table.render());
    let merge = &launched.merge;
    eprintln!(
        "launch: {} resumed from shards, {} healed by catch-up; coverage {}/{}; \
         compacted checkpoint: {} ({} records, {} duplicates, {} torn lines dropped)",
        merge.resumed,
        merge.healed,
        merge.audit.present,
        merge.audit.planned,
        merge.compacted.display(),
        merge.compact_stats.records_out,
        merge.compact_stats.duplicate_records,
        merge.compact_stats.dropped_lines,
    );
    eprint!("{}", merge.report.render_table());
    let json = merge.report.to_json().to_string_pretty();
    match args.get_or("out", "-").as_str() {
        "-" => println!("{json}"),
        path => {
            std::fs::write(path, format!("{json}\n"))?;
            eprintln!("report written to {path}");
        }
    }
    Ok(())
}

/// The campaign dir a status/events invocation points at: the first
/// positional argument, falling back to `--dir` and its default.
fn campaign_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(
        args.positional
            .first()
            .cloned()
            .unwrap_or_else(|| args.get_or("dir", "launch-run")),
    )
}

/// Same-campaign checkpoint files currently in the dir (the shard
/// files mid-run, `merged.jsonl` afterwards) — `events.jsonl` is the
/// sidecar log, never checkpoint state.
fn campaign_checkpoints(
    dir: &std::path::Path,
) -> memfine::Result<Vec<std::path::PathBuf>> {
    let mut state: Vec<std::path::PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| {
            p.extension().and_then(|e| e.to_str()) == Some("jsonl")
                && p.file_name().and_then(|n| n.to_str()) != Some("events.jsonl")
        })
        .collect();
    state.sort();
    Ok(state)
}

fn cmd_status(args: &Args) -> memfine::Result<()> {
    let dir = campaign_dir(args);
    let launch_json = dir.join("launch.json");
    let text = std::fs::read_to_string(&launch_json).map_err(|e| {
        memfine::Error::Io(std::io::Error::new(
            e.kind(),
            format!("{}: {e} (not a campaign dir?)", launch_json.display()),
        ))
    })?;
    let cfg = LaunchConfig::from_json(&memfine::json::parse(&text)?)?;
    let plan = memfine::orchestrator::plan_shards(&cfg, &dir)?;

    // Scenario coverage straight from checkpoint state — the same
    // torn-tolerant reader and planned-hash audit the merge step uses.
    let state = campaign_checkpoints(&dir)?;
    let set = memfine::sweep::checkpoint::CheckpointSet::load(&state)?;
    let audit = memfine::sweep::checkpoint::audit_planned(&plan.planned, &set);

    let events_path = dir.join("events.jsonl");
    let (events, skipped) = if events_path.exists() {
        memfine::obs::read_events(&events_path)?
    } else {
        (Vec::new(), 0)
    };

    // Fold the event log into campaign aggregates. Cells are counted
    // by distinct scenario hash (relaunches and the merge catch-up may
    // re-log a cell); the throughput estimate uses only within-process
    // time deltas, since each process stamps its own monotonic clock.
    let mut cells_done: std::collections::BTreeSet<&str> =
        std::collections::BTreeSet::new();
    let (mut hits, mut misses, mut degrades) = (0u64, 0u64, 0u64);
    let (mut steals, mut blocked) = (0u64, 0u64);
    let mut merged_records: Option<u64> = None;
    let mut last_shard_event: std::collections::BTreeMap<u64, &str> =
        std::collections::BTreeMap::new();
    let mut per_pid: std::collections::BTreeMap<u64, (u64, f64)> =
        std::collections::BTreeMap::new();
    for ev in &events {
        match ev.kind.as_str() {
            "cell_eval" | "cell_assembled" => {
                if let Some(h) = ev.field_str("hash") {
                    cells_done.insert(h);
                }
                if ev.kind == "cell_eval" {
                    match ev.field_str("cache") {
                        Some("hit") => hits += 1,
                        Some("miss") => misses += 1,
                        Some("degrade") => degrades += 1,
                        _ => {}
                    }
                    let slot = per_pid.entry(ev.pid).or_insert((0, 0.0));
                    slot.0 += 1;
                    slot.1 = slot.1.max(ev.t_ms);
                }
            }
            "sweep_done" => {
                steals += ev.field_u64("steals").unwrap_or(0);
                blocked += ev.field_u64("blocked_sends").unwrap_or(0);
            }
            "merge_done" => merged_records = ev.field_u64("records"),
            kind if kind.starts_with("shard_") => {
                if let Some(s) = ev.field_u64("shard") {
                    last_shard_event.insert(s, ev.kind.as_str());
                }
            }
            _ => {}
        }
    }
    let counts = memfine::obs::summarize(&events);
    let count_of = |k: &str| counts.get(k).copied().unwrap_or(0);

    // Host plane (multi-host campaigns): current shard assignment by
    // replaying the host tag on shard events (initial round-robin,
    // last tag wins — the same fold the supervisor's emitter used),
    // losses from shard_host_lost, lease freshness from lease files.
    let host_specs = if cfg.hosts.is_empty() {
        Vec::new()
    } else {
        memfine::orchestrator::HostSpec::parse_list(&cfg.hosts)?
    };
    let multi_host = !host_specs.is_empty();
    let mut host_of: Vec<usize> = (0..plan.shards.len())
        .map(|i| i % host_specs.len().max(1))
        .collect();
    let mut lost_hosts: std::collections::BTreeSet<&str> =
        std::collections::BTreeSet::new();
    if multi_host {
        let index_of: std::collections::BTreeMap<&str, usize> = host_specs
            .iter()
            .enumerate()
            .map(|(i, h)| (h.id.as_str(), i))
            .collect();
        for ev in &events {
            if ev.kind == "shard_host_lost" {
                if let Some(h) = ev.field_str("host") {
                    lost_hosts.insert(h);
                }
                continue;
            }
            if !ev.kind.starts_with("shard_") {
                continue;
            }
            if let (Some(s), Some(h)) = (ev.field_u64("shard"), ev.field_str("host"))
            {
                if let Some(&hi) = index_of.get(h) {
                    if (s as usize) < host_of.len() {
                        host_of[s as usize] = hi;
                    }
                }
            }
        }
    }

    println!(
        "campaign {}: {} scenario(s) in {} trace cell(s) over {} shard proc(s)",
        dir.display(),
        plan.total_scenarios,
        plan.total_cells,
        plan.procs
    );
    println!(
        "scenarios: {}/{} checkpointed ({:.1}%)",
        audit.present,
        audit.planned,
        100.0 * audit.present as f64 / audit.planned.max(1) as f64
    );
    if !events.is_empty() {
        println!(
            "cells:     {}/{} logged done; cache {} hit / {} miss / {} degraded{}",
            cells_done.len(),
            plan.total_cells,
            hits,
            misses,
            degrades,
            if hits + misses + degrades > 0 {
                format!(
                    " ({:.0}% hit)",
                    100.0 * hits as f64 / (hits + misses + degrades) as f64
                )
            } else {
                String::new()
            },
        );
        println!(
            "fleet:     {} spawn(s), {} relaunch(es), {} stall(s), {} crash(es), \
             {} chaos kill(s), {} gave up; {} steal(s), {} backpressure stall(s)",
            count_of("shard_spawned"),
            events
                .iter()
                .filter(|ev| ev.kind == "shard_spawned"
                    && ev.field_u64("attempt").unwrap_or(1) > 1)
                .count(),
            count_of("shard_stalled"),
            count_of("shard_crashed"),
            count_of("shard_chaos_killed"),
            count_of("shard_gave_up"),
            steals,
            blocked,
        );
        // Watchdog health: quarantined shard checkpoints and raised
        // alert_* events (each kind is raised at most once per
        // campaign, so these are presence flags more than counts).
        let quarantined = count_of("shard_quarantined");
        let alerts: Vec<&str> = counts
            .keys()
            .filter(|k| k.starts_with("alert_"))
            .map(|k| k.as_str())
            .collect();
        if quarantined > 0 || !alerts.is_empty() || !lost_hosts.is_empty() {
            println!(
                "health:    {} quarantined checkpoint(s); alerts: {}{}",
                quarantined,
                if alerts.is_empty() {
                    "none".to_string()
                } else {
                    alerts.join(", ")
                },
                if lost_hosts.is_empty() {
                    String::new()
                } else {
                    format!(
                        "; hosts LOST: {}",
                        lost_hosts.iter().copied().collect::<Vec<_>>().join(", ")
                    )
                },
            );
        }
    }

    println!();
    let host_col = |shard: usize| -> String {
        if multi_host {
            format!(" {:>6}", host_specs[host_of[shard]].id)
        } else {
            String::new()
        }
    };
    println!(
        "{:>5}{} {:>9} {:>9} {:>12} {:>10}  {}",
        "shard",
        if multi_host { format!(" {:>6}", "host") } else { String::new() },
        "cells",
        "scenarios",
        "checkpoint",
        "heartbeat",
        "last event"
    );
    for shard in &plan.shards {
        let len = memfine::orchestrator::probe_len(&shard.checkpoint);
        let age = memfine::orchestrator::probe_mtime_age(&shard.checkpoint);
        println!(
            "{:>5}{} {:>9} {:>9} {:>12} {:>10}  {}",
            shard.index,
            host_col(shard.index),
            shard.cells,
            shard.scenarios,
            match len {
                Some(b) => fmt_bytes(b),
                None
                    if memfine::orchestrator::supervise::quarantine_path(
                        &shard.checkpoint,
                    )
                    .exists() =>
                    "quarantined".into(),
                None => "-".into(),
            },
            match age {
                Some(a) => format!("{:.0}s ago", a.as_secs_f64()),
                None => "-".into(),
            },
            last_shard_event
                .get(&(shard.index as u64))
                .copied()
                .unwrap_or("-"),
        );
    }
    println!();

    // Per-host view: spec, lease freshness (mtime of the lease file —
    // renewal-driven expiry lives in the supervisor; this is just an
    // observability read), and the shards currently assigned.
    if multi_host {
        println!(
            "{:>5} {:>14} {:>10} {:>6}  {}",
            "host", "spec", "lease", "state", "shards"
        );
        for (i, spec) in host_specs.iter().enumerate() {
            let lease = memfine::orchestrator::lease_path(&dir, &spec.id);
            let lease_age = memfine::orchestrator::probe_mtime_age(&lease);
            let shards: Vec<String> = host_of
                .iter()
                .enumerate()
                .filter(|(_, &h)| h == i)
                .map(|(s, _)| s.to_string())
                .collect();
            println!(
                "{:>5} {:>14} {:>10} {:>6}  {}",
                spec.id,
                cfg.hosts.get(i).map(String::as_str).unwrap_or("local"),
                match lease_age {
                    Some(a) => format!("{:.0}s ago", a.as_secs_f64()),
                    None => "-".into(),
                },
                if lost_hosts.contains(spec.id.as_str()) { "LOST" } else { "ok" },
                if shards.is_empty() { "-".into() } else { shards.join(",") },
            );
        }
        println!();
    }

    if audit.complete() || merged_records.is_some() {
        println!(
            "status: COMPLETE{}",
            match merged_records {
                Some(n) => format!(" — merged.jsonl holds {n} record(s)"),
                None => String::new(),
            }
        );
    } else {
        // Fleet throughput from within-process cell rates; a rough
        // figure (order of magnitude), but it needs no shared clock.
        let rate: f64 = per_pid
            .values()
            .filter(|(_, t_ms)| *t_ms > 0.0)
            .map(|(cells, t_ms)| *cells as f64 / (t_ms / 1e3))
            .sum();
        let remaining = plan.total_cells.saturating_sub(cells_done.len());
        match (remaining, rate > 0.0) {
            (0, _) => println!("status: in progress (merge pending)"),
            (_, true) => println!(
                "status: in progress — {} cell(s) remaining, ETA ~{:.0}s",
                remaining,
                remaining as f64 / rate
            ),
            (_, false) => {
                println!("status: in progress — {remaining} cell(s) remaining")
            }
        }
    }
    if skipped > 0 {
        eprintln!("status: {skipped} unreadable event line(s) skipped (torn tails)");
    }
    Ok(())
}

/// One event as a human line: monotonic stamp, emitting pid, type,
/// then the domain fields (everything but the three envelope keys).
fn render_event(ev: &memfine::obs::EventRecord) -> String {
    let mut out = format!("[{:>10.1} ms  pid {:>7}] {}", ev.t_ms, ev.pid, ev.kind);
    if let Some(map) = ev.fields.as_obj() {
        for (k, v) in map {
            if k == "t_ms" || k == "pid" || k == "type" {
                continue;
            }
            out.push(' ');
            out.push_str(k);
            out.push('=');
            match v {
                memfine::json::Value::Str(s) => out.push_str(s),
                other => out.push_str(&other.to_string_compact()),
            }
        }
    }
    out
}

fn cmd_events(args: &Args) -> memfine::Result<()> {
    let target = campaign_dir(args);
    let path = if target.extension().and_then(|e| e.to_str()) == Some("jsonl") {
        target
    } else {
        target.join("events.jsonl")
    };
    let (events, skipped) = memfine::obs::read_events(&path)?;
    let type_filter = args.get("type");
    // accept both `--shard 1` and the sweep spelling `--shard 1/4`
    let shard_filter = args
        .get("shard")
        .map(|s| {
            s.split('/').next().unwrap_or(s).trim().parse::<u64>().map_err(|_| {
                memfine::Error::Cli(format!("--shard expects an index, got '{s}'"))
            })
        })
        .transpose()?;
    let hash_filter = args.get("hash");
    let filtered: Vec<&memfine::obs::EventRecord> = events
        .iter()
        .filter(|ev| {
            type_filter.map_or(true, |t| ev.kind == t)
                && shard_filter.map_or(true, |s| ev.field_u64("shard") == Some(s))
                && hash_filter.map_or(true, |h| {
                    ev.field_str("hash").is_some_and(|eh| eh.starts_with(h))
                })
        })
        .collect();
    if args.has_flag("summary") {
        let mut counts: std::collections::BTreeMap<&str, u64> =
            std::collections::BTreeMap::new();
        for ev in &filtered {
            *counts.entry(ev.kind.as_str()).or_insert(0) += 1;
        }
        for (kind, n) in &counts {
            println!("{n:>8}  {kind}");
        }
        println!("{:>8}  total", filtered.len());
    } else {
        for ev in &filtered {
            println!("{}", render_event(ev));
        }
    }
    if skipped > 0 {
        eprintln!("events: {skipped} unreadable line(s) skipped (torn tails)");
    }
    Ok(())
}

fn cmd_checkpoint(args: &Args) -> memfine::Result<()> {
    use memfine::sweep::checkpoint;
    let sub = args.positional.first().map(String::as_str).unwrap_or("");
    let files: Vec<std::path::PathBuf> = args
        .positional
        .iter()
        .skip(1)
        .map(std::path::PathBuf::from)
        .collect();
    match sub {
        "compact" => {
            if files.is_empty() {
                return Err(memfine::Error::Cli(
                    "checkpoint compact needs at least one file".into(),
                ));
            }
            let out = match args.get("out") {
                Some("-") => {
                    return Err(memfine::Error::Cli(
                        "checkpoint compact cannot write to stdout; pass --out FILE".into(),
                    ))
                }
                Some(p) => std::path::PathBuf::from(p),
                None if files.len() == 1 => files[0].clone(),
                None => {
                    return Err(memfine::Error::Cli(
                        "checkpoint compact of several files needs --out".into(),
                    ))
                }
            };
            let stats = checkpoint::compact(&files, &out)?;
            eprintln!(
                "compacted {} file(s): {} line(s) -> {} record(s) \
                 ({} duplicate(s) collapsed, {} torn/garbage line(s) dropped) -> {}",
                stats.files_in,
                stats.lines_in,
                stats.records_out,
                stats.duplicate_records,
                stats.dropped_lines,
                out.display(),
            );
            Ok(())
        }
        "audit" => {
            if files.is_empty() {
                return Err(memfine::Error::Cli(
                    "checkpoint audit needs at least one file".into(),
                ));
            }
            let cfg_path = args.get("config").ok_or_else(|| {
                memfine::Error::Cli("checkpoint audit needs --config <grid.json>".into())
            })?;
            let (cfg, doc_sampler, doc_rng) =
                grid_and_sampler_from_doc(&parse_config_file(cfg_path)?)?;
            let set = checkpoint::CheckpointSet::load(&files)?;
            // Provenance resolution, most explicit first and field by
            // field: a --router/--rng flag > the launch.json's
            // recorded choice > the checkpoint files' own header > the
            // engine default. Headerless legacy files under a bare
            // grid therefore need --router seq if they predate the
            // sampler flip. A fully implicit audit adopts the header
            // verbatim, so files from future rng versions still audit.
            let prov = match (sampler_flag(args)?.or(doc_sampler), rng_flag(args)?.or(doc_rng)) {
                (None, None) => match &set.header_provenance {
                    Some(recorded) => recorded.clone(),
                    None => TraceProvenance::default(),
                },
                (s, r) => {
                    let recorded = set.header_provenance.as_ref();
                    let sampler =
                        s.or(recorded.map(|p| p.sampler)).unwrap_or_default();
                    let rng = match r {
                        Some(v) => v,
                        None => recorded.map(|p| p.rng()).transpose()?.unwrap_or_default(),
                    };
                    TraceProvenance::with(sampler, rng)
                }
            };
            eprintln!(
                "audit: hashing under router '{}' (rng v{})",
                prov.tag(),
                prov.rng_version
            );
            let audit = checkpoint::audit_coverage(&cfg, &prov, &set)?;
            eprintln!(
                "audit: {}/{} planned scenario(s) present, {} missing, \
                 {} foreign record(s), {} unreadable line(s)",
                audit.present,
                audit.planned,
                audit.missing.len(),
                audit.extra,
                set.skipped_lines,
            );
            for (index, hash) in audit.missing.iter().take(10) {
                eprintln!("  missing: grid index {index}, hash {hash}");
            }
            if audit.missing.len() > 10 {
                eprintln!("  ... and {} more", audit.missing.len() - 10);
            }
            if audit.complete() {
                Ok(())
            } else {
                Err(memfine::Error::config(format!(
                    "checkpoint set does not cover the grid: {} of {} scenario(s) missing",
                    audit.missing.len(),
                    audit.planned
                )))
            }
        }
        other => Err(memfine::Error::Cli(format!(
            "unknown checkpoint subcommand '{other}' (compact|audit)"
        ))),
    }
}

/// Upkeep for a shared (cross-campaign) trace-cache root: `stats`
/// reports entry count and bytes, `gc` evicts entries older than
/// `--max-age-h`. Safe at any time — content addressing means an
/// evicted trace just regenerates on next use.
fn cmd_trace_cache(args: &Args) -> memfine::Result<()> {
    use memfine::trace::store::TraceStore;
    let sub = args.positional.first().map(String::as_str).unwrap_or("");
    let dir = args
        .positional
        .get(1)
        .cloned()
        .or_else(|| args.get("trace-cache").map(str::to_string))
        .ok_or_else(|| {
            memfine::Error::Cli("trace-cache needs a cache directory".into())
        })?;
    let store = TraceStore::open(&dir)?;
    match sub {
        "stats" => {
            let s = store.stats();
            println!(
                "trace cache {}: {} entr{}, {}",
                dir,
                s.entries,
                if s.entries == 1 { "y" } else { "ies" },
                fmt_bytes(s.bytes),
            );
            Ok(())
        }
        "gc" => {
            let hours = args.get_u64("max-age-h", 168)?;
            let gone =
                store.gc(std::time::Duration::from_secs(hours.saturating_mul(3600)));
            let left = store.stats();
            println!(
                "trace cache {}: evicted {} entr{} ({}) older than {}h; {} left ({})",
                dir,
                gone.removed,
                if gone.removed == 1 { "y" } else { "ies" },
                fmt_bytes(gone.bytes),
                hours,
                left.entries,
                fmt_bytes(left.bytes),
            );
            Ok(())
        }
        other => Err(memfine::Error::Cli(format!(
            "unknown trace-cache subcommand '{other}' (stats|gc)"
        ))),
    }
}

fn cmd_repro(args: &Args) -> memfine::Result<()> {
    let what = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("table4");
    match what {
        "table4" => memfine::sim::repro::table4(args.get_u64("seed", 7)?),
        "fig2" => memfine::sim::repro::fig2(args.get_u64("seed", 7)?, 7),
        "fig4" => memfine::sim::repro::fig4(args.get_u64("seed", 7)?, args.get_u64("iters", 25)?),
        "fig5" => memfine::sim::repro::fig5(args.get_u64("seed", 7)?, args.get_u64("iters", 25)?),
        other => Err(memfine::Error::Cli(format!(
            "unknown artifact '{other}' (table4|fig2|fig4|fig5)"
        ))),
    }
}

fn cmd_train(args: &Args) -> memfine::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let steps = args.get_u64("steps", 50)?;
    let store = ArtifactStore::open(&dir)?;
    let driver = TrainDriver::new(store)?;
    println!(
        "training {} steps (tokens/step = {})",
        steps,
        driver.tokens_per_step()
    );
    let report = driver.train(steps, args.get_u64("seed", 7)?, |log| {
        if log.step == 1 || log.step % 10 == 0 {
            println!(
                "step {:>4}  loss {:.4}  {:.2}s  TGS {:.0}",
                log.step, log.loss, log.step_s, log.tgs
            );
        }
    })?;
    println!(
        "done: first loss {:.4} → final {:.4} (tail-5 {:.4}), mean TGS {:.0}, total {:.1}s",
        report.first_loss,
        report.final_loss,
        report.tail_loss(5),
        report.mean_tgs,
        report.total_s
    );
    Ok(())
}

fn cmd_coord(args: &Args) -> memfine::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let budget = args.get_u64("budget-mb", 48)? << 20;
    let policy = match args.get_or("policy", "mact").as_str() {
        "mact" => ChunkPolicy::Mact { budget_bytes: budget },
        "fixed" => ChunkPolicy::Fixed(args.get_u64("chunk", 8)?),
        other => return Err(memfine::Error::Cli(format!("unknown policy '{other}'"))),
    };
    let coord = EpCoordinator::new(dir, policy, args.get_u64("seed", 7)?)?;
    println!(
        "EP coordinator: {} ranks × {} local experts, {} tokens/rank, top-{}",
        coord.topo.ep, coord.topo.local_experts, coord.topo.tokens_per_rank, coord.topo.top_k
    );
    let d = coord.decide()?;
    println!(
        "decision: chunk bin {} (capacity {}, buffers {})",
        d.chunk_bin,
        d.capacity,
        fmt_bytes(d.buffer_bytes)
    );
    let result = coord.run_layer()?;
    println!("received per rank: {:?}", result.received);
    println!(
        "peak tracked bytes per rank: {:?}",
        result
            .peak_bytes
            .iter()
            .map(|&b| fmt_bytes(b))
            .collect::<Vec<_>>()
    );
    let norm: f32 = result.outputs[0].iter().map(|x| x * x).sum::<f32>().sqrt();
    println!("rank-0 output L2 = {norm:.3} (layer pass complete)");
    Ok(())
}
