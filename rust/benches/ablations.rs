//! `cargo bench --bench ablations` — design-choice ablations
//! (DESIGN.md §4): MACT bin granularity, selective recomputation, and
//! the GShard capacity-factor accuracy price.

use memfine::bench::BenchReport;
use memfine::config::{model_i, paper_run, Method};
use memfine::sim::ablation;
use memfine::util::fmt_bytes;

fn main() {
    memfine::logging::init();
    let mut base = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
    base.iterations = 25;

    // 1. Bin granularity.
    let rows = ablation::bin_granularity(
        &base,
        &[
            ("fine [1..8]", vec![1, 2, 3, 4, 5, 6, 7, 8]),
            ("paper [1,2,4,8]", vec![1, 2, 4, 8]),
            ("coarse [1,8]", vec![1, 8]),
            ("single [8]", vec![8]),
        ],
    )
    .expect("bin ablation");
    let mut report = BenchReport::new(
        "ablation — MACT bin granularity (Model I, 25 iters)",
        &["bins", "peak act", "avg TGS", "OOM iters", "executables"],
    );
    for r in rows {
        report.row(&[
            r.label,
            fmt_bytes(r.peak_act_bytes),
            format!("{:.1}", r.avg_tgs),
            r.oom_iterations.to_string(),
            r.distinct_chunks.to_string(),
        ]);
    }
    report.print();
    println!("reading: finer bins buy little memory over [1,2,4,8] but double the");
    println!("compiled-executable count; a single large bin wastes throughput.");

    // 2. Selective recomputation.
    let (with, without) = ablation::selective_recompute_effect(&base).unwrap();
    println!(
        "\nablation — selective recompute: TGS {:.1} with vs {:.1} without ({:+.2} %)",
        with,
        without,
        100.0 * (with / without - 1.0)
    );

    // 3. Capacity-factor accuracy price.
    let rows = ablation::capacity_factor_drops(&base.model, &base, &[1.0, 1.5, 2.0, 4.0, 8.0]);
    let mut report = BenchReport::new(
        "ablation — GShard capacity factor at the chaos peak (iter 8, last layer)",
        &["capacity factor", "dropped copies", "peak expert tokens"],
    );
    for r in rows {
        report.row(&[
            format!("{:.1}", r.capacity_factor),
            format!("{:.1} %", 100.0 * r.dropped_fraction),
            r.peak_expert_tokens.to_string(),
        ]);
    }
    report.print();
    println!("reading: capping memory via capacity factors costs dropped tokens —");
    println!("the accuracy price MemFine's drop-free chunking avoids entirely.");
}
