"""L2 model correctness: shapes, chunk equivalence, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.TINY


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(
        jax.random.PRNGKey(1), (CFG.batch, CFG.seq), 0, CFG.vocab)


class TestParams:
    def test_flatten_roundtrip(self, params):
        vec = M.flatten(CFG, params)
        back = M.unflatten(CFG, vec)
        assert set(back) == set(params)
        for k in params:
            np.testing.assert_array_equal(params[k], back[k])

    def test_param_count_matches_vector(self, params):
        assert M.flatten(CFG, params).shape[0] == M.param_count(CFG)

    def test_layer_structure(self):
        names = [n for n, _ in M.param_shapes(CFG)]
        # first n_dense_layers use dense FFN, rest MoE
        assert "layer0.ffn_w1" in names and "layer0.gate" not in names
        assert "layer1.gate" in names and "layer1.ffn_w1" not in names

    def test_norm_gains_init_to_one(self, params):
        assert np.all(np.asarray(params["layer0.ln1"]) == 1.0)

    def test_e2e_param_count_in_target_band(self):
        # examples/train_moe.rs trains this; keep it in the documented band
        n = M.param_count(M.E2E)
        assert 10_000_000 < n < 60_000_000


class TestForward:
    def test_logits_shape(self, params, tokens):
        logits = M.forward(CFG, params, tokens)
        assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)

    def test_loss_finite_positive(self, params, tokens):
        loss = M.loss_fn(CFG, params, tokens)
        assert np.isfinite(float(loss)) and float(loss) > 0

    def test_initial_loss_near_uniform(self, params, tokens):
        """Random init ⇒ loss ≈ ln(vocab)."""
        loss = float(M.loss_fn(CFG, params, tokens))
        assert abs(loss - np.log(CFG.vocab)) < 1.5

    def test_causality(self, params):
        """Changing a future token must not affect earlier logits."""
        t1 = jnp.zeros((1, CFG.seq), jnp.int32)
        t2 = t1.at[0, -1].set(5)
        l1 = M.forward(CFG, params, t1)
        l2 = M.forward(CFG, params, t2)
        np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("n_chunks", [1, 2, 4])
    def test_fcda_chunk_equivalence(self, params, tokens, n_chunks):
        """Paper Eq. 6: the chunk count must not change the math."""
        import dataclasses
        cfg_c = dataclasses.replace(CFG, n_chunks=n_chunks)
        base = M.forward(CFG, params, tokens)
        out = M.forward(cfg_c, params, tokens)
        np.testing.assert_allclose(out, base, rtol=5e-4, atol=5e-5)


class TestTrainStep:
    def test_loss_decreases(self, params, tokens):
        vec = M.flatten(CFG, params)
        m = jnp.zeros_like(vec)
        v = jnp.zeros_like(vec)
        losses = []
        for i in range(8):
            vec, m, v, loss = M.train_step(CFG, vec, m, v, tokens,
                                           jnp.float32(i + 1))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_state_shapes_preserved(self, params, tokens):
        vec = M.flatten(CFG, params)
        z = jnp.zeros_like(vec)
        out = M.train_step(CFG, vec, z, z, tokens, jnp.float32(1.0))
        assert out[0].shape == vec.shape
        assert out[1].shape == vec.shape
        assert out[2].shape == vec.shape
        assert out[3].shape == ()

    def test_eval_loss_matches_loss_fn(self, params, tokens):
        vec = M.flatten(CFG, params)
        np.testing.assert_allclose(
            float(M.eval_loss(CFG, vec, tokens)),
            float(M.loss_fn(CFG, params, tokens)), rtol=1e-6)

    def test_deterministic(self, params, tokens):
        vec = M.flatten(CFG, params)
        z = jnp.zeros_like(vec)
        a = M.train_step(CFG, vec, z, z, tokens, jnp.float32(1.0))
        b = M.train_step(CFG, vec, z, z, tokens, jnp.float32(1.0))
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
