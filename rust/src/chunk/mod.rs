//! FCDA + MACT — the paper's §4: fine-grained chunk distribution and
//! memory-aware chunk tuning.
//!
//! **FCDA** ([`split_chunks`], [`RecomputeSchedule`]) decomposes a
//! micro-batch's token set into `c` chunks. Forward runs
//! dispatch→expert→combine per chunk sequentially (Eq. 6), storing only
//! each chunk's boundary input; backward walks chunks in reverse,
//! recomputing each chunk's forward before differentiating it (Eq. 7).
//! Peak MoE activation memory drops from `f(s')` to `max_i f(s'_i)`.
//!
//! **MACT** ([`Mact`]) closes the loop: before each micro-batch it
//! evaluates the memory model's token budget `s'_max` (Eq. 8) per
//! pipeline stage, derives the ideal chunk count `c = ⌈s''/s'_max⌉`
//! (Eq. 9), and rounds **up** to the nearest configured bin so the
//! runtime only ever sees a handful of chunk shapes (one compiled
//! executable per bin — exactly how the AOT artifacts are exported).

use crate::config::RunConfig;
use crate::memory::{ActivationModel, StaticModel};
use crate::util::ceil_div;

/// One FCDA chunk: a contiguous token range of the micro-batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub index: u64,
    pub start: u64,
    pub len: u64,
}

/// Split `total_tokens` into `c` near-equal contiguous chunks
/// (remainder spread over the leading chunks). `c` is clamped to
/// `total_tokens` so no chunk is empty.
pub fn split_chunks(total_tokens: u64, c: u64) -> Vec<Chunk> {
    if total_tokens == 0 {
        return Vec::new();
    }
    let c = c.clamp(1, total_tokens);
    let base = total_tokens / c;
    let rem = total_tokens % c;
    let mut chunks = Vec::with_capacity(c as usize);
    let mut start = 0;
    for i in 0..c {
        let len = base + u64::from(i < rem);
        chunks.push(Chunk { index: i, start, len });
        start += len;
    }
    chunks
}

/// A step of the chunked forward/backward schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Forward of chunk i (dispatch→expert→combine), storing only the
    /// chunk's boundary input.
    Forward(u64),
    /// Recompute chunk i's forward from its stored boundary (backward
    /// phase, Eq. 7).
    Recompute(u64),
    /// Backward of chunk i through the recomputed activations.
    Backward(u64),
    /// Free chunk i's recomputed activations.
    Free(u64),
}

/// The full FCDA execution schedule for one MoE layer invocation.
#[derive(Clone, Debug)]
pub struct RecomputeSchedule {
    pub chunks: Vec<Chunk>,
    pub steps: Vec<Step>,
}

impl RecomputeSchedule {
    /// Build the Eq. 6/Eq. 7 schedule: all forwards in order, then per
    /// chunk (reverse order): recompute → backward → free.
    pub fn build(total_tokens: u64, c: u64) -> Self {
        let chunks = split_chunks(total_tokens, c);
        let mut steps = Vec::with_capacity(chunks.len() * 4);
        for ch in &chunks {
            steps.push(Step::Forward(ch.index));
        }
        for ch in chunks.iter().rev() {
            steps.push(Step::Recompute(ch.index));
            steps.push(Step::Backward(ch.index));
            steps.push(Step::Free(ch.index));
        }
        RecomputeSchedule { chunks, steps }
    }

    /// Walk the schedule tracking live activation cost, where chunk i's
    /// recomputed activations cost `cost(len_i)` units while alive.
    /// Returns the peak. This is the executable form of the paper's
    /// claim that peak = max over chunks, not the sum.
    pub fn peak_live_cost(&self, cost: impl Fn(u64) -> u64) -> u64 {
        let mut live = 0u64;
        let mut peak = 0u64;
        for step in &self.steps {
            match step {
                Step::Recompute(i) => {
                    live += cost(self.chunks[*i as usize].len);
                    peak = peak.max(live);
                }
                Step::Free(i) => {
                    live -= cost(self.chunks[*i as usize].len);
                }
                _ => {}
            }
        }
        peak
    }

    /// Validity: every chunk is forwarded once, then recomputed,
    /// backwarded and freed exactly once, with backward before free and
    /// recompute before backward.
    pub fn validate(&self) -> bool {
        let n = self.chunks.len();
        let mut fwd = vec![0u32; n];
        let mut rec = vec![0u32; n];
        let mut bwd = vec![0u32; n];
        let mut freed = vec![0u32; n];
        for s in &self.steps {
            match *s {
                Step::Forward(i) => fwd[i as usize] += 1,
                Step::Recompute(i) => {
                    if fwd[i as usize] == 0 {
                        return false;
                    }
                    rec[i as usize] += 1;
                }
                Step::Backward(i) => {
                    if rec[i as usize] == 0 {
                        return false;
                    }
                    bwd[i as usize] += 1;
                }
                Step::Free(i) => {
                    if bwd[i as usize] == 0 {
                        return false;
                    }
                    freed[i as usize] += 1;
                }
            }
        }
        (0..n).all(|i| fwd[i] == 1 && rec[i] == 1 && bwd[i] == 1 && freed[i] == 1)
    }
}

/// The MACT controller (paper §4.2).
#[derive(Clone, Debug)]
pub struct Mact {
    act: ActivationModel,
    /// Per-stage static bytes, precomputed once before training.
    static_per_stage: Vec<u64>,
    /// α·M_GPU, the usable budget (Eq. 3).
    budget: u64,
    /// Threshold bins (strictly increasing, e.g. [1, 2, 4, 8]).
    pub bins: Vec<u64>,
}

/// One MACT decision with its audit trail (logged to the Fig. 5 trace).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MactDecision {
    /// Eq. 8 token budget of this stage.
    pub s_prime_max: u64,
    /// Observed/predicted received tokens (`s''`).
    pub s_received: u64,
    /// Eq. 9 ideal chunk count.
    pub ideal_c: u64,
    /// Chosen bin (≥ ideal_c, or the largest bin if none suffices).
    pub chosen_c: u64,
    /// Whether even the largest bin violates the budget (residual OOM
    /// risk — the caller may fall back to offloading or fail fast).
    pub feasible: bool,
}

impl Mact {
    /// Precompute the memory model for a run ("before training, the
    /// MACT system models the training memory usage").
    pub fn new(run: &RunConfig, bins: Vec<u64>) -> Self {
        assert!(!bins.is_empty(), "MACT needs at least one bin");
        assert!(
            bins.windows(2).all(|w| w[0] < w[1]),
            "bins must be strictly increasing"
        );
        let act = ActivationModel::new(run);
        let sta = StaticModel::new(run);
        let static_per_stage = (0..run.parallel.pp)
            .map(|r| sta.bytes_on_rank(r))
            .collect();
        let budget = (run.alpha * run.gpu_mem_bytes as f64) as u64;
        Mact { act, static_per_stage, budget, bins }
    }

    /// Eq. 8 for a pipeline stage (memoised inputs, cheap to call in
    /// the per-micro-batch hot path).
    pub fn s_prime_max(&self, pp_rank: u64) -> u64 {
        self.act.s_prime_max(
            pp_rank,
            self.static_per_stage[pp_rank as usize],
            self.budget,
            true, // MemFine keeps full recompute for the dense part
        )
    }

    /// The MACT decision for one (stage, s'') query: Eq. 9 + threshold
    /// binning ("select the larger bin that is closest to c").
    pub fn decide(&self, pp_rank: u64, s_received: u64) -> MactDecision {
        self.decide_given(self.s_prime_max(pp_rank), s_received)
    }

    /// The decision core, taking an already-evaluated Eq. 8 budget.
    /// `s_prime_max(stage)` is constant over a run, so hot callers (the
    /// fused cell evaluator) hoist it per stage and call this directly;
    /// [`Mact::decide`] delegates here, keeping the two paths one
    /// implementation.
    pub fn decide_given(&self, s_prime_max: u64, s_received: u64) -> MactDecision {
        let s_max = s_prime_max;
        let ideal = if s_max == 0 {
            u64::MAX // nothing fits: force the largest bin, flag infeasible
        } else {
            ceil_div(s_received, s_max).max(1)
        };
        let chosen = self
            .bins
            .iter()
            .copied()
            .find(|&b| b >= ideal)
            .unwrap_or(*self.bins.last().unwrap());
        let feasible = s_max > 0 && ceil_div(s_received, chosen) <= s_max.max(1)
            && ideal <= *self.bins.last().unwrap();
        MactDecision {
            s_prime_max: s_max,
            s_received,
            ideal_c: if ideal == u64::MAX { u64::MAX } else { ideal },
            chosen_c: chosen,
            feasible,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, paper_run, Method};

    #[test]
    fn split_even() {
        let ch = split_chunks(100, 4);
        assert_eq!(ch.len(), 4);
        assert!(ch.iter().all(|c| c.len == 25));
        assert_eq!(ch[3].start, 75);
    }

    #[test]
    fn split_remainder_spread() {
        let ch = split_chunks(10, 3);
        assert_eq!(ch.iter().map(|c| c.len).collect::<Vec<_>>(), vec![4, 3, 3]);
        // contiguity
        assert_eq!(ch[1].start, 4);
        assert_eq!(ch[2].start, 7);
    }

    #[test]
    fn split_conserves_tokens() {
        for (n, c) in [(1u64, 1u64), (7, 3), (4096, 8), (100, 100), (5, 9)] {
            let ch = split_chunks(n, c);
            assert_eq!(ch.iter().map(|x| x.len).sum::<u64>(), n);
            assert!(ch.iter().all(|x| x.len > 0), "empty chunk at n={n} c={c}");
        }
    }

    #[test]
    fn split_zero_tokens_empty() {
        assert!(split_chunks(0, 4).is_empty());
    }

    #[test]
    fn schedule_shape_eq6_eq7() {
        let s = RecomputeSchedule::build(100, 4);
        assert_eq!(s.steps.len(), 4 + 3 * 4);
        // forwards first, in order
        assert_eq!(s.steps[0], Step::Forward(0));
        assert_eq!(s.steps[3], Step::Forward(3));
        // backward phase reversed, chunk 3 first
        assert_eq!(s.steps[4], Step::Recompute(3));
        assert_eq!(s.steps[5], Step::Backward(3));
        assert_eq!(s.steps[6], Step::Free(3));
        assert!(s.validate());
    }

    #[test]
    fn schedule_peak_is_single_chunk() {
        // cost linear in tokens → peak live = one (largest) chunk,
        // NOT the sum: the paper's memory-saving claim.
        let s = RecomputeSchedule::build(1000, 8);
        let peak = s.peak_live_cost(|len| len);
        assert_eq!(peak, 125);
        let s1 = RecomputeSchedule::build(1000, 1);
        assert_eq!(s1.peak_live_cost(|len| len), 1000);
    }

    #[test]
    fn schedule_validate_rejects_wrong_order() {
        let mut s = RecomputeSchedule::build(10, 2);
        // steps: [F0, F1, R1, B1, Free1, R0, B0, Free0]
        s.steps.swap(2, 3); // Backward(1) before Recompute(1)
        assert!(!s.validate());
    }

    fn mact() -> Mact {
        let run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        Mact::new(&run, vec![1, 2, 4, 8])
    }

    #[test]
    fn decide_balanced_needs_one_chunk() {
        let m = mact();
        // perfectly balanced: each rank gets total/ep copies
        let balanced = 4096 * 8; // s·t_k with e ranks sharing equally
        let d = m.decide(0, balanced);
        assert_eq!(d.chosen_c, 1, "{d:?}");
        assert!(d.feasible);
    }

    #[test]
    fn decide_extreme_needs_more_chunks() {
        let m = mact();
        let extreme = 32 * 4096 * 8; // theoretical peak
        let d = m.decide(0, extreme);
        assert!(d.ideal_c >= 2, "{d:?}");
        assert!(d.chosen_c >= d.ideal_c.min(8));
        // chunk memory after split must fit: s''/c ≤ s'_max whenever
        // feasible is reported
        if d.feasible {
            assert!(extreme.div_ceil(d.chosen_c) <= d.s_prime_max);
        }
    }

    #[test]
    fn decide_rounds_up_to_bin() {
        let m = mact();
        let s_max = m.s_prime_max(0);
        // choose s'' so ideal_c = 3 → bin must be 4
        let d = m.decide(0, s_max * 3 - 1);
        assert_eq!(d.ideal_c, 3);
        assert_eq!(d.chosen_c, 4);
    }

    #[test]
    fn decide_monotone_in_load() {
        let m = mact();
        let s_max = m.s_prime_max(1);
        let mut last = 0;
        for mult in [1u64, 2, 3, 5, 8] {
            let d = m.decide(1, s_max * mult);
            assert!(d.chosen_c >= last, "not monotone at mult {mult}");
            last = d.chosen_c;
        }
    }

    #[test]
    fn decide_given_matches_decide() {
        // The hoisted-budget core and the per-stage entry point are one
        // implementation: identical decisions for every (stage, s'').
        let m = mact();
        for stage in 0..4u64 {
            let s_max = m.s_prime_max(stage);
            for s_recv in [0u64, 1, 10_000, 250_000, 32 * 4096 * 8] {
                assert_eq!(
                    m.decide(stage, s_recv),
                    m.decide_given(s_max, s_recv),
                    "stage {stage} s'' {s_recv}"
                );
            }
        }
    }

    #[test]
    fn stage0_has_smallest_budget() {
        // Stage 0 carries the embedding → less headroom → smaller
        // s'_max (the "varying memory pressure across PP stages"
        // motivation for MACT).
        let m = mact();
        assert!(m.s_prime_max(0) < m.s_prime_max(1));
    }

    #[test]
    fn infeasible_when_budget_tiny() {
        let mut run = paper_run(model_i(), Method::Mact(vec![1, 2]));
        run.gpu_mem_bytes = 30 * crate::config::GB; // below static
        let m = Mact::new(&run, vec![1, 2]);
        let d = m.decide(0, 100_000);
        assert!(!d.feasible);
        assert_eq!(d.chosen_c, 2); // falls back to largest bin
    }

    #[test]
    #[should_panic]
    fn unsorted_bins_panic() {
        let run = paper_run(model_i(), Method::Mact(vec![1, 2]));
        Mact::new(&run, vec![4, 2]);
    }
}
