//! CLI-level smoke of the resumable/shardable sweep: drives the real
//! `memfine` binary end to end, checking the flag wiring
//! (`--checkpoint/--resume/--shard/--limit`), the artifact files, and
//! that a 2-shard checkpointed split merged by a resume run emits the
//! byte-identical artifact of a direct run — the same contract the
//! in-process tests pin, proven through the shipped interface.

use std::path::PathBuf;
use std::process::Command;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("memfine-it-cli-{}-{name}", std::process::id()));
    p
}

/// Run `memfine sweep` with the common tiny grid plus `extra` args;
/// panics with stderr attached if the process fails.
fn sweep(extra: &[&str]) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_memfine"));
    cmd.args([
        "sweep", "--models", "i", "--methods", "1,3", "--seeds", "2",
        "--iters", "5", "--workers", "2",
    ]);
    cmd.args(extra);
    let out = cmd.output().expect("spawn memfine");
    assert!(
        out.status.success(),
        "memfine sweep {extra:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn cli_two_shard_merge_matches_direct_run() {
    let direct = tmp("direct.json");
    let shard_out = tmp("shard-partial.json");
    let merged = tmp("merged.json");
    let ck0 = tmp("shard0.jsonl");
    let ck1 = tmp("shard1.jsonl");

    sweep(&["--out", direct.to_str().unwrap()]);
    sweep(&[
        "--shard", "0/2",
        "--checkpoint", ck0.to_str().unwrap(),
        "--out", shard_out.to_str().unwrap(),
    ]);
    sweep(&[
        "--shard", "1/2",
        "--checkpoint", ck1.to_str().unwrap(),
        "--out", shard_out.to_str().unwrap(),
    ]);
    let both = format!("{},{}", ck0.to_str().unwrap(), ck1.to_str().unwrap());
    sweep(&[
        "--resume",
        "--checkpoint", &both,
        "--out", merged.to_str().unwrap(),
    ]);

    let direct_bytes = std::fs::read(&direct).expect("direct artifact");
    let merged_bytes = std::fs::read(&merged).expect("merged artifact");
    assert_eq!(
        direct_bytes, merged_bytes,
        "CLI 2-shard merge diverged from the direct artifact"
    );
    // shard checkpoints partition the 4-scenario grid
    let lines = |p: &PathBuf| {
        std::fs::read_to_string(p)
            .unwrap_or_default()
            .lines()
            .count()
    };
    assert_eq!(lines(&ck0) + lines(&ck1), 4);

    for p in [&direct, &shard_out, &merged, &ck0, &ck1] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cli_limit_then_resume_completes_the_grid() {
    let ck = tmp("limit.jsonl");
    let out_a = tmp("limit-a.json");
    let out_b = tmp("limit-b.json");
    let direct = tmp("limit-direct.json");

    sweep(&["--out", direct.to_str().unwrap()]);
    sweep(&[
        "--limit", "2",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", out_a.to_str().unwrap(),
    ]);
    assert_eq!(
        std::fs::read_to_string(&ck).expect("checkpoint").lines().count(),
        2
    );
    sweep(&[
        "--resume",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", out_b.to_str().unwrap(),
    ]);
    assert_eq!(
        std::fs::read(&direct).expect("direct"),
        std::fs::read(&out_b).expect("resumed"),
        "limit-then-resume diverged from the direct artifact"
    );

    for p in [&ck, &out_a, &out_b, &direct] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cli_rejects_bad_shard_and_bare_resume() {
    for args in [&["--shard", "2/2"][..], &["--resume"][..]] {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_memfine"));
        cmd.args(["sweep", "--models", "i", "--methods", "1", "--seeds", "1", "--iters", "2"]);
        cmd.args(args);
        let out = cmd.output().expect("spawn memfine");
        assert!(
            !out.status.success(),
            "memfine sweep {args:?} unexpectedly succeeded"
        );
    }
}
