//! Whole-training-run simulator: the engine behind every paper table
//! and figure.
//!
//! The run is split into two phases with a hard boundary between them:
//!
//! 1. **Trace generation** — the routed-token stream per (iteration,
//!    MoE layer) is drawn by [`crate::router::GatingSim`] into a
//!    [`SharedRoutingTrace`]. The stream depends only on (model,
//!    gating, seed) — never on the method — so one draw serves every
//!    method of a paired-comparison cell ([`run_scenario_on_trace`]).
//! 2. **Method evaluation** — per iteration, (a) apply the configured
//!    method's chunking decision ([`crate::chunk::Mact`] for
//!    Method 3), (b) evaluate the memory model per pipeline stage to
//!    detect OOM (Eq. 2/3), and (c) compose per-layer timing into an
//!    iteration time and TGS (Eq. 10). Evaluation never touches the
//!    RNG.
//!
//! Outputs are the traces the benches print: Table 4's memory rows,
//! Fig. 2's distribution slice, Fig. 4's TGS series and Fig. 5's
//! chunk grid.
//!
//! For sweep grids there is a third, fused entry point:
//! [`evaluate_cell`] walks a cell's trace **once** and evaluates every
//! method of the cell simultaneously, memoising the method-dependent
//! kernels and emitting only [`RunSummary`] aggregates — pinned
//! bit-identical to per-method [`run_scenario_on_trace`] calls.
//!
//! The phase boundary is also the telemetry boundary: the sweep
//! engine's `stage.trace_ns` / `stage.eval_ns` histograms
//! ([`crate::obs`]) bracket phases 1 and 2 from *outside* these entry
//! points. No clock is ever read inside the simulator — evaluation
//! stays a pure function of its inputs, so instrumentation can never
//! perturb artifact bytes.

use std::collections::HashMap;

use crate::chunk::Mact;
use crate::config::{Method, RunConfig};
use crate::error::Error;
use crate::memory::{ActivationModel, StaticModel};
use crate::perf::PerfModel;
use crate::router::GatingSim;
use crate::trace::provenance::RouterSampler;
pub mod ablation;
pub mod repro;

use crate::trace::{ChunkRecord, ChunkTrace, RoutingRecord, RoutingTrace, SharedRoutingTrace};

/// Outcome of one MoE layer in one iteration.
#[derive(Clone, Copy, Debug)]
pub struct LayerOutcome {
    pub layer: u64,
    /// Coldest rank's received copies.
    pub min_recv: u64,
    /// Mean received copies across the EP group.
    pub mean_recv: f64,
    /// Hottest rank's received copies (`s''`).
    pub max_recv: u64,
    /// Chunk count the method applied.
    pub chunks: u64,
    /// Peak activation bytes of the hottest rank for this layer.
    pub act_bytes: u64,
}

/// Outcome of one iteration.
#[derive(Clone, Debug)]
pub struct IterationOutcome {
    pub iteration: u64,
    pub layers: Vec<LayerOutcome>,
    /// Peak activation bytes across stages (hottest layer).
    pub peak_act_bytes: u64,
    /// Static + activation peak across stages.
    pub peak_total_bytes: u64,
    /// True when Eq. 3 is violated on some stage.
    pub oom: bool,
    pub iteration_s: f64,
    pub tgs: f64,
}

/// Aggregate of a full simulated run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub method: Method,
    pub iterations: Vec<IterationOutcome>,
    pub routing: RoutingTrace,
    pub chunks: ChunkTrace,
    /// Mean TGS over non-OOM iterations (0 if all OOM).
    pub avg_tgs: f64,
    pub oom_iterations: u64,
    /// Worst-case activation bytes observed anywhere in the run.
    pub peak_act_bytes: u64,
    /// Static bytes of the heaviest stage.
    pub static_bytes: u64,
}

impl RunOutcome {
    pub fn trained(&self) -> bool {
        self.oom_iterations == 0
    }
}

/// Run one scenario as a pure function of its inputs: clone the base
/// envelope, substitute the method and seed, draw the trace, evaluate.
/// No shared mutable state — the [`Simulator`] holds only per-run
/// models and every stochastic draw forks a fresh RNG from `(seed,
/// iteration, layer)` — so calls are bit-reproducible and safe to
/// execute from any thread in any order. This is the reference
/// (trace-per-scenario) execution path; the sweep engine shares one
/// trace across a cell's methods via [`run_scenario_on_trace`] and is
/// pinned bit-identical to this path.
///
/// Draws through the historical **sequential** sampler (the
/// [`crate::router::GatingSim::new`] default); [`run_scenario_sampled`]
/// takes an explicit [`RouterSampler`] — the sweep engine's legacy A/B
/// path uses it with the engine default (split).
pub fn run_scenario(base: &RunConfig, method: Method, seed: u64) -> crate::Result<RunOutcome> {
    run_scenario_sampled(base, method, seed, RouterSampler::Sequential)
}

/// [`run_scenario`] with an explicit router sampler: the per-scenario
/// reference path for either sampler's sample, pinned bit-identical to
/// trace sharing (`run_scenario_on_trace` over a trace drawn with the
/// same sampler) and to the fused [`evaluate_cell`].
pub fn run_scenario_sampled(
    base: &RunConfig,
    method: Method,
    seed: u64,
    sampler: RouterSampler,
) -> crate::Result<RunOutcome> {
    let mut run = base.clone();
    run.method = method;
    run.seed = seed;
    Ok(Simulator::new(run)?.with_sampler(sampler).run_all())
}

/// Evaluate one method against an already-drawn routing trace: the
/// trace-shared half of [`run_scenario`]. The scenario's seed is the
/// trace's seed (a trace *is* a seed's routed-token stream). For a
/// trace drawn with the default sampler, the outcome is bit-identical
/// to `run_scenario(base, method, trace.seed)` — the
/// paired-comparison invariant the sweep engine's determinism
/// contract rests on. A trace drawn with
/// [`crate::router::GatingSim::with_fast_multinomial`] is a
/// *different* (equally valid) sample of the same distribution, so
/// its outcomes are deterministic but not byte-equal to the
/// default-sampler path.
pub fn run_scenario_on_trace(
    base: &RunConfig,
    method: Method,
    trace: &SharedRoutingTrace,
) -> crate::Result<RunOutcome> {
    let mut run = base.clone();
    run.method = method;
    run.seed = trace.seed;
    let sim = Simulator::new(run)?;
    // The records encode (model, parallel)-specific per-rank statistics
    // — any geometry difference (EP width, expert count, sequence/batch
    // shape, layer counts) silently corrupts chunk decisions and OOM
    // verdicts, so the whole identity must match, not just layer
    // counts.
    if trace.model != sim.run.model || trace.parallel != sim.run.parallel {
        return Err(Error::config(
            "trace was drawn for a different (model, parallel) configuration than the run",
        ));
    }
    if trace.iterations < sim.run.iterations {
        return Err(Error::config(format!(
            "trace covers {} iterations, run needs {}",
            trace.iterations, sim.run.iterations
        )));
    }
    Ok(sim.run_on_trace(trace))
}

/// Lightweight aggregate of one simulated run: the fields the sweep
/// artifact consumes ([`crate::sweep::report::ScenarioResult`] is built
/// 1:1 from them) plus the one-f64-per-iteration Fig. 5 chunk-mean
/// series — none of the per-iteration × per-layer traces a full
/// [`RunOutcome`] materialises. The fused sweep path returns these so
/// a million-scenario grid never allocates `Vec<LayerOutcome>` +
/// `RoutingTrace` + `ChunkTrace` per scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSummary {
    /// Iterations simulated.
    pub iterations: u64,
    /// Iterations that violated Eq. 3 on some stage.
    pub oom_iterations: u64,
    /// Mean TGS over non-OOM iterations (0 if all OOM), folded in
    /// ascending iteration order — bit-identical to
    /// [`RunOutcome::avg_tgs`].
    pub avg_tgs: f64,
    /// Worst-case activation bytes observed anywhere in the run.
    pub peak_act_bytes: u64,
    /// Worst static + activation peak across iterations.
    pub peak_total_bytes: u64,
    /// Static bytes of the heaviest stage.
    pub static_bytes: u64,
    /// Mean chunk value per iteration (the Fig. 5 trend series) —
    /// bit-identical to `ChunkTrace::mean_per_iteration` on the full
    /// outcome, at one f64 per iteration instead of one record per
    /// (iteration, layer).
    pub chunk_mean_per_iteration: Vec<f64>,
}

impl RunSummary {
    pub fn trained(&self) -> bool {
        self.oom_iterations == 0
    }

    /// Collapse a full [`RunOutcome`] to its summary — the bridge the
    /// fused-vs-reference equivalence tests compare across.
    pub fn of(out: &RunOutcome) -> Self {
        RunSummary {
            iterations: out.iterations.len() as u64,
            oom_iterations: out.oom_iterations,
            avg_tgs: out.avg_tgs,
            peak_act_bytes: out.peak_act_bytes,
            peak_total_bytes: out
                .iterations
                .iter()
                .map(|i| i.peak_total_bytes)
                .max()
                .unwrap_or(0),
            static_bytes: out.static_bytes,
            chunk_mean_per_iteration: out
                .chunks
                .mean_per_iteration(out.iterations.len() as u64),
        }
    }
}

/// One method's result from a fused cell evaluation
/// ([`evaluate_cell`]), in the caller's method order.
#[derive(Clone, Debug, PartialEq)]
pub struct CellMethodOutcome {
    pub method: Method,
    pub summary: RunSummary,
}

/// One iteration's contribution to a method's [`RunSummary`] — the
/// unit [`fold_cell_partials`] re-accumulates in ascending iteration
/// order so a split cell folds bit-identically to an unsplit walk
/// (float sums are order-sensitive; u64 peaks are not, but we keep
/// one canonical order for everything).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MethodIterationRow {
    /// Eq. 3 violated on some stage this iteration.
    pub oom: bool,
    /// TGS of this iteration (counted into the mean only when not OOM).
    pub tgs: f64,
    /// Max per-stage activation peak this iteration.
    pub peak_act: u64,
    /// Max per-stage static + activation total this iteration.
    pub peak_total: u64,
    /// Mean chunk count over the iteration's MoE layers (Fig. 5 point).
    pub chunk_mean: f64,
}

/// One method's partial result from evaluating a contiguous iteration
/// range of a cell ([`evaluate_cell_range`]). Concatenating the `rows`
/// of adjacent ranges and folding with [`fold_cell_partials`]
/// reproduces the whole-cell [`CellMethodOutcome`] exactly — this is
/// the contract the intra-cell sweep splitter relies on.
#[derive(Clone, Debug, PartialEq)]
pub struct CellMethodPartial {
    pub method: Method,
    /// Static bytes of the heaviest stage (range-invariant).
    pub static_bytes: u64,
    /// Per-iteration rows for `lo..hi`, ascending.
    pub rows: Vec<MethodIterationRow>,
}

/// Memoised method-evaluation kernels for one `(max_recv, chunks)`
/// query. Everything here is stage-independent: the chunked memory
/// peaks are evaluated at `m_g = 1` (full recompute of the dense part,
/// exactly what `iteration_stats` passes), and the MemFine layer
/// timing depends only on the received tokens, the chunk count and the
/// selective-recompute flag — so one entry serves every stage, every
/// iteration, and every method of the cell that lands on the same
/// chunk decision (a fixed-chunk method and MACT picking the same bin
/// share entries).
#[derive(Clone, Copy, Debug)]
struct MemfineKernel {
    /// `act.layer(max_recv ⌈/⌉ chunks).moe_part()` — the chunked MoE
    /// transient (drives both the selective-recompute test and the
    /// selective-path peak).
    chunked_moe: u64,
    /// `act.peak_bytes_chunked(_, max_recv, chunks, true)`.
    act_chunked: u64,
    /// `perf.moe_layer_memfine(max_recv, chunks, true).total()`.
    time_rc: f64,
    /// `perf.moe_layer_memfine(max_recv, chunks, false).total()`.
    time_selective: f64,
}

/// Memoised Method-1 kernels for one `max_recv` (chunking never
/// applies; `m_g = 1` under full recompute, so stage-independent too).
#[derive(Clone, Copy, Debug)]
struct Method1Kernel {
    /// `act.peak_bytes(_, max_recv, true)`.
    act: u64,
    /// `perf.moe_layer_method1(max_recv).total()`.
    time: f64,
}

/// One MoE layer's resolved evaluation inputs for the current
/// (iteration, method) — pass-1 scratch consumed by pass 2.
#[derive(Clone, Copy)]
struct LayerEval {
    stage: usize,
    chunks: u64,
    chunked_moe: u64,
    act_plain: u64,
    time_plain: f64,
    time_selective: f64,
}

/// Per-method state of a fused cell evaluation: the method's chunking
/// policy plus its per-iteration rows (folded into aggregates by
/// [`fold_cell_partials`]).
struct MethodState {
    method: Method,
    method1: bool,
    fixed_c: Option<u64>,
    mact: Option<Mact>,
    /// Eq. 8 budget per pipeline stage (MACT only) — constant over the
    /// run, hoisted out of the per-layer decision.
    s_max: Vec<u64>,
    rows: Vec<MethodIterationRow>,
}

fn memfine_kernel(
    memo: &mut HashMap<(u64, u64), MemfineKernel>,
    act: &ActivationModel,
    perf: &PerfModel,
    max_recv: u64,
    chunks: u64,
) -> MemfineKernel {
    *memo.entry((max_recv, chunks)).or_insert_with(|| MemfineKernel {
        chunked_moe: act.layer(max_recv.div_ceil(chunks)).moe_part(),
        act_chunked: act.peak_bytes_chunked(0, max_recv, chunks, true),
        time_rc: perf.moe_layer_memfine(max_recv, chunks, true).total(),
        time_selective: perf.moe_layer_memfine(max_recv, chunks, false).total(),
    })
}

fn method1_kernel(
    memo: &mut HashMap<u64, Method1Kernel>,
    act: &ActivationModel,
    perf: &PerfModel,
    max_recv: u64,
) -> Method1Kernel {
    *memo.entry(max_recv).or_insert_with(|| Method1Kernel {
        act: act.peak_bytes(0, max_recv, true),
        time: perf.moe_layer_method1(max_recv).total(),
    })
}

/// Evaluate **every** method of a paired-comparison cell against one
/// shared routing trace in a single trace walk — the fused form of
/// calling [`run_scenario_on_trace`] once per method, pinned
/// bit-identical to it (and transitively to [`run_scenario`]) by the
/// unit, property and sweep integration tests.
///
/// Why one pass wins:
///
/// * the method-independent work per (iteration, layer) — stage
///   lookup, the trace-record walk, the per-stage geometry
///   (`m_g · layers · dense_bytes`, static bytes, dense-layer timing)
///   — is hoisted once per cell instead of recomputed per method;
/// * the method-dependent kernels (`chunks_for`,
///   `peak_bytes_chunked`, `PerfModel::moe_layer_*`) are memoised in
///   per-cell caches keyed on `(max_recv, chunks)` (Method 1:
///   `max_recv`), since routing statistics repeat across iterations
///   once the router stabilises and methods frequently land on the
///   same chunk decision — every repeat costs a map probe instead of
///   re-deriving the memory and timing models;
/// * per-stage scratch buffers are reused across all (iteration,
///   method) evaluations, and only [`RunSummary`] aggregates are
///   produced — no per-iteration `Vec<LayerOutcome>`, `RoutingTrace`
///   or `ChunkTrace` is materialised.
///
/// The scenario seed is the trace's seed, exactly as in
/// [`run_scenario_on_trace`]; outcomes come back in the caller's
/// method order. Evaluation never touches the RNG.
pub fn evaluate_cell(
    base: &RunConfig,
    methods: &[Method],
    trace: &SharedRoutingTrace,
) -> crate::Result<Vec<CellMethodOutcome>> {
    if trace.iterations < base.iterations {
        return Err(Error::config(format!(
            "trace covers {} iterations, run needs {}",
            trace.iterations, base.iterations
        )));
    }
    let parts = evaluate_cell_range(base, methods, trace, 0, base.iterations)?;
    fold_cell_partials(vec![parts])
}

/// Evaluate iterations `lo..hi` of a fused cell against `trace` —
/// the range form of [`evaluate_cell`], which is literally
/// `evaluate_cell_range(_, _, _, 0, iterations)` + one fold. The trace
/// must cover the range (`trace.first_iteration <= lo && hi <=
/// trace.iterations`); per-iteration evaluation has no cross-iteration
/// state (memo caches are pure), so any partition of `0..iterations`
/// into contiguous ranges folds back bit-identically.
pub fn evaluate_cell_range(
    base: &RunConfig,
    methods: &[Method],
    trace: &SharedRoutingTrace,
    lo: u64,
    hi: u64,
) -> crate::Result<Vec<CellMethodPartial>> {
    let mut run = base.clone();
    run.seed = trace.seed;
    // Same trace-identity contract as run_scenario_on_trace: the
    // records encode (model, parallel)-specific per-rank statistics.
    if trace.model != run.model || trace.parallel != run.parallel {
        return Err(Error::config(
            "trace was drawn for a different (model, parallel) configuration than the run",
        ));
    }
    if lo > hi || lo < trace.first_iteration || hi > trace.iterations {
        return Err(Error::config(format!(
            "iteration range {}..{} outside trace coverage {}..{}",
            lo, hi, trace.first_iteration, trace.iterations
        )));
    }

    // Shared (method-independent) models, built once per cell.
    let mut probe = run.clone();
    probe.method = methods.first().cloned().unwrap_or(Method::FullRecompute);
    probe.validate()?;
    let act = ActivationModel::new(&probe);
    let sta = StaticModel::new(&probe);
    let perf = PerfModel::new(run.model.clone(), run.parallel.clone(), run.dtype_bytes);

    // Per-method policy + accumulators (validating each resolved run).
    let mut states = methods
        .iter()
        .map(|m| {
            let mut r = run.clone();
            r.method = m.clone();
            r.validate()?;
            let (method1, fixed_c, mact) = match m {
                Method::FullRecompute => (true, None, None),
                Method::FixedChunk(c) => (false, Some(*c), None),
                Method::Mact(bins) => (false, None, Some(Mact::new(&r, bins.clone()))),
            };
            let s_max = match &mact {
                Some(ma) => (0..run.parallel.pp).map(|s| ma.s_prime_max(s)).collect(),
                None => Vec::new(),
            };
            Ok(MethodState {
                method: m.clone(),
                method1,
                fixed_c,
                mact,
                s_max,
                rows: Vec::with_capacity((hi - lo) as usize),
            })
        })
        .collect::<crate::Result<Vec<MethodState>>>()?;

    // Hoisted per-cell geometry — exactly the terms iteration_stats
    // derives per iteration, computed once here (all pure integer /
    // float expressions, so the hoists are bit-neutral).
    let pp = run.parallel.pp as usize;
    let budget = (run.alpha * run.gpu_mem_bytes as f64) as u64;
    let layers_per_stage = run.parallel.layers_per_stage(run.model.layers);
    let stage_of =
        |layer: u64| ((layer / layers_per_stage).min(run.parallel.pp - 1)) as usize;
    let dense_stage: Vec<usize> = (0..run.model.dense_layers).map(stage_of).collect();
    let moe_stage: Vec<usize> =
        (run.model.dense_layers..run.model.layers).map(stage_of).collect();
    let n_moe = moe_stage.len();
    let sta_bytes: Vec<u64> = (0..run.parallel.pp).map(|s| sta.bytes_on_rank(s)).collect();
    let dense_bytes = act.dense_bytes();
    let stored_dense: Vec<u64> = (0..run.parallel.pp)
        .map(|s| run.parallel.m_g(s) * layers_per_stage * dense_bytes)
        .collect();
    let dense_time_rc = perf.dense_layer(true).total();
    let dense_time_norc = perf.dense_layer(false).total();
    let micro_batches = run.parallel.micro_batches();
    let static_bytes = sta.max_bytes();
    let allow_selective = run.allow_selective_recompute;

    // Per-cell memo caches and per-iteration scratch, reused across
    // every (iteration, method) evaluation.
    let mut memfine_memo: HashMap<(u64, u64), MemfineKernel> = HashMap::new();
    let mut method1_memo: HashMap<u64, Method1Kernel> = HashMap::new();
    let mut layer_evals: Vec<LayerEval> = Vec::with_capacity(n_moe);
    let mut moe_chunk_peak = vec![0u64; pp];
    let mut selective = vec![false; pp];
    let mut per_stage_time = vec![0.0f64; pp];
    let mut per_stage_act_peak = vec![0u64; pp];

    for it in lo..hi {
        let recs = trace.iteration(it);
        debug_assert_eq!(recs.len(), n_moe);
        for state in &mut states {
            // Pass 1: chunk decisions + chunked-MoE peaks per stage
            // (kernels from the memo; ascending layer order).
            layer_evals.clear();
            moe_chunk_peak.fill(0);
            for (j, rec) in recs.iter().enumerate() {
                debug_assert_eq!(rec.iteration, it);
                let stage = moe_stage[j];
                let r = rec.max_recv;
                if state.method1 {
                    let k = method1_kernel(&mut method1_memo, &act, &perf, r);
                    layer_evals.push(LayerEval {
                        stage,
                        chunks: 1,
                        chunked_moe: 0,
                        act_plain: k.act,
                        time_plain: k.time,
                        time_selective: 0.0,
                    });
                } else {
                    let chunks = match (state.fixed_c, &state.mact) {
                        (Some(c), _) => c,
                        (None, Some(mact)) => {
                            mact.decide_given(state.s_max[stage], r).chosen_c
                        }
                        (None, None) => unreachable!("method is chunked"),
                    };
                    let k = memfine_kernel(&mut memfine_memo, &act, &perf, r, chunks);
                    moe_chunk_peak[stage] = moe_chunk_peak[stage].max(k.chunked_moe);
                    layer_evals.push(LayerEval {
                        stage,
                        chunks,
                        chunked_moe: k.chunked_moe,
                        act_plain: k.act_chunked,
                        time_plain: k.time_rc,
                        time_selective: k.time_selective,
                    });
                }
            }

            // Selective-recompute verdict per stage (Eq. 3 with the
            // stored dense part) — same sum as Simulator::selective_fits.
            for s in 0..pp {
                selective[s] = !state.method1
                    && allow_selective
                    && sta_bytes[s] + stored_dense[s] + moe_chunk_peak[s] <= budget;
            }

            // Pass 2: memory + time accumulation, in iteration_stats's
            // exact order (dense layers ascending, then MoE layers
            // ascending — float sums are order-sensitive).
            per_stage_time.fill(0.0);
            per_stage_act_peak.fill(0);
            for &s in &dense_stage {
                per_stage_time[s] +=
                    if selective[s] { dense_time_norc } else { dense_time_rc };
                per_stage_act_peak[s] = per_stage_act_peak[s].max(dense_bytes);
            }
            let mut chunk_sum = 0.0f64;
            for le in &layer_evals {
                let s = le.stage;
                let sel = !state.method1 && selective[s];
                let act_bytes = if sel {
                    stored_dense[s] + le.chunked_moe
                } else {
                    le.act_plain
                };
                per_stage_act_peak[s] = per_stage_act_peak[s].max(act_bytes);
                per_stage_time[s] += if sel { le.time_selective } else { le.time_plain };
                chunk_sum += le.chunks as f64;
            }

            let mut oom = false;
            let mut it_peak_total = 0u64;
            let mut it_peak_act = 0u64;
            for s in 0..pp {
                let total = sta_bytes[s] + per_stage_act_peak[s];
                it_peak_total = it_peak_total.max(total);
                it_peak_act = it_peak_act.max(per_stage_act_peak[s]);
                if total > budget {
                    oom = true;
                }
            }
            let iteration_s = perf.iteration_time(&per_stage_time, micro_batches);
            let tgs = perf.tgs(iteration_s);
            state.rows.push(MethodIterationRow {
                oom,
                tgs,
                peak_act: it_peak_act,
                peak_total: it_peak_total,
                chunk_mean: if n_moe == 0 { 0.0 } else { chunk_sum / n_moe as f64 },
            });
        }
    }

    Ok(states
        .into_iter()
        .map(|s| CellMethodPartial { method: s.method, static_bytes, rows: s.rows })
        .collect())
}

/// Fold the partial results of contiguous iteration ranges (given in
/// ascending range order, jointly covering the whole run) back into
/// whole-cell outcomes. The accumulation replays [`evaluate_cell`]'s
/// original in-place order exactly — rows visited ascending, TGS
/// summed left-to-right over non-OOM iterations, peaks max-folded,
/// chunk means appended — so the result is bit-identical for every
/// partition of the run, including the trivial one-range partition.
pub fn fold_cell_partials(
    parts: Vec<Vec<CellMethodPartial>>,
) -> crate::Result<Vec<CellMethodOutcome>> {
    let n_methods = match parts.first() {
        Some(first) => first.len(),
        None => return Err(Error::config("no cell partials to fold")),
    };
    if parts.iter().any(|p| p.len() != n_methods) {
        return Err(Error::config("cell partials disagree on method count"));
    }
    let mut out = Vec::with_capacity(n_methods);
    for m in 0..n_methods {
        let method = parts[0][m].method.clone();
        let static_bytes = parts[0][m].static_bytes;
        let mut iterations = 0u64;
        let mut oom_iterations = 0u64;
        let mut tgs_sum = 0.0f64;
        let mut tgs_n = 0u64;
        let mut peak_act = 0u64;
        let mut peak_total = 0u64;
        let mut chunk_means = Vec::new();
        for part in &parts {
            let p = &part[m];
            if p.method != method || p.static_bytes != static_bytes {
                return Err(Error::config("cell partials disagree on method identity"));
            }
            iterations += p.rows.len() as u64;
            for row in &p.rows {
                if row.oom {
                    oom_iterations += 1;
                } else {
                    tgs_sum += row.tgs;
                    tgs_n += 1;
                }
                peak_act = peak_act.max(row.peak_act);
                peak_total = peak_total.max(row.peak_total);
                chunk_means.push(row.chunk_mean);
            }
        }
        out.push(CellMethodOutcome {
            method,
            summary: RunSummary {
                iterations,
                oom_iterations,
                avg_tgs: if tgs_n > 0 { tgs_sum / tgs_n as f64 } else { 0.0 },
                peak_act_bytes: peak_act,
                peak_total_bytes: peak_total,
                static_bytes,
                chunk_mean_per_iteration: chunk_means,
            },
        });
    }
    Ok(out)
}

/// The simulator.
pub struct Simulator {
    pub run: RunConfig,
    gating: GatingSim,
    act: ActivationModel,
    sta: StaticModel,
    perf: PerfModel,
    mact: Option<Mact>,
}

impl Simulator {
    pub fn new(run: RunConfig) -> crate::Result<Self> {
        run.validate()?;
        let gating = GatingSim::new(run.model.clone(), run.parallel.clone(), run.seed);
        let act = ActivationModel::new(&run);
        let sta = StaticModel::new(&run);
        let perf = PerfModel::new(run.model.clone(), run.parallel.clone(), run.dtype_bytes);
        let mact = match &run.method {
            Method::Mact(bins) => Some(Mact::new(&run, bins.clone())),
            _ => None,
        };
        Ok(Simulator { run, gating, act, sta, perf, mact })
    }

    /// Select the router sampler traces are drawn with (see
    /// [`GatingSim::with_sampler`]); evaluation is sampler-blind.
    pub fn with_sampler(mut self, sampler: RouterSampler) -> Self {
        self.gating.set_sampler(sampler);
        self
    }

    /// Pipeline stage hosting `layer`.
    fn stage_of(&self, layer: u64) -> u64 {
        let per = self.run.parallel.layers_per_stage(self.run.model.layers);
        (layer / per).min(self.run.parallel.pp - 1)
    }

    /// The method's chunk decision for (stage, s'').
    pub fn chunks_for(&self, stage: u64, max_recv: u64) -> u64 {
        match &self.run.method {
            Method::FullRecompute => 1,
            Method::FixedChunk(c) => *c,
            Method::Mact(_) => {
                self.mact.as_ref().expect("mact built").decide(stage, max_recv).chosen_c
            }
        }
    }

    /// Can MemFine skip attention recomputation on this stage
    /// (*selective* recomputation)? Only if storing the dense part of
    /// all the stage's layers for every in-flight micro-batch
    /// (`stored_dense = m_g · layers_per_stage · dense_bytes`,
    /// loop-invariant and precomputed by the caller) — plus the chunked
    /// MoE peak — still fits the budget (Eq. 3). This is the throughput
    /// edge of Methods 2/3 over full recomputation.
    fn selective_fits(
        &self,
        stage: u64,
        stored_dense: u64,
        moe_chunk_peak: u64,
        budget: u64,
    ) -> bool {
        self.sta.bytes_on_rank(stage) + stored_dense + moe_chunk_peak <= budget
    }

    /// Simulate one iteration, drawing its routing directly (the
    /// standalone path; [`Simulator::run_on_trace`] evaluates against
    /// a pre-drawn trace instead, with bit-identical results).
    pub fn iteration(&self, it: u64) -> IterationOutcome {
        let model = &self.run.model;
        let stats: Vec<RoutingRecord> = (model.dense_layers..model.layers)
            .map(|layer| {
                let routing = self.gating.route(it, layer);
                let s = routing.summary();
                RoutingRecord {
                    iteration: it,
                    layer,
                    min_recv: routing.min_received(),
                    mean_recv: s.mean(),
                    max_recv: routing.max_received(),
                }
            })
            .collect();
        self.iteration_stats(it, &stats)
    }

    /// Evaluate one iteration of the configured method against the
    /// given per-MoE-layer routing statistics (ascending layer order).
    /// Pure method evaluation: no RNG is touched here, which is what
    /// lets a cell's methods share one drawn trace.
    fn iteration_stats(&self, it: u64, moe_stats: &[RoutingRecord]) -> IterationOutcome {
        let model = &self.run.model;
        let pp = self.run.parallel.pp as usize;
        let budget = (self.run.alpha * self.run.gpu_mem_bytes as f64) as u64;
        let method1 = matches!(self.run.method, Method::FullRecompute);
        debug_assert_eq!(
            moe_stats.len(),
            (model.layers - model.dense_layers) as usize
        );

        // Loop-invariant geometry, hoisted out of the per-layer work
        // below: layers-per-stage does not depend on the stage, and the
        // selective-recompute dense term `m_g · layers · dense_bytes`
        // only varies by stage.
        let layers_per_stage = self.run.parallel.layers_per_stage(model.layers);
        let dense_bytes = self.act.dense_bytes();
        let stored_dense: Vec<u64> = (0..self.run.parallel.pp)
            .map(|s| self.run.parallel.m_g(s) * layers_per_stage * dense_bytes)
            .collect();

        // Pass 1: chunk decision per MoE layer from the routing stats.
        struct MoeLayer {
            layer: u64,
            stage: usize,
            min_recv: u64,
            mean_recv: f64,
            max_recv: u64,
            chunks: u64,
        }
        // Only the MoE layers land here — `model.layers` would
        // over-allocate by the dense-layer count.
        let mut moe_layers = Vec::with_capacity(moe_stats.len());
        for rec in moe_stats {
            debug_assert_eq!(rec.iteration, it);
            let layer = rec.layer;
            let stage = self.stage_of(layer) as usize;
            let max_recv = rec.max_recv;
            let chunks = self.chunks_for(stage as u64, max_recv);
            moe_layers.push(MoeLayer {
                layer,
                stage,
                min_recv: rec.min_recv,
                mean_recv: rec.mean_recv,
                max_recv,
                chunks,
            });
        }

        // Per-stage chunked-MoE peaks decide selective recompute.
        let mut moe_chunk_peak = vec![0u64; pp];
        for l in &moe_layers {
            let chunked = self
                .act
                .layer(l.max_recv.div_ceil(l.chunks))
                .moe_part();
            moe_chunk_peak[l.stage] = moe_chunk_peak[l.stage].max(chunked);
        }
        let selective: Vec<bool> = (0..pp)
            .map(|s| {
                !method1
                    && self.run.allow_selective_recompute
                    && self.selective_fits(s as u64, stored_dense[s], moe_chunk_peak[s], budget)
            })
            .collect();

        // Pass 2: memory + time accumulation.
        let mut layers = Vec::with_capacity(moe_layers.len());
        let mut per_stage_time = vec![0.0f64; pp];
        let mut per_stage_act_peak = vec![0u64; pp];
        for layer in 0..model.dense_layers {
            let stage = self.stage_of(layer) as usize;
            per_stage_time[stage] += self.perf.dense_layer(!selective[stage]).total();
            per_stage_act_peak[stage] = per_stage_act_peak[stage].max(dense_bytes);
        }
        for l in &moe_layers {
            let stage = l.stage;
            let act_bytes = if method1 {
                self.act.peak_bytes(stage as u64, l.max_recv, true)
            } else if selective[stage] {
                // stored dense part of the whole stage + this layer's
                // chunked MoE transient
                stored_dense[stage]
                    + self.act.layer(l.max_recv.div_ceil(l.chunks)).moe_part()
            } else {
                self.act
                    .peak_bytes_chunked(stage as u64, l.max_recv, l.chunks, true)
            };
            per_stage_act_peak[stage] = per_stage_act_peak[stage].max(act_bytes);
            per_stage_time[stage] += if method1 {
                self.perf.moe_layer_method1(l.max_recv).total()
            } else {
                self.perf
                    .moe_layer_memfine(l.max_recv, l.chunks, !selective[stage])
                    .total()
            };
            layers.push(LayerOutcome {
                layer: l.layer,
                min_recv: l.min_recv,
                mean_recv: l.mean_recv,
                max_recv: l.max_recv,
                chunks: l.chunks,
                act_bytes,
            });
        }

        let mut oom = false;
        let mut peak_total = 0u64;
        let mut peak_act = 0u64;
        for stage in 0..self.run.parallel.pp {
            let total = self.sta.bytes_on_rank(stage) + per_stage_act_peak[stage as usize];
            peak_total = peak_total.max(total);
            peak_act = peak_act.max(per_stage_act_peak[stage as usize]);
            if total > budget {
                oom = true;
            }
        }

        let iteration_s = self
            .perf
            .iteration_time(&per_stage_time, self.run.parallel.micro_batches());
        let tgs = self.perf.tgs(iteration_s);
        IterationOutcome {
            iteration: it,
            layers,
            peak_act_bytes: peak_act,
            peak_total_bytes: peak_total,
            oom,
            iteration_s,
            tgs,
        }
    }

    /// Draw this run's full routing trace (phase 1 of the run). The
    /// trace depends only on (model, gating, seed) — callers holding
    /// several methods of one cell draw it once and evaluate each via
    /// [`Simulator::run_on_trace`] / [`run_scenario_on_trace`].
    pub fn draw_trace(&self) -> SharedRoutingTrace {
        SharedRoutingTrace::generate(&self.gating, self.run.iterations)
    }

    /// Simulate the configured number of iterations, producing traces.
    ///
    /// Like the real system, an OOM iteration contributes no TGS sample
    /// (the job would have crashed); the bench reports `trained = ×`
    /// when any iteration OOMs — matching Table 4's "training" column.
    pub fn run_all(&self) -> RunOutcome {
        self.run_on_trace(&self.draw_trace())
    }

    /// Evaluate the configured method against a pre-drawn routing
    /// trace (phase 2 of the run). Bit-identical to
    /// [`Simulator::run_all`] when
    /// the trace was drawn from this run's seed: evaluation consumes
    /// only the per-(iteration, layer) statistics, which
    /// [`SharedRoutingTrace::generate`] draws through the very same
    /// stateless `route()` streams.
    ///
    /// Panics (debug) if the trace shape does not match the run; use
    /// [`run_scenario_on_trace`] for a validated entry point.
    pub fn run_on_trace(&self, trace: &SharedRoutingTrace) -> RunOutcome {
        debug_assert_eq!(trace.model, self.run.model);
        debug_assert_eq!(trace.parallel, self.run.parallel);
        debug_assert!(trace.iterations >= self.run.iterations);
        let mut iterations = Vec::new();
        let mut routing = RoutingTrace::default();
        let mut chunks = ChunkTrace::default();
        let mut tgs_sum = 0.0;
        let mut tgs_n = 0u64;
        let mut oom_iterations = 0;
        let mut peak_act = 0u64;

        for it in 0..self.run.iterations {
            let out = self.iteration_stats(it, trace.iteration(it));
            for l in &out.layers {
                chunks.push(ChunkRecord {
                    iteration: it,
                    layer: l.layer,
                    chosen_c: l.chunks,
                });
            }
            for l in &out.layers {
                routing.push(RoutingRecord {
                    iteration: it,
                    layer: l.layer,
                    min_recv: l.min_recv,
                    mean_recv: l.mean_recv,
                    max_recv: l.max_recv,
                });
            }
            if out.oom {
                oom_iterations += 1;
            } else {
                tgs_sum += out.tgs;
                tgs_n += 1;
            }
            peak_act = peak_act.max(out.peak_act_bytes);
            iterations.push(out);
        }
        RunOutcome {
            method: self.run.method.clone(),
            iterations,
            routing,
            chunks,
            avg_tgs: if tgs_n > 0 { tgs_sum / tgs_n as f64 } else { 0.0 },
            oom_iterations,
            peak_act_bytes: peak_act,
            static_bytes: self.sta.max_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, model_ii, paper_run, Method};

    fn outcome(model: crate::config::ModelConfig, method: Method) -> RunOutcome {
        let mut run = paper_run(model, method);
        run.iterations = 20;
        Simulator::new(run).unwrap().run_all()
    }

    #[test]
    fn method1_model_i_ooms_table4() {
        let o = outcome(model_i(), Method::FullRecompute);
        assert!(!o.trained(), "Table 4: Method 1 on Model I must OOM");
    }

    #[test]
    fn memfine_rescues_model_i_table4() {
        let o2 = outcome(model_i(), Method::FixedChunk(8));
        assert!(o2.trained(), "Method 2 must train");
        let o3 = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        assert!(o3.trained(), "Method 3 must train");
    }

    #[test]
    fn activation_ordering_m2_lt_m3_lt_m1() {
        // Table 4: c=8 saves most activation; MACT sits between.
        let m1 = outcome(model_i(), Method::FullRecompute).peak_act_bytes;
        let m2 = outcome(model_i(), Method::FixedChunk(8)).peak_act_bytes;
        let m3 = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8])).peak_act_bytes;
        assert!(m2 < m3, "m2 {m2} !< m3 {m3}");
        assert!(m3 < m1, "m3 {m3} !< m1 {m1}");
    }

    #[test]
    fn model_ii_method1_trains_table4() {
        let o = outcome(model_ii(), Method::FullRecompute);
        assert!(o.trained(), "Table 4: Method 1 on Model II trains");
    }

    #[test]
    fn fig4_model_ii_ordering() {
        // Model II average TGS: Method 3 > Method 1 > Method 2.
        let m1 = outcome(model_ii(), Method::FullRecompute).avg_tgs;
        let m2 = outcome(model_ii(), Method::FixedChunk(8)).avg_tgs;
        let m3 = outcome(model_ii(), Method::Mact(vec![1, 2, 4, 8])).avg_tgs;
        assert!(m3 > m1, "m3 {m3} !> m1 {m1}");
        assert!(m1 > m2, "m1 {m1} !> m2 {m2}");
    }

    #[test]
    fn fig4_model_i_m3_beats_m2() {
        let m2 = outcome(model_i(), Method::FixedChunk(8)).avg_tgs;
        let m3 = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8])).avg_tgs;
        assert!(m3 > m2, "m3 {m3} !> m2 {m2}");
    }

    #[test]
    fn fig5_chunk_trend_bump() {
        // Mean MACT chunk value rises into the chaos window then falls.
        let o = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        let means = o.chunks.mean_per_iteration(20);
        let early = means[0];
        let peak = means[5..12].iter().cloned().fold(0.0, f64::max);
        let late = means[19];
        assert!(peak > early, "peak {peak} !> early {early}");
        assert!(peak > late, "peak {peak} !> late {late}");
    }

    #[test]
    fn fig5_deep_layers_get_larger_chunks() {
        let o = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        let grid = o.chunks.grid(16, 20);
        let shallow: u64 = (3..8).map(|l| grid[l][7]).sum();
        let deep: u64 = (11..16).map(|l| grid[l][7]).sum();
        assert!(deep >= shallow, "deep {deep} < shallow {shallow}");
    }

    #[test]
    fn deterministic_runs() {
        let a = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        let b = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        assert_eq!(a.peak_act_bytes, b.peak_act_bytes);
        assert_eq!(a.avg_tgs, b.avg_tgs);
        assert_eq!(a.chunks.records, b.chunks.records);
    }

    #[test]
    fn run_scenario_pure_and_matches_simulator() {
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 8;
        let a = run_scenario(&base, Method::Mact(vec![1, 2, 4, 8]), 11).unwrap();
        let b = run_scenario(&base, Method::Mact(vec![1, 2, 4, 8]), 11).unwrap();
        assert_eq!(a.chunks.records, b.chunks.records);
        assert_eq!(a.peak_act_bytes, b.peak_act_bytes);
        assert_eq!(a.avg_tgs, b.avg_tgs);
        // the base envelope is input, not state: untouched
        assert_eq!(base.method, Method::FullRecompute);
        assert_eq!(base.seed, 7);
        // and equals the direct Simulator path
        let mut direct = base.clone();
        direct.method = Method::Mact(vec![1, 2, 4, 8]);
        direct.seed = 11;
        let c = Simulator::new(direct).unwrap().run_all();
        assert_eq!(a.chunks.records, c.chunks.records);
        assert_eq!(a.avg_tgs, c.avg_tgs);
    }

    #[test]
    fn trace_sharing_bit_identical_to_per_scenario_runs() {
        // The paired-comparison invariant: every method evaluated
        // against one shared trace must equal its own full
        // run_scenario (which re-draws the same trace from the seed).
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 8;
        let seed = 11u64;
        let mut probe = base.clone();
        probe.seed = seed;
        let trace = Simulator::new(probe).unwrap().draw_trace();
        for method in [
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ] {
            let shared = run_scenario_on_trace(&base, method.clone(), &trace).unwrap();
            let direct = run_scenario(&base, method.clone(), seed).unwrap();
            assert_eq!(shared.chunks.records, direct.chunks.records);
            assert_eq!(shared.routing.records, direct.routing.records);
            assert_eq!(shared.peak_act_bytes, direct.peak_act_bytes);
            assert_eq!(shared.oom_iterations, direct.oom_iterations);
            assert_eq!(shared.avg_tgs, direct.avg_tgs);
        }
    }

    #[test]
    fn run_scenario_sampled_matches_sampled_trace_path() {
        // The per-scenario reference under the split sampler must equal
        // evaluating against a split-sampler trace — the invariant that
        // lets the sweep default flip without breaking the A/B chain.
        use crate::trace::provenance::RouterSampler;
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 6;
        let seed = 11u64;
        let gating = crate::router::GatingSim::new(
            base.model.clone(),
            base.parallel.clone(),
            seed,
        )
        .with_sampler(RouterSampler::Split);
        let trace = SharedRoutingTrace::generate(&gating, base.iterations);
        for method in [Method::FullRecompute, Method::Mact(vec![1, 2, 4, 8])] {
            let direct =
                run_scenario_sampled(&base, method.clone(), seed, RouterSampler::Split)
                    .unwrap();
            let shared = run_scenario_on_trace(&base, method.clone(), &trace).unwrap();
            assert_eq!(direct.routing.records, shared.routing.records);
            assert_eq!(direct.chunks.records, shared.chunks.records);
            assert_eq!(direct.avg_tgs.to_bits(), shared.avg_tgs.to_bits());
            // the sequential reference is a different sample
            let seq = run_scenario(&base, method.clone(), seed).unwrap();
            assert_ne!(direct.routing.records, seq.routing.records);
        }
    }

    #[test]
    fn evaluate_cell_bit_identical_to_per_method_trace_runs() {
        // THE fused-path invariant: one trace walk evaluating all
        // methods must reproduce every field of the per-method
        // run_scenario_on_trace summaries to the bit — OOM-heavy
        // Method 1 on Model I included.
        let methods = vec![
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ];
        for model in [model_i(), model_ii()] {
            let mut base = paper_run(model, Method::FullRecompute);
            base.iterations = 8;
            let mut probe = base.clone();
            probe.seed = 11;
            let trace = Simulator::new(probe).unwrap().draw_trace();
            let fused = evaluate_cell(&base, &methods, &trace).unwrap();
            assert_eq!(fused.len(), methods.len());
            for (outcome, method) in fused.iter().zip(&methods) {
                assert_eq!(&outcome.method, method);
                let reference = RunSummary::of(
                    &run_scenario_on_trace(&base, method.clone(), &trace).unwrap(),
                );
                assert_eq!(
                    outcome.summary.avg_tgs.to_bits(),
                    reference.avg_tgs.to_bits(),
                    "{method:?} avg_tgs"
                );
                for (a, b) in outcome
                    .summary
                    .chunk_mean_per_iteration
                    .iter()
                    .zip(&reference.chunk_mean_per_iteration)
                {
                    assert_eq!(a.to_bits(), b.to_bits(), "{method:?} chunk mean");
                }
                assert_eq!(outcome.summary, reference, "{method:?}");
            }
        }
    }

    #[test]
    fn evaluate_cell_without_selective_recompute_matches_reference() {
        // The Table-4 accounting configuration (selective recompute
        // disabled) drives the non-selective branches everywhere.
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 6;
        base.allow_selective_recompute = false;
        let methods = vec![Method::FixedChunk(4), Method::Mact(vec![1, 2, 4, 8])];
        let mut probe = base.clone();
        probe.seed = 5;
        let trace = Simulator::new(probe).unwrap().draw_trace();
        let fused = evaluate_cell(&base, &methods, &trace).unwrap();
        for (outcome, method) in fused.iter().zip(&methods) {
            let reference =
                RunSummary::of(&run_scenario_on_trace(&base, method.clone(), &trace).unwrap());
            assert_eq!(outcome.summary, reference, "{method:?}");
        }
    }

    #[test]
    fn evaluate_cell_empty_methods_and_mismatched_trace() {
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 4;
        let mut probe = base.clone();
        probe.seed = 3;
        let trace = Simulator::new(probe.clone()).unwrap().draw_trace();
        assert!(evaluate_cell(&base, &[], &trace).unwrap().is_empty());
        // short trace
        let mut short = probe.clone();
        short.iterations = 2;
        let short_trace = Simulator::new(short).unwrap().draw_trace();
        assert!(evaluate_cell(&base, &[Method::FullRecompute], &short_trace).is_err());
        // wrong model
        let mut other = paper_run(model_ii(), Method::FullRecompute);
        other.iterations = 4;
        other.seed = 3;
        let trace_ii = Simulator::new(other).unwrap().draw_trace();
        assert!(evaluate_cell(&base, &[Method::FullRecompute], &trace_ii).is_err());
    }

    #[test]
    fn evaluate_cell_range_split_folds_bit_identical() {
        // The intra-cell split invariant: ANY partition of the run into
        // contiguous ranges, folded in order, equals the unsplit walk
        // to the bit (the sweep splitter's artifact-stability contract).
        let methods = vec![
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ];
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 9;
        let mut probe = base.clone();
        probe.seed = 11;
        let trace = Simulator::new(probe).unwrap().draw_trace();
        let whole = evaluate_cell(&base, &methods, &trace).unwrap();
        for bounds in [
            vec![0u64, 9],
            vec![0, 1, 9],
            vec![0, 4, 9],
            vec![0, 3, 6, 9],
            vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9],
        ] {
            let parts = bounds
                .windows(2)
                .map(|w| evaluate_cell_range(&base, &methods, &trace, w[0], w[1]).unwrap())
                .collect::<Vec<_>>();
            let folded = fold_cell_partials(parts).unwrap();
            assert_eq!(folded.len(), whole.len());
            for (f, w) in folded.iter().zip(&whole) {
                assert_eq!(
                    f.summary.avg_tgs.to_bits(),
                    w.summary.avg_tgs.to_bits(),
                    "split {bounds:?}"
                );
                assert_eq!(f, w, "split {bounds:?}");
            }
        }
    }

    #[test]
    fn evaluate_cell_range_on_range_trace_matches_full_trace() {
        // Slice jobs draw only their own iteration range
        // (generate_range) — the partial must equal evaluating the
        // same range against the full trace, under both RNG versions.
        use crate::trace::provenance::RngVersion;
        let methods = vec![Method::FixedChunk(8), Method::Mact(vec![1, 2, 4, 8])];
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 8;
        for rng in [RngVersion::V1, RngVersion::V2] {
            let gating = crate::router::GatingSim::new(
                base.model.clone(),
                base.parallel.clone(),
                11,
            )
            .with_rng(rng);
            let full = SharedRoutingTrace::generate(&gating, base.iterations);
            for (lo, hi) in [(0u64, 8u64), (0, 3), (3, 8), (5, 6), (8, 8)] {
                let range = SharedRoutingTrace::generate_range(&gating, lo, hi);
                let a = evaluate_cell_range(&base, &methods, &range, lo, hi).unwrap();
                let b = evaluate_cell_range(&base, &methods, &full, lo, hi).unwrap();
                assert_eq!(a, b, "{rng:?} range {lo}..{hi}");
            }
        }
    }

    #[test]
    fn evaluate_cell_range_rejects_uncovered_ranges() {
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 6;
        let gating = crate::router::GatingSim::new(base.model.clone(), base.parallel.clone(), 3);
        let range_trace = SharedRoutingTrace::generate_range(&gating, 2, 5);
        let methods = [Method::FullRecompute];
        // inside coverage: fine
        assert!(evaluate_cell_range(&base, &methods, &range_trace, 2, 5).is_ok());
        // before / past coverage, inverted bounds: rejected
        assert!(evaluate_cell_range(&base, &methods, &range_trace, 0, 5).is_err());
        assert!(evaluate_cell_range(&base, &methods, &range_trace, 2, 6).is_err());
        assert!(evaluate_cell_range(&base, &methods, &range_trace, 4, 3).is_err());
        // fold of nothing is an error, not a silent empty result
        assert!(fold_cell_partials(Vec::new()).is_err());
    }

    #[test]
    fn run_summary_of_collapses_outcome() {
        let o = outcome(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        let s = RunSummary::of(&o);
        assert_eq!(s.iterations, 20);
        assert_eq!(s.oom_iterations, o.oom_iterations);
        assert_eq!(s.trained(), o.trained());
        assert_eq!(s.peak_act_bytes, o.peak_act_bytes);
        assert_eq!(s.static_bytes, o.static_bytes);
        assert_eq!(s.chunk_mean_per_iteration.len(), 20);
        assert_eq!(
            s.peak_total_bytes,
            o.iterations.iter().map(|i| i.peak_total_bytes).max().unwrap()
        );
    }

    #[test]
    fn run_on_trace_rejects_mismatched_trace() {
        let mut base = paper_run(model_i(), Method::FullRecompute);
        base.iterations = 8;
        let mut probe = base.clone();
        probe.seed = 11;
        // trace too short for the run
        let mut short = probe.clone();
        short.iterations = 4;
        let trace = Simulator::new(short).unwrap().draw_trace();
        assert!(run_scenario_on_trace(&base, Method::FullRecompute, &trace).is_err());
        // trace drawn for a different model shape
        let mut other = paper_run(model_ii(), Method::FullRecompute);
        other.iterations = 8;
        other.seed = 11;
        let trace_ii = Simulator::new(other).unwrap().draw_trace();
        assert!(run_scenario_on_trace(&base, Method::FullRecompute, &trace_ii).is_err());
        // trace drawn under a different EP width (same layer counts —
        // the per-rank statistics still belong to the wrong topology)
        let mut narrow = probe.clone();
        narrow.parallel.ep = 16;
        let trace_ep = Simulator::new(narrow).unwrap().draw_trace();
        assert!(run_scenario_on_trace(&base, Method::FullRecompute, &trace_ep).is_err());
    }

    #[test]
    fn routing_trace_covers_moe_layers() {
        let o = outcome(model_i(), Method::FullRecompute);
        // 13 MoE layers × 20 iterations
        assert_eq!(o.routing.records.len(), 13 * 20);
        assert!(o.routing.peak_recv() > 0);
    }
}
