//! The theoretical memory cost model — paper §3 (Eq. 1–3, Table 2) and
//! the MACT token budget (Eq. 8).
//!
//! All quantities are **bytes on one GPU**. The model splits GPU memory
//! into *static* (weights + gradients + optimizer state, Eq. 1) and
//! *activated* (stored activations of the in-flight micro-batches,
//! Eq. 2 built from Table 2's per-module rows).
//!
//! The key structural fact the whole paper rests on: the activation
//! term has a dense part proportional to the local sequence length `s`
//! and a MoE part proportional to `s'`, the tokens *received* by this
//! rank's experts after all-to-all. Load imbalance can push
//! `s' → e·s·t_k` (every routed copy lands here), which overflows
//! memory even under full recomputation — and chunking divides exactly
//! that term by the chunk count (Eq. 6).

use crate::config::{ModelConfig, ParallelConfig, RunConfig};

/// Per-module stored activations of ONE transformer layer for ONE
/// micro-batch — the rows of Table 2, in bytes. `s` and `s_recv` (`s'`)
/// are token counts after any context/tensor-parallel split is applied
/// by the caller via [`ActivationModel`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerActivation {
    pub norm1: u64,
    pub qkv_in: u64,
    pub q: u64,
    pub k: u64,
    pub v: u64,
    pub attn_out: u64,
    pub norm2: u64,
    pub router_in: u64,
    pub router_logits: u64,
    pub expert_in: u64,
    pub expert_hidden: u64,
    pub score_mul: u64,
}

impl LayerActivation {
    /// Total stored bytes (the Table 2 "Total" row).
    pub fn total(&self) -> u64 {
        self.norm1
            + self.qkv_in
            + self.q
            + self.k
            + self.v
            + self.attn_out
            + self.norm2
            + self.router_in
            + self.router_logits
            + self.expert_in
            + self.expert_hidden
            + self.score_mul
    }

    /// The dense (∝ s) component.
    pub fn dense_part(&self) -> u64 {
        self.total() - self.moe_part()
    }

    /// The MoE (∝ s') component — what FCDA chunking divides.
    pub fn moe_part(&self) -> u64 {
        self.expert_in + self.expert_hidden + self.score_mul
    }
}

/// Evaluates the paper's activation formulas for a (model, parallel,
/// dtype) triple.
#[derive(Clone, Debug)]
pub struct ActivationModel {
    pub model: ModelConfig,
    pub parallel: ParallelConfig,
    /// Bytes per element (`D_t`).
    pub dtype_bytes: u64,
}

impl ActivationModel {
    pub fn new(run: &RunConfig) -> Self {
        ActivationModel {
            model: run.model.clone(),
            parallel: run.parallel.clone(),
            dtype_bytes: run.dtype_bytes,
        }
    }

    /// Table 2 rows for one layer, one micro-batch.
    ///
    /// `s_recv` is the number of token copies this rank's experts
    /// receive for this micro-batch (`s'` in the paper).
    pub fn layer(&self, s_recv: u64) -> LayerActivation {
        let m = &self.model;
        let p = &self.parallel;
        let tc = p.tp * p.cp;
        let dt = self.dtype_bytes;
        let b = p.micro_batch;
        let s = m.seq;
        let per = |elems: u64| dt * b * elems / tc;
        LayerActivation {
            norm1: per(s * m.hidden),
            qkv_in: per(s * m.hidden),
            q: per(s * m.heads * m.head_dim),
            k: per(s * m.kv_heads * m.head_dim),
            v: per(s * m.kv_heads * m.head_dim),
            attn_out: per(s * m.hidden),
            norm2: per(s * m.hidden),
            router_in: per(s * m.hidden),
            router_logits: per(s * m.n_experts),
            expert_in: per(s_recv * m.hidden),
            expert_hidden: per(2 * s_recv * m.ffn_expert),
            score_mul: per(s_recv * m.hidden),
        }
    }

    /// Eq. 2 closed form for one layer, one micro-batch:
    /// `D_t·b/(t·c) · [ s(5h + a·h_d + 2k_a·h_d + e_n) + s'(2h + 2g_e) ]`.
    pub fn layer_bytes(&self, s_recv: u64) -> u64 {
        self.layer(s_recv).total()
    }

    /// Only the dense term of Eq. 2 (∝ s).
    pub fn dense_bytes(&self) -> u64 {
        self.layer(0).total()
    }

    /// Per-received-token MoE bytes: `D_t·b·(2h + 2g_e)/(t·c)`.
    pub fn moe_bytes_per_token(&self) -> u64 {
        let m = &self.model;
        let p = &self.parallel;
        self.dtype_bytes * p.micro_batch * (2 * m.hidden + 2 * m.ffn_expert)
            / (p.tp * p.cp)
    }

    /// Peak activated memory (Eq. 2) on pipeline rank `pp_rank` when
    /// the hottest layer of the stage receives `s_recv` token copies
    /// and recomputation stores `m_g` micro-batch boundaries.
    ///
    /// `full_recompute = true` forces `m_g = 1` (the paper's note under
    /// Eq. 2); otherwise `m_g = vp + p − 2·r − 1`.
    pub fn peak_bytes(&self, pp_rank: u64, s_recv: u64, full_recompute: bool) -> u64 {
        let m_g = if full_recompute { 1 } else { self.parallel.m_g(pp_rank) };
        m_g * self.layer_bytes(s_recv)
    }

    /// Peak activation with FCDA chunking: the dense part is unchanged
    /// while the MoE part is bounded by the largest chunk
    /// (Eq. 6: `F(X) − max_i F(X_i)` is saved).
    pub fn peak_bytes_chunked(
        &self,
        pp_rank: u64,
        s_recv: u64,
        chunks: u64,
        full_recompute: bool,
    ) -> u64 {
        assert!(chunks >= 1);
        let m_g = if full_recompute { 1 } else { self.parallel.m_g(pp_rank) };
        let act = self.layer(s_recv.div_ceil(chunks));
        let dense = self.layer(0).total();
        m_g * (dense + act.moe_part())
    }

    /// Eq. 8: the largest `s'` a stage can host without violating
    /// Eq. 3, given the static memory and budget. Returns 0 when even
    /// the dense part overflows.
    pub fn s_prime_max(
        &self,
        pp_rank: u64,
        static_bytes: u64,
        budget_bytes: u64,
        full_recompute: bool,
    ) -> u64 {
        let m_g = if full_recompute { 1 } else { self.parallel.m_g(pp_rank) };
        let dense = m_g * self.dense_bytes();
        let per_token = m_g * self.moe_bytes_per_token();
        if budget_bytes <= static_bytes + dense || per_token == 0 {
            return 0;
        }
        (budget_bytes - static_bytes - dense) / per_token
    }

    /// Theoretical worst-case received tokens per rank per micro-batch:
    /// every routed copy of every EP peer's tokens lands on this rank
    /// (`s' → e·s·b·t_k`, the Fig. 2 "theoretical peak").
    pub fn s_prime_theoretical_peak(&self) -> u64 {
        self.parallel.ep * self.model.seq * self.parallel.micro_batch * self.model.top_k
    }
}

/// Static memory (Eq. 1): per-GPU bytes for weights (+grads+optimizer,
/// folded into `bytes_per_param`).
#[derive(Clone, Debug)]
pub struct StaticModel {
    pub model: ModelConfig,
    pub parallel: ParallelConfig,
    /// Combined bytes per parameter (weights + grads + optimizer).
    pub bytes_per_param: f64,
    /// Constant per-GPU overhead (CUDA context, NCCL, workspace).
    pub overhead_bytes: u64,
}

impl StaticModel {
    pub fn new(run: &RunConfig) -> Self {
        StaticModel {
            model: run.model.clone(),
            parallel: run.parallel.clone(),
            bytes_per_param: run.static_bytes_per_param,
            overhead_bytes: run.static_overhead_bytes,
        }
    }

    /// Parameters resident on one GPU of pipeline rank `pp_rank`
    /// (embedding on stage 0, LM head on the last stage, experts
    /// sharded over EP, attention/dense replicated inside the EP group
    /// but sharded over TP).
    pub fn params_on_rank(&self, pp_rank: u64) -> u64 {
        let m = &self.model;
        let p = &self.parallel;
        let stage_layers = p.layers_per_stage(m.layers);
        let first_layer = pp_rank * stage_layers;
        let mut params = 0u64;
        for layer in first_layer..(first_layer + stage_layers).min(m.layers) {
            params += m.attention_params() / p.tp;
            params += 2 * m.hidden; // norm gains
            if layer < m.dense_layers {
                params += m.dense_ffn_params() / p.tp;
            } else {
                params += m.router_params();
                let local_experts = m.n_experts / p.ep;
                params += m.expert_params_per_rank(local_experts);
            }
        }
        // Embedding (stage 0) and LM head (last stage). At d=1 their
        // optimizer state cannot live unsharded (129k×7168 ≈ 0.93 B
        // params ⇒ ~17 GB of fp32 Adam alone would sink every budget
        // in Table 4), so it is ZeRO-sharded across the EP group —
        // the only replicated group available in the paper's layout.
        if pp_rank == 0 {
            params += m.vocab * m.hidden / (p.tp * p.ep);
        }
        if pp_rank == p.pp - 1 {
            params += m.vocab * m.hidden / (p.tp * p.ep);
        }
        params
    }

    /// Eq. 1: static bytes on the given rank (parameter-derived state
    /// plus the constant framework overhead).
    pub fn bytes_on_rank(&self, pp_rank: u64) -> u64 {
        (self.params_on_rank(pp_rank) as f64 * self.bytes_per_param) as u64
            + self.overhead_bytes
    }

    /// The stage with the largest static footprint (embedding stage,
    /// usually rank 0).
    pub fn max_bytes(&self) -> u64 {
        (0..self.parallel.pp)
            .map(|r| self.bytes_on_rank(r))
            .max()
            .unwrap_or(0)
    }
}

/// Eq. 3 feasibility: can the run fit on every stage at the given
/// worst-case `s'`?
pub fn fits(
    run: &RunConfig,
    s_recv_worst: u64,
    chunks: u64,
    full_recompute: bool,
) -> bool {
    let act = ActivationModel::new(run);
    let sta = StaticModel::new(run);
    let budget = (run.alpha * run.gpu_mem_bytes as f64) as u64;
    (0..run.parallel.pp).all(|r| {
        let a = if chunks <= 1 {
            act.peak_bytes(r, s_recv_worst, full_recompute)
        } else {
            act.peak_bytes_chunked(r, s_recv_worst, chunks, full_recompute)
        };
        sta.bytes_on_rank(r) + a <= budget
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, paper_run, Method, GB};

    fn run() -> RunConfig {
        paper_run(model_i(), Method::FullRecompute)
    }

    #[test]
    fn table2_total_matches_closed_form() {
        // Eq. 2 closed form: D_t·b/(tc)·[s(5h + a·h_d + 2k_a·h_d + e_n)
        //                               + s'(2h + 2g_e)]
        let r = run();
        let am = ActivationModel::new(&r);
        let m = &r.model;
        let s_recv = 100_000u64;
        let want = r.dtype_bytes
            * r.parallel.micro_batch
            * (m.seq * (5 * m.hidden + m.heads * m.head_dim + 2 * m.kv_heads * m.head_dim + m.n_experts)
                + s_recv * (2 * m.hidden + 2 * m.ffn_expert))
            / (r.parallel.tp * r.parallel.cp);
        assert_eq!(am.layer_bytes(s_recv), want);
    }

    #[test]
    fn moe_part_is_linear_in_s_recv() {
        let am = ActivationModel::new(&run());
        let a = am.layer(1000).moe_part();
        let b = am.layer(2000).moe_part();
        assert_eq!(b, 2 * a);
        assert_eq!(am.layer(0).moe_part(), 0);
    }

    #[test]
    fn dense_part_independent_of_s_recv() {
        let am = ActivationModel::new(&run());
        assert_eq!(am.layer(0).dense_part(), am.layer(123_456).dense_part());
    }

    #[test]
    fn chunking_divides_moe_part_only() {
        let am = ActivationModel::new(&run());
        let s_recv = 131_072;
        let full = am.peak_bytes(0, s_recv, true);
        let c2 = am.peak_bytes_chunked(0, s_recv, 2, true);
        let c8 = am.peak_bytes_chunked(0, s_recv, 8, true);
        let dense = am.dense_bytes();
        assert_eq!(full - dense, (c2 - dense) * 2);
        assert_eq!(full - dense, (c8 - dense) * 8);
        assert!(c8 < c2 && c2 < full);
    }

    #[test]
    fn chunk_of_one_equals_unchunked() {
        let am = ActivationModel::new(&run());
        assert_eq!(
            am.peak_bytes(2, 50_000, true),
            am.peak_bytes_chunked(2, 50_000, 1, true)
        );
    }

    #[test]
    fn full_recompute_sets_mg_one() {
        let am = ActivationModel::new(&run());
        let no_rc = am.peak_bytes(0, 10_000, false);
        let rc = am.peak_bytes(0, 10_000, true);
        // stage 0 of p=4,v=1 has m_g = 7
        assert_eq!(no_rc, 7 * rc);
    }

    #[test]
    fn s_prime_max_inverts_peak() {
        // peak(s'_max) must fit the budget; peak(s'_max + slack) must not.
        let r = run();
        let am = ActivationModel::new(&r);
        let sta = StaticModel::new(&r);
        let budget = (r.alpha * r.gpu_mem_bytes as f64) as u64;
        for rank in 0..4 {
            let s_max = am.s_prime_max(rank, sta.bytes_on_rank(rank), budget, true);
            assert!(s_max > 0, "rank {rank} has no token budget at all");
            let used = sta.bytes_on_rank(rank) + am.peak_bytes(rank, s_max, true);
            assert!(used <= budget, "rank {rank}: {used} > {budget}");
            let over = sta.bytes_on_rank(rank) + am.peak_bytes(rank, s_max + 2, true);
            assert!(over > budget, "rank {rank}: s'_max not tight");
        }
    }

    #[test]
    fn s_prime_max_zero_when_static_overflows() {
        let mut r = run();
        r.gpu_mem_bytes = 1 * GB;
        let am = ActivationModel::new(&r);
        let sta = StaticModel::new(&r);
        let budget = (r.alpha * r.gpu_mem_bytes as f64) as u64;
        assert_eq!(am.s_prime_max(0, sta.bytes_on_rank(0), budget, true), 0);
    }

    #[test]
    fn theoretical_peak_matches_fig2() {
        // e=32, s=4096, b=1, t_k=8 → 1,048,576 token copies
        let am = ActivationModel::new(&run());
        assert_eq!(am.s_prime_theoretical_peak(), 32 * 4096 * 8);
    }

    #[test]
    fn static_memory_stage0_largest() {
        let sta = StaticModel::new(&run());
        let s0 = sta.bytes_on_rank(0);
        let s1 = sta.bytes_on_rank(1);
        assert!(s0 > s1, "embedding stage should dominate: {s0} vs {s1}");
        assert_eq!(sta.max_bytes(), s0.max(sta.bytes_on_rank(3)));
    }

    #[test]
    fn static_memory_model_ii_smaller() {
        use crate::config::model_ii;
        let a = StaticModel::new(&paper_run(model_i(), Method::FullRecompute));
        let b = StaticModel::new(&paper_run(model_ii(), Method::FullRecompute));
        assert!(b.max_bytes() < a.max_bytes());
    }

    #[test]
    fn static_in_paper_ballpark() {
        // Table 4 reports 43.0 GB (Model I) / 39.5 GB (Model II). Our
        // inventory with 6 B/param should land within ~35% — the paper
        // does not disclose its optimizer sharding exactly.
        let sta = StaticModel::new(&run());
        let gb = sta.max_bytes() as f64 / GB as f64;
        assert!(gb > 25.0 && gb < 60.0, "static {gb:.1} GB out of band");
    }

    #[test]
    fn fits_detects_oom_at_extreme_imbalance() {
        let r = run();
        // Balanced routing fits...
        let balanced = r.model.seq * r.model.top_k; // s' ≈ s·t_k/e·e = s·t_k
        assert!(fits(&r, balanced, 1, true));
        // ...but the theoretical worst case does not (Model I, Method 1 OOM).
        let worst = ActivationModel::new(&r).s_prime_theoretical_peak();
        assert!(!fits(&r, worst, 1, true));
        // Chunking by 8 rescues it (Method 2 trains).
        assert!(fits(&r, worst, 8, true));
    }
}
