//! Small shared utilities: deterministic RNG, statistics, formatting.
//!
//! The whole simulator is deterministic given a seed — every stochastic
//! component draws from [`rng::Rng`] (splitmix64-seeded xoshiro256**),
//! so table/figure benches are exactly reproducible run to run.

pub mod rng;
pub mod stats;

/// Format a byte count using binary units (GiB shown as "GB" to match
/// the paper's tables).
pub fn fmt_bytes(bytes: u64) -> String {
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    const MB: f64 = 1024.0 * 1024.0;
    let b = bytes as f64;
    if b >= GB {
        format!("{:.1} GB", b / GB)
    } else if b >= MB {
        format!("{:.1} MB", b / MB)
    } else {
        format!("{} B", bytes)
    }
}

/// Integer ceiling division, the `⌈a/b⌉` of paper Eq. 9.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// FNV-1a 64 offset basis — the initial state of the streaming form.
pub const FNV1A_OFFSET: u64 = 0xcbf29ce484222325;

/// Streaming FNV-1a 64 step: fold `bytes` into the running state `h`.
/// `fnv1a_64(x)` ≡ `fnv1a_64_update(FNV1A_OFFSET, x)`, and hashing a
/// concatenation equals chaining updates — which is what lets the
/// checkpoint layer hash a cell's invariant JSON prefix once and
/// re-hash only the per-method middle (see
/// `sweep::checkpoint::CellHasher`).
pub fn fnv1a_64_update(mut h: u64, bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// FNV-1a 64-bit hash — the checkpoint layer's content hash over
/// canonical scenario JSON. Not cryptographic; chosen because it is
/// tiny, dependency-free, and stable across platforms/versions (the
/// std `Hasher` is explicitly not stable), which is what a resumable
/// artifact format needs.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    fnv1a_64_update(FNV1A_OFFSET, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MB");
        assert_eq!(fmt_bytes(64 * 1024 * 1024 * 1024), "64.0 GB");
    }

    #[test]
    fn ceil_div_matches_eq9() {
        assert_eq!(ceil_div(10, 5), 2);
        assert_eq!(ceil_div(11, 5), 3);
        assert_eq!(ceil_div(0, 5), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    #[should_panic]
    fn ceil_div_zero_denominator_panics() {
        ceil_div(1, 0);
    }

    #[test]
    fn fnv1a_64_known_vectors() {
        // Published FNV-1a test vectors: the empty string hashes to the
        // offset basis; "a" and "foobar" are from the reference table.
        assert_eq!(fnv1a_64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv1a_64_sensitivity() {
        assert_ne!(fnv1a_64(b"scenario-1"), fnv1a_64(b"scenario-2"));
        assert_ne!(fnv1a_64(b"ab"), fnv1a_64(b"ba"));
    }

    #[test]
    fn fnv1a_64_streaming_equals_whole() {
        // concatenation ≡ chained updates, at every split point
        let data = b"the quick brown fox jumps over the lazy dog";
        let whole = fnv1a_64(data);
        for split in 0..=data.len() {
            let h = fnv1a_64_update(FNV1A_OFFSET, &data[..split]);
            assert_eq!(fnv1a_64_update(h, &data[split..]), whole, "split {split}");
        }
    }
}
