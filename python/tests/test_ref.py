"""Invariants of the reference MoE pipeline (dispatch/combine/chunking).

These mirror the rust `dispatch` and `chunk` property tests: the same
invariants hold on both sides of the language boundary.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def _setup(seed, t=32, h=16, e=4, k=2):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (t, h))
    wg = jax.random.normal(ks[1], (h, e))
    w1 = jax.random.normal(ks[2], (e, h, 3 * h)) * 0.2
    w3 = jax.random.normal(ks[3], (e, h, 3 * h)) * 0.2
    w2 = jax.random.normal(ks[4], (e, 3 * h, h)) * 0.2
    return x, wg, w1, w3, w2


class TestDispatchCombine:
    def test_dropfree_capacity_no_overflow(self):
        x, wg, *_ = _setup(0)
        _, idx = ref.router_topk_ref(x, wg, 2)
        gathered, mask, pos = ref.dispatch_ref(x, idx, 4, capacity=64)
        assert np.all(np.asarray(pos) >= 0), "drop-free capacity must not drop"

    def test_mask_count_equals_routed_copies(self):
        x, wg, *_ = _setup(1)
        _, idx = ref.router_topk_ref(x, wg, 2)
        _, mask, _ = ref.dispatch_ref(x, idx, 4, capacity=64)
        assert float(np.sum(np.asarray(mask))) == x.shape[0] * 2

    def test_gathered_rows_are_token_rows(self):
        x, wg, *_ = _setup(2)
        _, idx = ref.router_topk_ref(x, wg, 2)
        gathered, mask, pos = ref.dispatch_ref(x, idx, 4, capacity=64)
        g = np.asarray(gathered).reshape(-1, x.shape[1])
        p = np.asarray(pos)
        xn = np.asarray(x)
        for tok in range(x.shape[0]):
            for k in range(2):
                np.testing.assert_allclose(g[p[tok, k]], xn[tok], rtol=1e-6)

    def test_identity_expert_roundtrip(self):
        """With identity-like experts (output == input via large linear
        identity emulation is impossible with SwiGLU), use combine over
        the gathered tokens directly: combine(dispatch(x)) with weights
        renormalised must reconstruct a convex mix of x rows — for top-1
        routing it must be exactly x."""
        x, wg, *_ = _setup(3)
        w, idx = ref.router_topk_ref(x, wg, 1)
        gathered, mask, pos = ref.dispatch_ref(x, idx, 4, capacity=32)
        out = ref.combine_ref(gathered, pos, w)
        np.testing.assert_allclose(out, x, rtol=1e-5, atol=1e-6)

    def test_small_capacity_drops_surface_as_negative_pos(self):
        x, wg, *_ = _setup(4)
        _, idx = ref.router_topk_ref(x, wg, 2)
        _, mask, pos = ref.dispatch_ref(x, idx, 4, capacity=2)
        assert np.any(np.asarray(pos) < 0)
        assert float(np.sum(np.asarray(mask))) <= 4 * 2

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), k=st.integers(1, 3),
           e=st.sampled_from([2, 4, 8]))
    def test_hypothesis_conservation(self, seed, k, e):
        t, h = 16, 8
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        x = jax.random.normal(ks[0], (t, h))
        wg = jax.random.normal(ks[1], (h, e))
        kk = min(k, e)
        _, idx = ref.router_topk_ref(x, wg, kk)
        _, mask, pos = ref.dispatch_ref(x, idx, e, capacity=t * kk)
        assert float(np.sum(np.asarray(mask))) == t * kk
        assert np.all(np.asarray(pos) >= 0)
        # slots unique
        p = np.asarray(pos).reshape(-1)
        assert len(set(p.tolist())) == p.size


class TestChunkedEquivalence:
    """FCDA's core semantic claim (Eq. 6): chunking is invisible."""

    def test_chunked_equals_unchunked(self):
        x, wg, w1, w3, w2 = _setup(5)
        full = ref.moe_layer_ref(x, wg, w1, w3, w2, top_k=2)
        for c in (1, 2, 4):
            chunked = ref.moe_layer_chunked_ref(x, wg, w1, w3, w2, 2, c)
            np.testing.assert_allclose(chunked, full, rtol=1e-4, atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), c=st.sampled_from([1, 2, 4, 8]))
    def test_hypothesis_chunk_sweep(self, seed, c):
        x, wg, w1, w3, w2 = _setup(seed)
        full = ref.moe_layer_ref(x, wg, w1, w3, w2, top_k=2)
        chunked = ref.moe_layer_chunked_ref(x, wg, w1, w3, w2, 2, c)
        np.testing.assert_allclose(chunked, full, rtol=1e-4, atol=1e-5)

    def test_peak_buffer_shrinks_with_chunks(self):
        """The memory claim behind Eq. 6: per-chunk drop-free capacity is
        T·k/c, so the gathered buffer shrinks linearly in c."""
        t, k = 32, 2
        for c in (1, 2, 4):
            cap = t * k // c
            assert cap * c == t * k
