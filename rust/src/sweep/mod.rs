//! Parallel scenario-sweep engine — the substrate behind every
//! table/figure grid in the reproduction.
//!
//! A sweep is the cross product `models × methods × seeds` from a
//! [`SweepConfig`], expanded into ordered [`grid::Scenario`]s, fanned
//! out over a std-thread worker pool ([`pool`], ppl-style: shared
//! injector + index-tagged result channel), executed through the pure
//! [`crate::sim::run_scenario`] path, and reduced into a
//! [`report::SweepReport`] (per-cell avg TGS, OOM rates, activation
//! peaks, memory-model deltas) with deterministic JSON output.
//!
//! **Determinism contract:** the report — including its serialised
//! bytes — depends only on the `SweepConfig`. Worker count and thread
//! scheduling cannot perturb it, because
//!
//! 1. every scenario derives its RNG streams purely from its own
//!    config/seed (no shared mutable state, nothing drawn from a
//!    global generator at execution time);
//! 2. results are keyed by grid index and re-sorted before reduction,
//!    so floats accumulate in one fixed order;
//! 3. JSON objects serialise with sorted keys.
//!
//! `tests/integration_sweep.rs` pins this: a 24-scenario grid run with
//! 1 worker and 8 workers must emit bit-identical JSON.

pub mod grid;
pub mod pool;
pub mod report;

pub use grid::{expand, Scenario};
pub use pool::parallel_map_indexed;
pub use report::{CellStats, ScenarioResult, SweepReport};

use crate::config::SweepConfig;
use crate::error::Result;
use crate::sim;

/// Default worker count: the machine's parallelism, capped so a small
/// grid doesn't spawn idle threads.
pub fn default_workers(scenarios: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1);
    hw.min(scenarios.max(1))
}

/// Run the full sweep on `workers` threads and reduce the results.
pub fn run_sweep(cfg: &SweepConfig, workers: usize) -> Result<SweepReport> {
    let scenarios = grid::expand(cfg)?;
    let outcomes = pool::parallel_map_indexed(scenarios, workers, |_, sc| {
        // Scenario carries (method, seed) both as report labels and
        // pre-applied in `run`; the explicit arguments below are the
        // authoritative pair (run_scenario re-applies them), and this
        // assert keeps the label copies from ever drifting.
        debug_assert!(sc.run.method == sc.method && sc.run.seed == sc.seed);
        let out = sim::run_scenario(&sc.run, sc.method.clone(), sc.seed);
        (sc, out)
    });
    let mut results = Vec::with_capacity(outcomes.len());
    for (sc, out) in outcomes {
        results.push(ScenarioResult::new(&sc, &out?));
    }
    Ok(SweepReport::build(cfg.clone(), results))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    /// A small single-model grid whose 10 iterations cover the
    /// early-training chaos window (peak ~iteration 8), so the MACT
    /// cell demonstrably chunks and Method 1 demonstrably peaks.
    fn tiny_grid() -> SweepConfig {
        SweepConfig {
            models: vec!["i".into()],
            methods: vec![Method::FullRecompute, Method::Mact(vec![1, 2, 4, 8])],
            seeds: vec![7, 8],
            iterations: 10,
        }
    }

    #[test]
    fn sweep_runs_and_aggregates() {
        let report = run_sweep(&tiny_grid(), 2).unwrap();
        assert_eq!(report.scenarios.len(), 4);
        assert_eq!(report.cells.len(), 2);
        // MACT cell must report a positive activation reduction vs m1
        let mact = &report.cells[1];
        assert!(mact.act_reduction_vs_m1_pct.unwrap() > 0.0);
        // every scenario row carries real simulation output
        assert!(report.scenarios.iter().all(|s| s.peak_act_bytes > 0));
        assert!(report.scenarios.iter().all(|s| s.iterations == 10));
    }

    #[test]
    fn worker_count_does_not_change_the_report() {
        let a = run_sweep(&tiny_grid(), 1).unwrap();
        let b = run_sweep(&tiny_grid(), 4).unwrap();
        assert_eq!(a.scenarios, b.scenarios);
        assert_eq!(a.cells, b.cells);
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
    }

    #[test]
    fn default_workers_bounded() {
        assert!(default_workers(1) >= 1);
        assert!(default_workers(4) <= 4);
        assert!(default_workers(0) >= 1);
    }
}
