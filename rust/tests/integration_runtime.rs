//! Integration tests over the PJRT runtime + coordinator: real HLO
//! executables (built by `make artifacts`) driven from rust, verified
//! against pure-rust math.
//!
//! These tests are skipped (with a loud message) when artifacts/ is
//! absent; `make test` always builds artifacts first.

use memfine::coordinator::ep::{
    native_reference, ChunkPolicy, EpCoordinator, EpTopology,
};
use memfine::coordinator::train::TrainDriver;
use memfine::runtime::{ArtifactStore, HostTensor};

const DIR: &str = "artifacts";

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open(DIR) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn manifest_entries_complete() {
    let Some(store) = store() else { return };
    for name in ["train_step", "fwd_loss", "router_topk"] {
        assert!(store.entries.contains_key(name), "missing {name}");
    }
    for bin in [1u64, 2, 4, 8] {
        let e = &store.entries[&format!("expert_ffn_c{bin}")];
        assert_eq!(e.chunk_bin, Some(bin));
        // capacities halve as bins double (Eq. 6 linear memory scaling)
        assert_eq!(
            e.capacity.unwrap(),
            store.entries["expert_ffn_c1"].capacity.unwrap() / bin
        );
    }
}

#[test]
fn initial_params_match_manifest() {
    let Some(store) = store() else { return };
    let params = store.initial_params().unwrap();
    assert_eq!(params.len(), store.param_count);
    assert!(params.iter().all(|p| p.is_finite()));
    // norm gains are initialised to exactly 1.0 somewhere in the vector
    assert!(params.iter().any(|&p| p == 1.0));
}

#[test]
fn router_executable_matches_native_softmax_topk() {
    let Some(store) = store() else { return };
    let topo = EpTopology::from_manifest(&store.manifest).unwrap();
    let x = memfine::coordinator::ep::rank_tokens(&topo, 3, 0);
    let gate = memfine::coordinator::ep::gate_weights(&topo, 3);
    let out = store
        .execute(
            "router_topk",
            &[HostTensor::F32(x.clone()), HostTensor::F32(gate.clone())],
        )
        .unwrap();
    let weights = out[0].as_f32().unwrap();
    let indices = out[1].as_i32().unwrap();
    assert_eq!(weights.len(), topo.tokens_per_rank * topo.top_k);
    // weights renormalised per token
    for t in 0..topo.tokens_per_rank {
        let s: f32 = weights[t * topo.top_k..(t + 1) * topo.top_k].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "token {t}: weights sum {s}");
        // indices distinct and in range
        let idx = &indices[t * topo.top_k..(t + 1) * topo.top_k];
        assert!(idx.iter().all(|&i| (i as usize) < topo.global_experts()));
        assert_ne!(idx[0], idx[1]);
    }
}

#[test]
fn expert_executable_zero_mask_zero_output() {
    let Some(store) = store() else { return };
    let topo = EpTopology::from_manifest(&store.manifest).unwrap();
    let cap = topo.capacity(8) as usize;
    let e = topo.local_experts;
    let h = topo.hidden;
    let g = topo.ffn;
    let out = store
        .execute(
            "expert_ffn_c8",
            &[
                HostTensor::F32(vec![1.0; e * cap * h]),
                HostTensor::F32(vec![0.1; e * h * g]),
                HostTensor::F32(vec![0.1; e * h * g]),
                HostTensor::F32(vec![0.1; e * g * h]),
                HostTensor::F32(vec![0.0; e * cap]), // all padding
            ],
        )
        .unwrap();
    assert!(out[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
}

#[test]
fn ep_coordinator_matches_native_reference() {
    let Some(_) = store() else { return };
    let coord = EpCoordinator::new(DIR, ChunkPolicy::Fixed(4), 5).unwrap();
    let result = coord.run_layer().unwrap();
    let reference = native_reference(&coord.topo, 5);
    let mut worst = 0f32;
    for (rank, (got, want)) in result.outputs.iter().zip(&reference).enumerate() {
        assert_eq!(got.len(), want.len(), "rank {rank} length");
        for (a, b) in got.iter().zip(want) {
            worst = worst.max((a - b).abs());
        }
    }
    assert!(worst < 2e-3, "coordinator vs native reference: max |Δ| = {worst}");
    // conservation: total received copies == ep · tokens · top_k
    let total: u64 = result.received.iter().sum();
    assert_eq!(total, coord.topo.total_copies());
}

#[test]
fn ep_coordinator_chunk_invariance() {
    // FCDA's semantic claim on the REAL pipeline: the chunk bin must
    // not change the combined outputs (Eq. 6).
    let Some(_) = store() else { return };
    let a = EpCoordinator::new(DIR, ChunkPolicy::Fixed(1), 9)
        .unwrap()
        .run_layer()
        .unwrap();
    let b = EpCoordinator::new(DIR, ChunkPolicy::Fixed(8), 9)
        .unwrap()
        .run_layer()
        .unwrap();
    let mut worst = 0f32;
    for (x, y) in a.outputs.iter().zip(&b.outputs) {
        for (u, v) in x.iter().zip(y) {
            worst = worst.max((u - v).abs());
        }
    }
    assert!(worst < 2e-3, "chunk bins diverge: {worst}");
    // and the memory accounting shrinks with the bin (Eq. 6)
    let peak1 = a.peak_bytes.iter().max().unwrap();
    let peak8 = b.peak_bytes.iter().max().unwrap();
    assert!(
        *peak8 < *peak1,
        "c=8 peak {peak8} not below c=1 peak {peak1}"
    );
    assert_eq!(a.decision.capacity, 8 * b.decision.capacity);
}

#[test]
fn ep_coordinator_mact_policy_respects_budget() {
    let Some(_) = store() else { return };
    // 20 MB budget: c=1 (67 MB) and c=2 (34 MB) don't fit, c=4 (17 MB) does.
    let coord = EpCoordinator::new(
        DIR,
        ChunkPolicy::Mact { budget_bytes: 20 << 20 },
        11,
    )
    .unwrap();
    let d = coord.decide().unwrap();
    assert_eq!(d.chunk_bin, 4, "{d:?}");
    assert!(d.buffer_bytes <= 20 << 20);
    let result = coord.run_layer().unwrap();
    for (rank, &peak) in result.peak_bytes.iter().enumerate() {
        assert!(peak <= 20 << 20, "rank {rank} exceeded budget: {peak}");
    }
}

#[test]
fn ep_coordinator_fixed_oversize_bin_ooms() {
    // A fixed c=1 bin with a tiny tracker capacity must surface
    // Error::Oom from the worker's MemoryTracker — the Table 4
    // Method-1-style failure, reproduced on the real pipeline.
    let Some(_) = store() else { return };
    let mut coord = EpCoordinator::new(DIR, ChunkPolicy::Fixed(1), 13).unwrap();
    coord.rank_capacity_bytes = 32 << 20; // < 67 MB c=1 buffers
    match coord.run_layer() {
        Err(memfine::Error::Oom { .. }) => {}
        other => panic!("expected OOM, got {other:?}"),
    }
}

#[test]
fn train_driver_two_steps_learns_something() {
    let Some(store) = store() else { return };
    let driver = TrainDriver::new(store).unwrap();
    let mut losses = Vec::new();
    let report = driver
        .train(2, 42, |log| losses.push(log.loss))
        .unwrap();
    assert_eq!(losses.len(), 2);
    assert!(losses.iter().all(|l| l.is_finite()));
    // initial loss ≈ ln(vocab) = ln(8192) ≈ 9.0; step 2 must not blow up
    assert!(report.first_loss > 7.0 && report.first_loss < 11.0);
    assert!(report.final_loss < report.first_loss + 0.5);
}
