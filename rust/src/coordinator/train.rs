//! End-to-end train driver: rust loop over the AOT `train_step`
//! executable. Python is not involved — the artifacts are loaded and
//! executed through PJRT directly.

use crate::data::Corpus;
use crate::error::Result;
use crate::json::Value;
use crate::metrics::Timer;
use crate::runtime::{ArtifactStore, HostTensor};

/// One logged training step.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: u64,
    pub loss: f32,
    pub step_s: f64,
    /// Tokens per second over this step (single simulated GPU).
    pub tgs: f64,
}

/// Summary of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: Vec<StepLog>,
    pub first_loss: f32,
    pub final_loss: f32,
    pub mean_tgs: f64,
    pub total_s: f64,
    /// Execution telemetry: a `stage.step_ns` histogram over the
    /// per-step wall times (mergeable across runs, see
    /// [`crate::obs::Histogram`]).
    pub metrics: crate::metrics::Registry,
}

impl TrainReport {
    /// Smoothed final loss (mean of the last k steps).
    pub fn tail_loss(&self, k: usize) -> f32 {
        let n = self.steps.len();
        let k = k.min(n).max(1);
        self.steps[n - k..].iter().map(|s| s.loss).sum::<f32>() / k as f32
    }
}

/// The driver.
pub struct TrainDriver {
    store: ArtifactStore,
    batch: usize,
    seq: usize,
}

impl TrainDriver {
    pub fn new(store: ArtifactStore) -> Result<Self> {
        let cfg = &store.config;
        let batch = cfg
            .get("batch")
            .and_then(Value::as_u64)
            .ok_or_else(|| crate::Error::artifact("manifest config missing batch"))?
            as usize;
        let seq = cfg
            .get("seq")
            .and_then(Value::as_u64)
            .ok_or_else(|| crate::Error::artifact("manifest config missing seq"))?
            as usize;
        Ok(TrainDriver { store, batch, seq })
    }

    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq
    }

    /// Run `steps` optimisation steps on the synthetic corpus; calls
    /// `on_step` after each (for live logging).
    pub fn train(
        &self,
        steps: u64,
        data_seed: u64,
        mut on_step: impl FnMut(&StepLog),
    ) -> Result<TrainReport> {
        let mut params = self.store.initial_params()?;
        let n = params.len();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let mut corpus = Corpus::new(
            self.store
                .config
                .get("vocab")
                .and_then(Value::as_u64)
                .unwrap_or(8192) as u32,
            data_seed,
        );
        let total = Timer::start();
        let mut metrics = crate::metrics::Registry::new();
        let mut logs = Vec::with_capacity(steps as usize);
        let mut first_loss = f32::NAN;
        for step in 1..=steps {
            let tokens = corpus.batch(self.batch, self.seq);
            let t = Timer::start();
            let outputs = self.store.execute(
                "train_step",
                &[
                    HostTensor::F32(std::mem::take(&mut params)),
                    HostTensor::F32(std::mem::take(&mut m)),
                    HostTensor::F32(std::mem::take(&mut v)),
                    HostTensor::I32(tokens),
                    HostTensor::F32(vec![step as f32]),
                ],
            )?;
            let step_s = t.elapsed_s();
            metrics.observe("stage.step_ns", (step_s * 1e9) as u64);
            let mut it = outputs.into_iter();
            params = match it.next() {
                Some(HostTensor::F32(p)) => p,
                _ => return Err(crate::Error::runtime("train_step output 0 not f32")),
            };
            m = match it.next() {
                Some(HostTensor::F32(p)) => p,
                _ => return Err(crate::Error::runtime("train_step output 1 not f32")),
            };
            v = match it.next() {
                Some(HostTensor::F32(p)) => p,
                _ => return Err(crate::Error::runtime("train_step output 2 not f32")),
            };
            let loss = it
                .next()
                .ok_or_else(|| crate::Error::runtime("missing loss output"))?
                .scalar_f32()?;
            if step == 1 {
                first_loss = loss;
            }
            let log = StepLog {
                step,
                loss,
                step_s,
                tgs: self.tokens_per_step() as f64 / step_s,
            };
            on_step(&log);
            logs.push(log);
        }
        let total_s = total.elapsed_s();
        let final_loss = logs.last().map(|l| l.loss).unwrap_or(f32::NAN);
        let mean_tgs = if logs.is_empty() {
            0.0
        } else {
            logs.iter().map(|l| l.tgs).sum::<f64>() / logs.len() as f64
        };
        Ok(TrainReport {
            steps: logs,
            first_loss,
            final_loss,
            mean_tgs,
            total_s,
            metrics,
        })
    }

    /// Evaluate the loss of the given parameters on a fixed batch.
    pub fn eval(&self, params: Vec<f32>, data_seed: u64) -> Result<f32> {
        let mut corpus = Corpus::new(
            self.store
                .config
                .get("vocab")
                .and_then(Value::as_u64)
                .unwrap_or(8192) as u32,
            data_seed,
        );
        let tokens = corpus.batch(self.batch, self.seq);
        let out = self.store.execute(
            "fwd_loss",
            &[HostTensor::F32(params), HostTensor::I32(tokens)],
        )?;
        out[0].scalar_f32()
    }
}
