//! Metrics: counters, timers, and CSV emission for traces and benches.
//!
//! Deliberately simple — a `Registry` of named counters/gauges plus a
//! `CsvWriter` with schema checking. Everything the benches print comes
//! through here so output formats stay consistent across tables.

use std::collections::BTreeMap;
use std::io::Write;
use std::time::Instant;

use crate::error::{Error, Result};

/// A monotonically increasing counter.
#[derive(Clone, Debug, Default)]
pub struct Counter(u64);

impl Counter {
    pub fn inc(&mut self) {
        self.0 += 1;
    }
    pub fn add(&mut self, n: u64) {
        self.0 += n;
    }
    pub fn get(&self) -> u64 {
        self.0
    }
}

/// Named metrics registry (single-threaded by design: each worker owns
/// one and the coordinator merges).
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn count(&mut self, name: &str, n: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += n;
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Merge another registry (summing counters, last-writer gauges).
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
    }

    /// Render as a JSON object (sorted keys — stable for goldens).
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::{num, Value};
        let mut obj = std::collections::BTreeMap::new();
        for (k, v) in &self.counters {
            obj.insert(format!("counter.{k}"), num(*v as f64));
        }
        for (k, v) in &self.gauges {
            obj.insert(format!("gauge.{k}"), num(*v));
        }
        Value::Obj(obj)
    }
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// CSV writer with header schema enforcement.
pub struct CsvWriter<W: Write> {
    out: W,
    columns: usize,
}

impl<W: Write> CsvWriter<W> {
    pub fn new(mut out: W, header: &[&str]) -> Result<Self> {
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        if cells.len() != self.columns {
            return Err(Error::schedule(format!(
                "csv row has {} cells, header has {}",
                cells.len(),
                self.columns
            )));
        }
        writeln!(self.out, "{}", cells.join(","))?;
        Ok(())
    }

    pub fn into_inner(self) -> W {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let mut c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn registry_counts_and_gauges() {
        let mut r = Registry::new();
        r.count("tokens", 10);
        r.count("tokens", 5);
        r.gauge("loss", 3.5);
        assert_eq!(r.counter("tokens"), 15);
        assert_eq!(r.gauge_value("loss"), Some(3.5));
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn registry_merge() {
        let mut a = Registry::new();
        a.count("x", 1);
        a.gauge("g", 1.0);
        let mut b = Registry::new();
        b.count("x", 2);
        b.gauge("g", 2.0);
        a.merge(&b);
        assert_eq!(a.counter("x"), 3);
        assert_eq!(a.gauge_value("g"), Some(2.0));
    }

    #[test]
    fn registry_json_stable() {
        let mut r = Registry::new();
        r.count("b", 1);
        r.count("a", 2);
        let j = r.to_json().to_string_compact();
        assert!(j.find("counter.a").unwrap() < j.find("counter.b").unwrap());
    }

    #[test]
    fn csv_schema_enforced() {
        let mut w = CsvWriter::new(Vec::new(), &["a", "b"]).unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        let bytes = w.into_inner();
        assert_eq!(String::from_utf8(bytes).unwrap(), "a,b\n1,2\n");
    }

    #[test]
    fn timer_progresses() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_s() > 0.0);
    }
}
