//! End-to-end system proof (DESIGN.md experiment E6): train the
//! AOT-compiled MoE transformer from rust for a few hundred steps on
//! the synthetic corpus and log the loss curve.
//!
//! This exercises all three layers: the Pallas expert kernel (L1) is
//! inside the jax-lowered `train_step` HLO (L2), which this rust driver
//! (L3) loads and executes through PJRT — python is not running.
//!
//! Usage: `cargo run --release --example train_moe -- [steps] [csv-out]`
//! Defaults: 200 steps, loss curve written to train_loss.csv.

use std::io::Write;

use memfine::coordinator::train::TrainDriver;
use memfine::runtime::ArtifactStore;

fn main() -> memfine::Result<()> {
    memfine::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let csv_path = args.get(1).cloned().unwrap_or_else(|| "train_loss.csv".into());
    let artifacts = std::env::var("MEMFINE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    let store = ArtifactStore::open(&artifacts)?;
    println!(
        "model: {} params | batch tokens: {}",
        store.param_count,
        store.config.get("batch").and_then(memfine::json::Value::as_u64).unwrap_or(0)
            * store.config.get("seq").and_then(memfine::json::Value::as_u64).unwrap_or(0),
    );
    let driver = TrainDriver::new(store)?;

    let mut csv = std::fs::File::create(&csv_path)?;
    writeln!(csv, "step,loss,step_seconds,tokens_per_second")?;
    let report = driver.train(steps, 7, |log| {
        let _ = writeln!(
            csv,
            "{},{:.6},{:.4},{:.1}",
            log.step, log.loss, log.step_s, log.tgs
        );
        if log.step == 1 || log.step % 10 == 0 {
            println!(
                "step {:>4}/{steps}  loss {:.4}  {:.2}s/step  tokens/s {:.0}",
                log.step, log.loss, log.step_s, log.tgs
            );
        }
    })?;

    println!("\n=== E2E training summary ===");
    println!("first loss : {:.4}", report.first_loss);
    println!("final loss : {:.4} (tail-5 mean {:.4})", report.final_loss, report.tail_loss(5));
    println!("mean tokens/s: {:.0}", report.mean_tgs);
    println!("wall clock : {:.1}s for {} steps", report.total_s, report.steps.len());
    println!("loss curve : {csv_path}");

    // The run only counts as a pass if the model actually learned.
    let improved = report.first_loss - report.tail_loss(5);
    if improved > 1.0 {
        println!("loss dropped by {improved:.2} nats — all three layers compose. ✓");
        Ok(())
    } else {
        Err(memfine::Error::runtime(format!(
            "loss only improved {improved:.3} nats over {steps} steps"
        )))
    }
}
