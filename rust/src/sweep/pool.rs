//! Deterministic worker pool for embarrassingly-parallel scenario
//! grids (the structured-parallelism idiom of ppl's `ThreadPool`,
//! reduced to std): a shared injector queue that idle workers pull
//! from, with results flowing back to the caller over an `mpsc`
//! channel tagged by job index.
//!
//! Scheduling order is nondeterministic by design (whichever worker is
//! free takes the next job), but the *output* is not: every job
//! carries its index, jobs are pure functions of their input, and the
//! consumer keys everything by that index — so any index-keyed
//! reduction is bit-identical for any worker count. The sweep engine's
//! determinism guarantee rests on exactly this property.
//!
//! Two entry points: [`parallel_for_each_indexed`] streams each result
//! to a caller-side consumer as it lands (the million-scenario path —
//! nothing is retained in the pool), and [`parallel_map_indexed`]
//! collects into an input-ordered `Vec` on top of it.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Mutex;

/// Run `f` over `items` on `workers` threads, streaming every result
/// to `consume` on the **caller's thread** as it arrives. `f` receives
/// `(index, item)`; `consume` receives `(index, result)` in completion
/// order, which is nondeterministic for `workers > 1` — consumers must
/// key on the index (the sweep reducer folds by grid index for exactly
/// this reason). With `workers <= 1` the loop runs inline in input
/// order with no threads spawned; serial and parallel deliver the same
/// (index, result) multiset.
pub fn parallel_for_each_indexed<T, R, F, C>(items: Vec<T>, workers: usize, f: F, mut consume: C)
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
    C: FnMut(usize, R),
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for (i, t) in items.into_iter().enumerate() {
            let r = f(i, t);
            consume(i, r);
        }
        return;
    }

    // Global injector: workers steal the next job when idle, so a slow
    // scenario never blocks the queue behind it (dynamic load balance
    // over a heterogeneous grid — method 1 runs cost ~2× method 3).
    let injector: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let injector = &injector;
    let f = &f;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            scope.spawn(move || loop {
                let job = injector.lock().unwrap().pop_front();
                match job {
                    Some((i, t)) => {
                        let r = f(i, t);
                        if tx.send((i, r)).is_err() {
                            break;
                        }
                    }
                    None => break,
                }
            });
        }
        drop(tx);
        for (i, r) in rx {
            consume(i, r);
        }
    })
}

/// Map `f` over `items` on `workers` threads, preserving input order
/// in the output. Collect-all convenience over
/// [`parallel_for_each_indexed`]; prefer the streaming form when
/// results are large or the grid is (the sweep engine does).
pub fn parallel_map_indexed<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    parallel_for_each_indexed(items, workers, f, |i, r| {
        debug_assert!(out[i].is_none(), "job {i} delivered twice");
        out[i] = Some(r);
    });
    out.into_iter()
        .map(|r| r.expect("every job delivers exactly one result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map_indexed(items, 4, |i, x| {
            assert_eq!(i as u64, x);
            x * x
        });
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let work = |_: usize, x: u64| x.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17);
        let items: Vec<u64> = (0..64).collect();
        let serial = parallel_map_indexed(items.clone(), 1, work);
        for workers in [2, 3, 8, 64, 200] {
            let parallel = parallel_map_indexed(items.clone(), workers, work);
            assert_eq!(serial, parallel, "workers={workers}");
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<u64> = parallel_map_indexed(Vec::<u64>::new(), 8, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_item_more_workers_than_jobs() {
        let out = parallel_map_indexed(vec![41u64], 16, |_, x| x + 1);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn streaming_delivers_every_result_exactly_once() {
        for workers in [1usize, 4, 16] {
            let items: Vec<u64> = (0..50).collect();
            let mut seen = vec![0u32; 50];
            let mut sum = 0u64;
            parallel_for_each_indexed(items, workers, |_, x| x * 3, |i, r| {
                seen[i] += 1;
                sum += r;
            });
            assert!(seen.iter().all(|&c| c == 1), "workers={workers}: {seen:?}");
            assert_eq!(sum, (0..50u64).map(|x| x * 3).sum::<u64>());
        }
    }

    #[test]
    fn streaming_serial_is_input_order() {
        let mut order = Vec::new();
        parallel_for_each_indexed((0..10u64).collect(), 1, |_, x| x, |i, _| order.push(i));
        assert_eq!(order, (0..10).collect::<Vec<usize>>());
    }

    #[test]
    fn uneven_job_costs_all_complete() {
        // Jobs with wildly different costs: the injector rebalances and
        // every result still lands at its index.
        let items: Vec<u64> = (0..32).collect();
        let out = parallel_map_indexed(items, 4, |_, x| {
            let spin = if x % 7 == 0 { 200_000 } else { 10 };
            let mut acc = x;
            for i in 0..spin {
                acc = acc.wrapping_add(i).rotate_left(1);
            }
            (x, acc)
        });
        for (i, (x, _)) in out.iter().enumerate() {
            assert_eq!(i as u64, *x);
        }
    }
}
