//! Load-balancing baselines the paper compares against conceptually:
//! GShard-style expert capacity (hard drop) and DeepSeek's
//! auxiliary-loss-free bias adjustment (soft steering). MemFine's
//! thesis is that both are insufficient on small-memory GPUs — capacity
//! hurts accuracy (token drops) and bias steering still admits extreme
//! iterations — so these exist to quantify that trade-off in the
//! ablation benches.

/// Outcome of applying an expert-capacity limit (GShard §3.2).
#[derive(Clone, Debug, PartialEq)]
pub struct CapacityOutcome {
    /// Post-drop tokens per expert.
    pub per_expert: Vec<u64>,
    /// Token copies dropped by the cap.
    pub dropped: u64,
    /// The applied per-expert cap.
    pub capacity: u64,
}

/// Apply a capacity factor: each expert accepts at most
/// `cf · total/ n_experts` copies; the excess is dropped (GShard routes
/// overflow to the residual path, which for memory purposes is a drop).
pub fn apply_capacity_factor(per_expert: &[u64], capacity_factor: f64) -> CapacityOutcome {
    assert!(capacity_factor > 0.0);
    let total: u64 = per_expert.iter().sum();
    let n = per_expert.len() as u64;
    let capacity = ((capacity_factor * total as f64 / n as f64).ceil() as u64).max(1);
    let mut dropped = 0;
    let clipped: Vec<u64> = per_expert
        .iter()
        .map(|&c| {
            let keep = c.min(capacity);
            dropped += c - keep;
            keep
        })
        .collect();
    CapacityOutcome { per_expert: clipped, dropped, capacity }
}

/// DeepSeek-style auxiliary-loss-free balancing: per-expert bias nudged
/// against recent load. Returns updated biases; the caller mixes them
/// into the popularity vector for the next iteration.
///
/// `biases[i] -= rate` if expert i was overloaded, `+= rate` otherwise
/// (sign update, as in the paper arXiv:2408.15664).
pub fn update_bias(biases: &mut [f64], per_expert: &[u64], rate: f64) {
    let total: u64 = per_expert.iter().sum();
    if total == 0 {
        return;
    }
    let mean = total as f64 / per_expert.len() as f64;
    for (b, &c) in biases.iter_mut().zip(per_expert) {
        if (c as f64) > mean {
            *b -= rate;
        } else {
            *b += rate;
        }
    }
}

/// Mix a bias vector into a popularity vector (softmax-free version:
/// additive in probability space with renormalisation, clamped ≥ 0).
pub fn biased_popularity(popularity: &[f64], biases: &[f64]) -> Vec<f64> {
    let mixed: Vec<f64> = popularity
        .iter()
        .zip(biases)
        .map(|(&p, &b)| (p + b).max(0.0))
        .collect();
    let sum: f64 = mixed.iter().sum();
    if sum <= 0.0 {
        return vec![1.0 / popularity.len() as f64; popularity.len()];
    }
    mixed.iter().map(|&x| x / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_clips_and_counts_drops() {
        let out = apply_capacity_factor(&[100, 0, 0, 0], 1.0);
        assert_eq!(out.capacity, 25);
        assert_eq!(out.per_expert, vec![25, 0, 0, 0]);
        assert_eq!(out.dropped, 75);
    }

    #[test]
    fn generous_capacity_drops_nothing() {
        let counts = vec![30, 20, 25, 25];
        let out = apply_capacity_factor(&counts, 2.0);
        assert_eq!(out.per_expert, counts);
        assert_eq!(out.dropped, 0);
    }

    #[test]
    fn conservation_kept_plus_dropped() {
        let counts = vec![50, 10, 90, 5, 45];
        let out = apply_capacity_factor(&counts, 1.2);
        let kept: u64 = out.per_expert.iter().sum();
        assert_eq!(kept + out.dropped, 200);
    }

    #[test]
    fn bias_pushes_toward_uniform() {
        let mut biases = vec![0.0; 4];
        update_bias(&mut biases, &[100, 0, 0, 0], 0.01);
        assert!(biases[0] < 0.0);
        assert!(biases[1] > 0.0);
        let pop = biased_popularity(&[0.97, 0.01, 0.01, 0.01], &biases);
        assert!(pop[0] < 0.97);
        assert!((pop.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn repeated_bias_updates_converge_cv() {
        // Iterating the bias rule on a fixed skewed popularity must
        // reduce the coefficient of variation of the effective load.
        use crate::util::rng::Rng;
        use crate::util::stats::Summary;
        let raw = [0.7, 0.1, 0.1, 0.1];
        let mut biases = vec![0.0; 4];
        let mut rng = Rng::new(3);
        let mut first_cv = None;
        let mut last_cv = 0.0;
        for _ in 0..50 {
            let pop = biased_popularity(&raw, &biases);
            let counts = rng.multinomial(100_000, &pop);
            last_cv = Summary::from_iter(counts.iter().map(|&c| c as f64)).cv();
            first_cv.get_or_insert(last_cv);
            update_bias(&mut biases, &counts, 0.02);
        }
        assert!(last_cv < first_cv.unwrap() * 0.5, "{last_cv} vs {first_cv:?}");
    }

    #[test]
    fn zero_total_is_noop() {
        let mut biases = vec![0.1; 3];
        update_bias(&mut biases, &[0, 0, 0], 0.5);
        assert_eq!(biases, vec![0.1; 3]);
    }
}
