//! Minimal `log::Log` backend (no `env_logger` offline).
//!
//! Level comes from `MEMFINE_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`. Messages go to stderr with a monotonic
//! timestamp so example/bench output on stdout stays machine-parsable.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct Logger {
    start: Instant,
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

impl log::Log for Logger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Parse a level name, case-insensitive; unknown names yield None.
pub fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger (idempotent; later calls only adjust the level).
pub fn init() {
    let level = std::env::var("MEMFINE_LOG")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or(LevelFilter::Info);
    let logger = LOGGER.get_or_init(|| Logger { start: Instant::now() });
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_names() {
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("TRACE"), Some(LevelFilter::Trace));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logger smoke test");
    }
}
