//! Layer-3 coordinator: the paper's system contribution running with
//! REAL buffers and REAL executables.
//!
//! Two components:
//!
//! * [`train::TrainDriver`] — the end-to-end training loop: loads the
//!   AOT `train_step` executable and the initial parameters, streams
//!   synthetic corpus batches, and logs loss/TGS
//!   (examples/train_moe.rs).
//! * [`ep::EpCoordinator`] — a thread-per-EP-rank mini-cluster for the
//!   MoE layer path: each rank routes its own tokens with the Pallas
//!   router executable, the leader plans the all-to-all
//!   ([`crate::dispatch`]), MACT picks the chunk bin against a memory
//!   budget, and each chunk's grouped expert buffers are assembled from
//!   real `mpsc` messages, executed with the matching
//!   `expert_ffn_c{bin}` executable, and combined back — Eq. 4/6 end
//!   to end, with [`crate::cluster::MemoryTracker`] accounting every
//!   buffer and surfacing OOM exactly where the paper's Table 4 does.

pub mod ep;
pub mod train;
