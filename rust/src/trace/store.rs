//! Content-addressed on-disk trace cache.
//!
//! After the fused evaluator (PR 4), trace generation — the
//! gamma/Dirichlet/multinomial draw loop — is the dominant cost of a
//! sweep. But a routed trace is a pure function of `(model, parallel,
//! seed, iterations, provenance)`, so re-sweeping the same (model,
//! seed) cells — new methods, new memory budgets, new MACT bins, a
//! re-run campaign — regenerates byte-for-byte identical traces. The
//! [`TraceStore`] caches them instead: one compact binary file per
//! trace cell, keyed by the FNV-1a 64 hash of the trace's canonical
//! identity document, shared by every `memfine sweep` / `memfine
//! launch` shard process pointed at the same campaign `--dir`.
//!
//! Safety properties, in the spirit of the checkpoint layer:
//!
//! * **Exact**: records round-trip through `u64`/f64-bit encoding, so
//!   a warm-cache sweep is bit-identical to a cold one (pinned by
//!   engine tests and a CI smoke).
//! * **Torn-write tolerant**: files are written to a per-process temp
//!   name and atomically renamed into place; loads validate magic,
//!   length, key and a trailing FNV checksum, and any mismatch is a
//!   cache miss (the trace regenerates and overwrites), never an
//!   error.
//! * **Concurrency-safe**: shard processes own disjoint cells, and
//!   even racing writers of the same key write identical bytes, so
//!   the atomic rename makes the last one win harmlessly.

use std::path::{Path, PathBuf};

use crate::config::{ModelConfig, ParallelConfig};
use crate::error::{Error, Result};
use crate::json;
use crate::trace::provenance::TraceProvenance;
use crate::trace::{RoutingRecord, SharedRoutingTrace};
use crate::util::fnv1a_64;

/// File magic: "MFTR" + format version. Bump on any layout change.
const MAGIC: &[u8; 8] = b"MFTRC001";
/// Fixed header: magic + key + seed + iterations + moe_layers + count.
const HEADER_BYTES: usize = 8 + 5 * 8;
/// Bytes per record: min_recv + mean_recv bits + max_recv.
const RECORD_BYTES: usize = 3 * 8;

/// Content hash (16 hex chars) of a trace's identity: everything that
/// decides its drawn bits. Model and parallel geometry enter via their
/// canonical JSON (same writer the scenario hash uses), provenance via
/// its version-stable hash fields — so, like scenario hashes, trace
/// keys agree across processes, hosts and releases.
pub fn trace_key(
    model: &ModelConfig,
    parallel: &ParallelConfig,
    seed: u64,
    iterations: u64,
    prov: &TraceProvenance,
) -> String {
    let mut fields = vec![
        ("iterations", json::num(iterations as f64)),
        ("model", model.to_json()),
        ("parallel", parallel.to_json()),
        ("seed", json::num(seed as f64)),
    ];
    fields.extend(prov.hash_fields());
    let doc = json::obj(fields);
    format!("{:016x}", fnv1a_64(doc.to_string_compact().as_bytes()))
}

/// A directory of cached traces, one `<key>.trace` file per cell.
#[derive(Clone, Debug)]
pub struct TraceStore {
    dir: PathBuf,
}

impl TraceStore {
    /// Open (creating if missing) a cache rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("trace cache {}: {e}", dir.display()),
            ))
        })?;
        Ok(TraceStore { dir })
    }

    /// The cache file a key maps to.
    pub fn path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.trace"))
    }

    /// Complete `.trace` entries currently on disk (tmp files and
    /// foreign names excluded) — an observability read for `memfine
    /// status`; 0 on an unreadable directory, never an error.
    pub fn entry_count(&self) -> usize {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return 0;
        };
        entries
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().and_then(|x| x.to_str()) == Some("trace")
            })
            .count()
    }

    /// Load the trace cached under `key`, reconstructing it against
    /// the caller's (model, parallel) identity. Returns `None` — a
    /// cache miss — on a missing, torn, corrupt, or mismatched file;
    /// the caller regenerates and overwrites.
    pub fn load(
        &self,
        key: &str,
        model: &ModelConfig,
        parallel: &ParallelConfig,
        seed: u64,
        iterations: u64,
    ) -> Option<SharedRoutingTrace> {
        let bytes = std::fs::read(self.path(key)).ok()?;
        if bytes.len() < HEADER_BYTES + 8 || &bytes[..8] != MAGIC {
            return None;
        }
        let payload = &bytes[..bytes.len() - 8];
        if fnv1a_64(payload) != read_u64(&bytes, bytes.len() - 8) {
            return None;
        }
        let file_key = read_u64(&bytes, 8);
        let file_seed = read_u64(&bytes, 16);
        let file_iterations = read_u64(&bytes, 24);
        let moe_layers = read_u64(&bytes, 32);
        let count = read_u64(&bytes, 40);
        let want_moe = model.layers - model.dense_layers;
        if u64::from_str_radix(key, 16).ok()? != file_key
            || file_seed != seed
            || file_iterations != iterations
            || moe_layers != want_moe
            || count != iterations.saturating_mul(moe_layers)
            || bytes.len() != HEADER_BYTES + count as usize * RECORD_BYTES + 8
        {
            return None;
        }
        let mut records = Vec::with_capacity(count as usize);
        for i in 0..count as usize {
            let off = HEADER_BYTES + i * RECORD_BYTES;
            records.push(RoutingRecord {
                iteration: i as u64 / moe_layers,
                layer: model.dense_layers + i as u64 % moe_layers,
                min_recv: read_u64(&bytes, off),
                mean_recv: f64::from_bits(read_u64(&bytes, off + 8)),
                max_recv: read_u64(&bytes, off + 16),
            });
        }
        Some(SharedRoutingTrace {
            seed,
            iterations,
            model: model.clone(),
            parallel: parallel.clone(),
            first_iteration: 0,
            records,
        })
    }

    /// Cache `trace` under `key`: serialise to a per-process temp file
    /// and atomically rename into place, so readers only ever see a
    /// complete file and racing writers of the same key are harmless
    /// (identical content by determinism).
    pub fn save(&self, key: &str, trace: &SharedRoutingTrace) -> Result<()> {
        // the on-disk format implies full coverage from iteration 0;
        // range traces (intra-cell splits) are never cached
        assert_eq!(trace.first_iteration, 0, "trace store only holds whole-cell traces");
        // chaos drills inject IO faults here; callers already treat a
        // failed save as cache-degrade (count it, keep the in-memory
        // trace), so an injected ENOSPC exercises that exact path
        crate::faultfs::check(crate::faultfs::SITE_TRACE_STORE).map_err(Error::Io)?;
        let moe_layers = trace.moe_layers() as u64;
        let key_u64 = u64::from_str_radix(key, 16)
            .map_err(|_| Error::config(format!("trace key '{key}' is not 16 hex chars")))?;
        let mut bytes =
            Vec::with_capacity(HEADER_BYTES + trace.records.len() * RECORD_BYTES + 8);
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&key_u64.to_le_bytes());
        bytes.extend_from_slice(&trace.seed.to_le_bytes());
        bytes.extend_from_slice(&trace.iterations.to_le_bytes());
        bytes.extend_from_slice(&moe_layers.to_le_bytes());
        bytes.extend_from_slice(&(trace.records.len() as u64).to_le_bytes());
        for r in &trace.records {
            bytes.extend_from_slice(&r.min_recv.to_le_bytes());
            bytes.extend_from_slice(&r.mean_recv.to_bits().to_le_bytes());
            bytes.extend_from_slice(&r.max_recv.to_le_bytes());
        }
        let checksum = fnv1a_64(&bytes);
        bytes.extend_from_slice(&checksum.to_le_bytes());

        let tmp = self.dir.join(format!("{key}.tmp.{}", std::process::id()));
        std::fs::write(&tmp, &bytes).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("write trace cache {}: {e}", tmp.display()),
            ))
        })?;
        std::fs::rename(&tmp, self.path(key)).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("rename trace cache {} -> {key}.trace: {e}", tmp.display()),
            ))
        })
    }
}

#[inline]
fn read_u64(bytes: &[u8], off: usize) -> u64 {
    let mut buf = [0u8; 8];
    buf.copy_from_slice(&bytes[off..off + 8]);
    u64::from_le_bytes(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, model_ii, paper_parallel};
    use crate::router::GatingSim;
    use crate::trace::provenance::RouterSampler;

    fn tmp_store(name: &str) -> TraceStore {
        let mut dir = std::env::temp_dir();
        dir.push(format!("memfine-trace-store-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        TraceStore::open(dir).unwrap()
    }

    fn sample_trace(seed: u64, iterations: u64) -> SharedRoutingTrace {
        let gating = GatingSim::new(model_i(), paper_parallel(), seed);
        SharedRoutingTrace::generate(&gating, iterations)
    }

    #[test]
    fn key_is_stable_and_identity_sensitive() {
        let prov = TraceProvenance::default();
        let k = trace_key(&model_i(), &paper_parallel(), 7, 10, &prov);
        assert_eq!(k.len(), 16);
        assert_eq!(k, trace_key(&model_i(), &paper_parallel(), 7, 10, &prov));
        // every identity axis perturbs the key
        assert_ne!(k, trace_key(&model_ii(), &paper_parallel(), 7, 10, &prov));
        assert_ne!(k, trace_key(&model_i(), &paper_parallel(), 8, 10, &prov));
        assert_ne!(k, trace_key(&model_i(), &paper_parallel(), 7, 11, &prov));
        let mut narrow = paper_parallel();
        narrow.ep = 16;
        assert_ne!(k, trace_key(&model_i(), &narrow, 7, 10, &prov));
        let seq = TraceProvenance::legacy_sequential();
        assert_ne!(k, trace_key(&model_i(), &paper_parallel(), 7, 10, &seq));
        let v2 = TraceProvenance { sampler: RouterSampler::Split, rng_version: 2 };
        assert_ne!(k, trace_key(&model_i(), &paper_parallel(), 7, 10, &v2));
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let store = tmp_store("roundtrip");
        let trace = sample_trace(7, 3);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            trace.seed,
            trace.iterations,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        let back = store
            .load(&key, &trace.model, &trace.parallel, trace.seed, trace.iterations)
            .expect("cache hit");
        assert_eq!(back.seed, trace.seed);
        assert_eq!(back.iterations, trace.iterations);
        assert_eq!(back.model, trace.model);
        assert_eq!(back.parallel, trace.parallel);
        assert_eq!(back.records.len(), trace.records.len());
        for (a, b) in back.records.iter().zip(&trace.records) {
            assert_eq!(a.iteration, b.iteration);
            assert_eq!(a.layer, b.layer);
            assert_eq!(a.min_recv, b.min_recv);
            assert_eq!(a.max_recv, b.max_recv);
            // means to the bit — warm-cache byte-identity rests on it
            assert_eq!(a.mean_recv.to_bits(), b.mean_recv.to_bits());
        }
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn entry_count_sees_only_complete_entries() {
        let store = tmp_store("entry-count");
        assert_eq!(store.entry_count(), 0);
        let trace = sample_trace(7, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            7,
            2,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        // a stray tmp file (an in-flight writer) must not be counted
        std::fs::write(store.dir.join("deadbeef.tmp.1"), b"x").unwrap();
        assert_eq!(store.entry_count(), 1);
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn missing_torn_and_corrupt_files_are_misses() {
        let store = tmp_store("corrupt");
        let trace = sample_trace(9, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            9,
            2,
            &TraceProvenance::default(),
        );
        // missing
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_none());
        store.save(&key, &trace).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_some());
        // torn: truncate mid-record
        let path = store.path(&key);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_none());
        // corrupt: flip a payload byte under an intact length
        let mut flipped = bytes.clone();
        flipped[HEADER_BYTES + 3] ^= 0xFF;
        std::fs::write(&path, &flipped).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_none());
        // restore: hit again (regeneration would overwrite in practice)
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.load(&key, &trace.model, &trace.parallel, 9, 2).is_some());
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn mismatched_identity_is_a_miss() {
        let store = tmp_store("mismatch");
        let trace = sample_trace(11, 2);
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            11,
            2,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        // wrong seed / iterations / model shape all miss
        assert!(store.load(&key, &trace.model, &trace.parallel, 12, 2).is_none());
        assert!(store.load(&key, &trace.model, &trace.parallel, 11, 3).is_none());
        assert!(store.load(&key, &model_ii(), &trace.parallel, 11, 2).is_none());
        // a file stored under a different key misses too
        let other = trace_key(
            &trace.model,
            &trace.parallel,
            12,
            2,
            &TraceProvenance::default(),
        );
        std::fs::copy(store.path(&key), store.path(&other)).unwrap();
        assert!(store.load(&other, &trace.model, &trace.parallel, 12, 2).is_none());
        std::fs::remove_dir_all(store.dir).ok();
    }

    #[test]
    fn empty_iteration_trace_roundtrips() {
        // iterations = 0 ⇒ zero records; the store must round-trip the
        // degenerate shape exactly (satellite edge case).
        let store = tmp_store("empty");
        let trace = sample_trace(5, 0);
        assert!(trace.records.is_empty());
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            5,
            0,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).unwrap();
        let back = store
            .load(&key, &trace.model, &trace.parallel, 5, 0)
            .expect("empty trace hit");
        assert_eq!(back.iterations, 0);
        assert!(back.records.is_empty());
        std::fs::remove_dir_all(store.dir).ok();
    }
}
