//! Parallel scenario-sweep engine — the substrate behind every
//! table/figure grid in the reproduction.
//!
//! A sweep is the cross product `models × methods × seeds` from a
//! [`SweepConfig`], expanded into ordered [`grid::Scenario`]s and
//! grouped into [`grid::TraceCell`]s — the (model, seed) cells whose
//! scenarios differ only in method. Each cell draws its routed-token
//! stream **once** ([`crate::trace::SharedRoutingTrace`]) and then
//! dispatches **one fused job** that walks the trace once and
//! evaluates every method simultaneously
//! ([`crate::sim::evaluate_cell`], memoised kernels, `RunSummary`
//! aggregates); the per-method pass
//! ([`crate::sim::run_scenario_on_trace`]) survives behind
//! [`SweepRunOptions::unfused`] as the A/B reference the fused path is
//! pinned byte-identical against. This is the paper's
//! paired-comparison structure, exploited for throughput. Workers
//! stream flat [`report::ScenarioResult`]s back as scenarios finish;
//! the [`report::SweepReducer`] folds them incrementally in grid-index
//! order (memory stays O(cells) of aggregate state plus the flat rows
//! the artifact carries — the heavyweight `RunOutcome`s die in the
//! workers), and the optional [`checkpoint`] layer appends each result
//! to a JSON-lines file keyed by scenario content hash, enabling
//! `--resume`, `--shard i/n` splits, and cross-host merges.
//!
//! Trace generation itself is cacheable: with a `trace_cache`
//! directory configured ([`crate::trace::store::TraceStore`], always
//! on under `memfine launch`), each cell's drawn stream is persisted
//! keyed by its full provenance (model, parallel, seed, iterations,
//! sampler, RNG version) and re-sweeps of the same cells skip the
//! gamma/multinomial draw loop entirely — warm-cache artifacts are
//! pinned byte-identical to cold runs.
//!
//! Under the counter-based RNG generation (`--rng v2`,
//! [`crate::trace::provenance::RngVersion`]) cells additionally admit
//! **intra-cell parallelism**: because every (iteration, layer) draw
//! site is O(1)-addressable in the Philox counter streams, a cell's
//! iterations can be cut into contiguous ranges dispatched as
//! independent pool jobs ([`sim::evaluate_cell_range`] over
//! [`SharedRoutingTrace::generate_range`]), with the consumer folding
//! the per-range partials in iteration order
//! ([`sim::fold_cell_partials`]) — so a grid with one dominant cell no
//! longer serialises on it, and the artifact stays byte-identical at
//! every split width ([`SweepRunOptions::split_iters`]).
//!
//! **Determinism contract:** the report — including its serialised
//! bytes — depends only on the `SweepConfig`, the router `sampler`
//! choice (default: the splitting multinomial; the sequential sampler
//! remains selectable and hash-distinct) and the RNG version (default:
//! v1, byte-frozen; v2 is an equally valid, hash-distinct sample).
//! Worker count, thread scheduling — including the pool's
//! work-stealing schedule, channel backend, and core pinning
//! ([`pool::PoolConfig`]) — intra-cell split widths, shard splits,
//! kill/resume points, trace-cache state, and checkpoint merge order
//! cannot perturb it, because
//!
//! 1. every scenario derives its RNG streams purely from its own
//!    config/seed (no shared mutable state, nothing drawn from a
//!    global generator at execution time), and trace sharing only
//!    changes *when* a stream is drawn, never *what* is drawn —
//!    `run_scenario_on_trace` is pinned bit-identical to
//!    `run_scenario`;
//! 2. results are keyed by grid index and folded in ascending index
//!    order whatever their arrival order, so floats accumulate in one
//!    fixed order (see [`report::SweepReducer`]);
//! 3. scenario identity under resume is a content hash of the
//!    resolved run config ([`checkpoint::scenario_hash`]) — grid
//!    position and execution parameters never enter it;
//! 4. JSON objects serialise with sorted keys, and every number in a
//!    checkpoint round-trips bit-exactly.
//!
//! `tests/integration_sweep.rs` pins all of it: a 24-scenario grid run
//! with 1 worker, 8 workers, as two merged shards, and as a killed-
//! then-resumed sweep must emit bit-identical JSON.

pub mod checkpoint;
pub mod grid;
pub mod pool;
pub mod report;

pub use grid::{expand, expand_cells, Scenario, TraceCell};
pub use pool::{
    parallel_for_each_indexed, parallel_map_indexed, ChannelKind, PoolConfig, PoolStats,
    Schedule, WorkerStats,
};
pub use report::{CellStats, ScenarioResult, SweepReducer, SweepReport};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::config::{ShardSpec, SweepConfig};
use crate::error::{Error, Result};
use crate::json;
use crate::obs::{DegradeLadder, EventLog, LadderVerdict};
use crate::router::GatingSim;
use crate::sim;
use crate::trace::provenance::{RngVersion, RouterSampler, TraceProvenance};
use crate::trace::store::{trace_key, TraceStore};
use crate::trace::SharedRoutingTrace;

/// Default worker count: the machine's parallelism, capped so a small
/// grid doesn't spawn idle threads.
pub fn default_workers(scenarios: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1);
    hw.min(scenarios.max(1))
}

/// Execution parameters of one sweep invocation. Deliberately **not**
/// part of [`SweepConfig`]: the config is the grid's identity (it is
/// serialised into the artifact and hashed into checkpoints), while
/// everything here only decides *how* that grid gets executed — the
/// artifact bytes must come out identical for any choice of these
/// (`sampler` excepted: it selects a different, equally valid sample
/// of the same routing distribution and is therefore part of the
/// scenario hash and the stamped report provenance).
#[derive(Clone, Debug, Default)]
pub struct SweepRunOptions {
    /// Worker threads (0 = all cores, capped to the grid).
    pub workers: usize,
    /// Checkpoint files: the first is the append/write target, all are
    /// read on `resume` (pass several to merge shard files).
    pub checkpoint: Vec<PathBuf>,
    /// Skip scenarios whose content hash already appears in the
    /// checkpoint files, folding their stored results instead.
    pub resume: bool,
    /// Run only the trace cells this shard owns (round-robin by cell
    /// index, so no shard ever re-draws another shard's traces).
    pub shard: Option<ShardSpec>,
    /// Execute at most this many scenarios this invocation (budgeted
    /// runs; also how the tests simulate a killed sweep). Resumed
    /// results don't count against it.
    pub limit: Option<usize>,
    /// Router sampler the traces are drawn with. **Defaults to the
    /// splitting multinomial** ([`RouterSampler::Split`]) — the flip
    /// the trace-provenance layer made safe; `--router seq` keeps the
    /// historical sequential sample reachable (and is what pre-flip
    /// checkpoints were recorded under).
    pub sampler: RouterSampler,
    /// Evaluate each of a cell's methods as its own pass over the
    /// shared trace ([`sim::run_scenario_on_trace`] per scenario) — the
    /// pre-fusion engine, kept as the A/B reference the fused default
    /// ([`sim::evaluate_cell`]) is pinned byte-identical against.
    /// Execution-only: artifacts never depend on this flag.
    pub unfused: bool,
    /// On-disk trace cache directory ([`TraceStore`]): cells whose
    /// trace is already cached under this run's provenance skip
    /// generation entirely; cells drawn cold are saved for the next
    /// campaign over the same (model, seed) axes. Execution-only —
    /// warm and cold runs are pinned byte-identical.
    pub trace_cache: Option<PathBuf>,
    /// Optional global trace-cache root stacked behind `trace_cache`
    /// ([`TraceStore::open_tiered`]): shared across campaigns and
    /// hosts, consulted on campaign-tier misses, populated on every
    /// save. Used alone it serves as the only tier. Execution-only,
    /// like the campaign tier.
    pub trace_cache_global: Option<PathBuf>,
    /// Worker-pool schedule: work stealing (default) or the legacy
    /// shared injector, kept as the A/B reference. Execution-only —
    /// the chaos tests pin byte-identity across both.
    pub pool: pool::Schedule,
    /// Result-channel backend: bounded backpressure (default, ~4×
    /// workers) or unbounded `std::sync::mpsc`. Execution-only.
    pub channel: pool::ChannelKind,
    /// Best-effort pin of worker `k` to core `k % cores` (Linux
    /// `sched_setaffinity`; no-op elsewhere). Execution-only.
    pub pin_cores: bool,
    /// RNG generation the routing streams are drawn with. **Defaults
    /// to v1** (the fork-per-site splitmix/xoshiro streams every
    /// existing artifact was drawn under, byte-frozen); `--rng v2`
    /// selects the counter-based Philox4x64 streams — a different,
    /// hash-distinct sample of the same distributions whose O(1)
    /// random access unlocks intra-cell splitting. Like `sampler`,
    /// part of the scenario hash and the stamped report provenance.
    pub rng: RngVersion,
    /// Intra-cell split width in iterations (v2 + fused only; ignored
    /// — cells stay whole — under v1 or `--unfused`). 0 = auto: split
    /// only when the grid has fewer cells than workers, so a dominant
    /// cell stops serialising the sweep tail. Execution-only: the
    /// per-cell partials fold in iteration order, so artifacts are
    /// byte-identical at every width and worker count.
    pub split_iters: u64,
    /// Append structured telemetry events to this JSON-lines file
    /// ([`crate::obs::EventLog`]; `memfine launch` points every shard
    /// at the campaign's shared `events.jsonl`). Strictly sidecar:
    /// best-effort emission, never part of scenario hashes or campaign
    /// identity, and pinned to never perturb artifact bytes.
    pub events: Option<PathBuf>,
}

/// What a sweep invocation did, plus the report it produced.
#[derive(Debug)]
pub struct SweepRunSummary {
    pub report: SweepReport,
    /// Scenarios in the full grid.
    pub total: usize,
    /// Scenarios satisfied from checkpoint files.
    pub resumed: usize,
    /// Scenarios executed by this invocation.
    pub executed: usize,
    /// Scenarios excluded by the shard split / `limit` (still missing
    /// from this invocation's report).
    pub skipped: usize,
    /// Unparseable checkpoint lines that were ignored (torn tail of a
    /// killed run).
    pub skipped_checkpoint_lines: usize,
    /// Trace cells whose routed stream was generated this invocation
    /// (every executed cell when no trace cache is configured).
    pub traces_generated: usize,
    /// Trace cells satisfied from the on-disk trace cache.
    pub traces_cached: usize,
    /// Trace cells whose cache write failed (disk full, permissions):
    /// the trace generated fine and the sweep continued uncached.
    pub traces_degraded: usize,
    /// What the worker pool did (jobs/steals/queue depths per worker).
    /// Execution facts only — never folded into the report artifact.
    pub pool: pool::PoolStats,
    /// Execution metrics of this invocation: cache hit/miss/degrade
    /// counters, stage timing histograms (`stage.trace_ns`,
    /// `stage.eval_ns`, `stage.slice_eval_ns`), pool steal and
    /// backpressure counters. Mergeable across shards
    /// ([`crate::metrics::Registry::merge`]); execution facts only —
    /// never folded into the report artifact.
    pub metrics: crate::metrics::Registry,
}

/// One worker job: the still-to-run scenarios of a trace cell, with
/// their precomputed content hashes.
struct CellWork {
    todo: Vec<(String, grid::Scenario)>,
}

/// A cell that has been split into iteration-range slices: the shared
/// job plan every slice of the cell carries (behind an `Arc`), plus
/// what the consumer needs to reassemble it.
struct CellPlan {
    todo: Vec<(String, grid::Scenario)>,
    /// Dense per-run index of this split cell (the consumer's
    /// assembly-map key).
    cell_seq: usize,
    /// Slices the cell was cut into.
    n_slices: usize,
}

/// One unit of pool work: a whole cell (the classic job) or one
/// iteration-range slice of a split cell.
enum SweepJob {
    Whole(CellWork),
    Slice { plan: Arc<CellPlan>, slice: usize, lo: u64, hi: u64 },
}

/// A finished whole-cell job: its rows plus the execution facts the
/// consumer turns into telemetry (worker-side timing rides back with
/// the result, so event emission stays on the single consumer thread).
struct CellOutcome {
    rows: Vec<(String, ScenarioResult)>,
    /// Trace came from the on-disk cache.
    cache_hit: bool,
    /// Trace generated fine but its cache write failed (degraded to
    /// uncached — never an error).
    cache_degraded: bool,
    /// Nanoseconds acquiring the trace (cache load or generation).
    trace_ns: u64,
    /// Nanoseconds evaluating the cell's methods against the trace.
    eval_ns: u64,
}

/// What one pool job sends back to the consumer thread.
enum JobOutput {
    /// A whole cell's finished rows + execution facts.
    Cell(CellOutcome),
    /// One slice's per-method partials, awaiting cell reassembly.
    Slice {
        plan: Arc<CellPlan>,
        slice: usize,
        parts: Vec<sim::CellMethodPartial>,
        eval_ns: u64,
    },
}

fn run_cell(
    work: CellWork,
    sampler: RouterSampler,
    rng: RngVersion,
    unfused: bool,
    store: Option<&TraceStore>,
) -> Result<CellOutcome> {
    let first = &work.todo[0].1;
    // One trace per (model, seed) cell; every method below evaluates
    // against it. The trace identity is (model, parallel, seed,
    // iterations, provenance) — method-independent within the cell —
    // which is exactly the trace store's key.
    let draw = || {
        let gating = GatingSim::new(
            first.run.model.clone(),
            first.run.parallel.clone(),
            first.run.seed,
        )
        .with_sampler(sampler)
        .with_rng(rng);
        SharedRoutingTrace::generate(&gating, first.run.iterations)
    };
    let mut cache_hit = false;
    let mut cache_degraded = false;
    let trace_t0 = std::time::Instant::now();
    let trace = match store {
        Some(st) => {
            let key = trace_key(
                &first.run.model,
                &first.run.parallel,
                first.run.seed,
                first.run.iterations,
                &TraceProvenance::with(sampler, rng),
            );
            match st.load(
                &key,
                &first.run.model,
                &first.run.parallel,
                first.run.seed,
                first.run.iterations,
            ) {
                Some(t) => {
                    cache_hit = true;
                    t
                }
                None => {
                    let t = draw();
                    // The cache is a pure optimisation: a write failure
                    // (disk full, permissions) must not kill a sweep
                    // whose trace generated fine — degrade to uncached.
                    if let Err(e) = st.save(&key, &t) {
                        cache_degraded = true;
                        crate::logging::warn(
                            "sweep",
                            format!("trace cache write failed ({key}): {e}"),
                        );
                    }
                    t
                }
            }
        }
        None => draw(),
    };
    let trace_ns = trace_t0.elapsed().as_nanos() as u64;
    let eval_t0 = std::time::Instant::now();
    let rows = if unfused {
        // Pre-fusion A/B path: one full evaluation pass per method.
        work.todo
            .into_iter()
            .map(|(hash, sc)| {
                debug_assert!(sc.run.method == sc.method && sc.run.seed == sc.seed);
                let out = sim::run_scenario_on_trace(&sc.run, sc.method.clone(), &trace)?;
                Ok((hash, ScenarioResult::new(&sc, &out)))
            })
            .collect::<Result<Vec<_>>>()?
    } else {
        // Fused default: one trace walk evaluates every still-to-run
        // method of the cell simultaneously (sim::evaluate_cell),
        // returning lightweight RunSummary aggregates — pinned
        // byte-identical to the per-method path above.
        let methods: Vec<_> = work.todo.iter().map(|(_, sc)| sc.method.clone()).collect();
        let outcomes = sim::evaluate_cell(&first.run, &methods, &trace)?;
        debug_assert_eq!(outcomes.len(), work.todo.len());
        work.todo
            .into_iter()
            .zip(outcomes)
            .map(|((hash, sc), out)| {
                debug_assert!(out.method == sc.method && sc.run.seed == sc.seed);
                (hash, ScenarioResult::from_summary(&sc, &out.summary))
            })
            .collect()
    };
    Ok(CellOutcome {
        rows,
        cache_hit,
        cache_degraded,
        trace_ns,
        eval_ns: eval_t0.elapsed().as_nanos() as u64,
    })
}

/// Evaluate one iteration-range slice of a split cell: draw exactly
/// this range of the cell's routing stream (O(1) random access is what
/// the v2 counter RNG buys — each (iteration, layer) site is addressed
/// directly, no sequential prefix to replay) and walk it through the
/// fused range evaluator. Slices bypass the trace store: the store
/// only holds whole-cell traces, and a split cell is by definition one
/// this run wants to parallelise *inside*, not re-load.
fn run_slice(
    plan: &CellPlan,
    sampler: RouterSampler,
    rng: RngVersion,
    lo: u64,
    hi: u64,
) -> Result<Vec<sim::CellMethodPartial>> {
    let first = &plan.todo[0].1;
    let gating = GatingSim::new(
        first.run.model.clone(),
        first.run.parallel.clone(),
        first.run.seed,
    )
    .with_sampler(sampler)
    .with_rng(rng);
    let trace = SharedRoutingTrace::generate_range(&gating, lo, hi);
    let methods: Vec<_> = plan.todo.iter().map(|(_, sc)| sc.method.clone()).collect();
    sim::evaluate_cell_range(&first.run, &methods, &trace, lo, hi)
}

/// Run a sweep under the given execution options: resume from
/// checkpoints, apply the shard filter and scenario budget, execute
/// the remaining trace cells on the worker pool, stream results
/// through the reducer (checkpointing each as it lands), and finish
/// the report. See the module docs for the determinism contract.
pub fn run_sweep_with(cfg: &SweepConfig, opts: &SweepRunOptions) -> Result<SweepRunSummary> {
    let cells = grid::expand_cells(cfg)?;
    let total = cfg.scenario_count();
    let prov = TraceProvenance::with(opts.sampler, opts.rng);

    if opts.resume && opts.checkpoint.is_empty() {
        return Err(Error::config("resume requires at least one checkpoint path"));
    }
    let done = if opts.resume {
        checkpoint::CheckpointSet::load(&opts.checkpoint)?
    } else {
        checkpoint::CheckpointSet::empty()
    };
    // Engine-level (and therefore once-per-process) mismatch warning:
    // shard children and the merge catch-up all pass through here, so
    // none of them needs its own copy of this check.
    if let Some(recorded) = &done.header_provenance {
        if *recorded != prov {
            checkpoint::warn_provenance_mismatch(recorded, &prov, opts.shard.as_ref());
        }
    }
    let mut writer = match opts.checkpoint.first() {
        None => checkpoint::CheckpointWriter::disabled(),
        Some(p) if opts.resume => checkpoint::CheckpointWriter::append(p, Some(&prov))?,
        Some(p) => checkpoint::CheckpointWriter::create(p, Some(&prov))?,
    };
    // the campaign cache fronts the optional global root; a global
    // root alone serves as the only tier
    let store = match (opts.trace_cache.as_deref(), opts.trace_cache_global.as_deref()) {
        (Some(dir), global) => Some(TraceStore::open_tiered(dir, global)?),
        (None, Some(global)) => Some(TraceStore::open(global)?),
        (None, None) => None,
    };

    let mut reducer = SweepReducer::new(cfg.clone(), prov.clone())?;
    let mut resumed = 0usize;
    let mut skipped = 0usize;
    let mut budget = opts.limit.unwrap_or(usize::MAX);
    let mut work: Vec<CellWork> = Vec::new();
    // Hashing serialises the run envelope — only worth it when a
    // checkpoint will be read or written, and then only once per trace
    // cell (checkpoint::CellHasher): a cell's scenarios differ solely
    // in method, so the per-scenario cost is re-hashing the method
    // value, not re-serialising the whole canonical RunConfig.
    let hashing = !opts.checkpoint.is_empty();
    for (cell_index, cell) in cells.into_iter().enumerate() {
        // Shard ownership is per trace *cell*, never per scenario: a
        // split cell would force every shard to re-draw the same
        // routing trace — the exact cost trace sharing removes. Cells
        // are homogeneous (each holds one scenario per method), so
        // round-robin over cells balances shards as well as scenario
        // striding did.
        let owned = match opts.shard {
            Some(s) => s.owns(cell_index),
            None => true,
        };
        // Resume must hash every scenario (other shards' rows fold
        // in regardless of ownership); a write-only checkpoint run
        // needs hashes only for the scenarios it will execute.
        let hasher = if opts.resume || (hashing && owned) {
            Some(checkpoint::CellHasher::new(&cell.scenarios[0].run, &prov))
        } else {
            None
        };
        let mut todo = Vec::new();
        for sc in cell.scenarios {
            let hash = match &hasher {
                Some(h) => h.hash(&sc.method),
                None => String::new(),
            };
            if let Some(prev) = done.get(&hash) {
                // hashes are grid-position-independent; re-key the
                // stored row into this grid's enumeration and re-label
                // it with this grid's spellings (a checkpoint written
                // from an aliased grid — model "1" vs "i" — hashes
                // identically but must not leak its labels into the
                // artifact)
                let mut row = prev.clone();
                row.index = sc.index;
                row.model = sc.model.clone();
                row.method = sc.method.name();
                row.seed = sc.seed;
                reducer.push(row);
                resumed += 1;
            } else if owned && budget > 0 {
                budget -= 1;
                todo.push((hash, sc));
            } else {
                skipped += 1;
            }
        }
        if !todo.is_empty() {
            work.push(CellWork { todo });
        }
    }
    let executed: usize = work.iter().map(|w| w.todo.len()).sum();
    let workers = if opts.workers == 0 {
        default_workers(work.len().max(1))
    } else {
        opts.workers
    };

    // Intra-cell parallelism (v2 + fused only): when one cell would
    // serialise the sweep tail — fewer cells than workers — cut each
    // cell's iterations into contiguous ranges and dispatch them as
    // independent pool jobs. The v1 generators stay whole-cell: their
    // streams are cheap to draw sequentially and the v1 execution
    // graph is byte-frozen. Artifacts cannot depend on the policy —
    // partials fold in iteration order (sim::fold_cell_partials), so
    // any width is bit-identical to unsplit.
    let split_width = if opts.rng == RngVersion::V2 && !opts.unfused {
        if opts.split_iters > 0 {
            opts.split_iters
        } else if workers > 1 && work.len() < workers {
            // auto: ~4 slices per idle worker, floor 16 so small cells
            // stay whole and per-slice setup stays amortised
            cfg.iterations.div_ceil(4 * workers as u64).max(16)
        } else {
            0
        }
    } else {
        0
    };
    let mut jobs: Vec<SweepJob> = Vec::with_capacity(work.len());
    let mut n_split_cells = 0usize;
    for w in work {
        let iters = w.todo[0].1.run.iterations;
        if split_width > 0 && split_width < iters {
            let n_slices = iters.div_ceil(split_width) as usize;
            let plan =
                Arc::new(CellPlan { todo: w.todo, cell_seq: n_split_cells, n_slices });
            n_split_cells += 1;
            for slice in 0..n_slices {
                let lo = slice as u64 * split_width;
                let hi = (lo + split_width).min(iters);
                jobs.push(SweepJob::Slice { plan: Arc::clone(&plan), slice, lo, hi });
            }
        } else {
            jobs.push(SweepJob::Whole(w));
        }
    }

    // Sidecar telemetry: a disabled log when no events path is set,
    // best-effort always. Workers never touch it — timing facts ride
    // back inside JobOutput and the single consumer thread emits, so
    // telemetry adds no synchronisation to the pool.
    let events = match opts.events.as_deref() {
        Some(p) => EventLog::open(p),
        None => EventLog::disabled(),
    };
    let shard_tag = opts.shard.as_ref().map(|s| format!("{}/{}", s.index, s.count));
    let mut metrics = crate::metrics::Registry::new();
    events.emit(
        "sweep_start",
        vec![
            ("total", json::num(total as f64)),
            ("resumed", json::num(resumed as f64)),
            ("planned", json::num(executed as f64)),
            ("jobs", json::num(jobs.len() as f64)),
            ("workers", json::num(workers as f64)),
            ("shard", json::s(shard_tag.as_deref().unwrap_or("-"))),
        ],
    );

    // Stream: each finished job delivers on this thread — whole cells
    // emit their rows directly (checkpoint line out first for
    // kill-safety, then fold); slices park in the assembly map until
    // their cell is complete, then fold in range order and emit the
    // same way.
    //
    // Record writes run through the unified degradation ladder rather
    // than failing the sweep: one in-place retry masks a transient, a
    // lost record is counted and emitted as `checkpoint_degraded` (the
    // row stays in the reducer; resume/merge catch-up re-executes it),
    // and a persistently dead disk quarantines the writer so the run
    // finishes on in-memory results alone.
    let ckpt_ladder = DegradeLadder::new(crate::faultfs::SITE_CHECKPOINT, 1, 3);
    let mut first_err: Option<Error> = None;
    let sampler = opts.sampler;
    let rng = opts.rng;
    let unfused = opts.unfused;
    let store_ref = store.as_ref();
    let cache_on = store_ref.is_some();
    let mut traces_generated = 0usize;
    let mut traces_cached = 0usize;
    let mut traces_degraded = 0usize;
    let mut pending: HashMap<usize, Vec<Option<Vec<sim::CellMethodPartial>>>> =
        HashMap::new();
    let pool_cfg = pool::PoolConfig {
        workers,
        schedule: opts.pool,
        channel: opts.channel,
        pin_cores: opts.pin_cores,
        ..pool::PoolConfig::default()
    };
    let pool_stats = pool::parallel_for_each_indexed_with(
        jobs,
        &pool_cfg,
        |_, job| match job {
            SweepJob::Whole(w) => {
                run_cell(w, sampler, rng, unfused, store_ref).map(JobOutput::Cell)
            }
            SweepJob::Slice { plan, slice, lo, hi } => {
                let t0 = std::time::Instant::now();
                run_slice(&plan, sampler, rng, lo, hi).map(|parts| JobOutput::Slice {
                    plan,
                    slice,
                    parts,
                    eval_ns: t0.elapsed().as_nanos() as u64,
                })
            }
        },
        |_, res| match res {
            Ok(JobOutput::Cell(cell)) => {
                if cell.cache_hit {
                    traces_cached += 1;
                } else {
                    traces_generated += 1;
                }
                if cell.cache_degraded {
                    traces_degraded += 1;
                }
                metrics.observe("stage.trace_ns", cell.trace_ns);
                metrics.observe("stage.eval_ns", cell.eval_ns);
                let mut fields = vec![
                    ("hash", json::s(cell.rows.first().map(|(h, _)| h.as_str()).unwrap_or(""))),
                    ("scenarios", json::num(cell.rows.len() as f64)),
                    ("trace_ns", json::num(cell.trace_ns as f64)),
                    ("eval_ns", json::num(cell.eval_ns as f64)),
                ];
                if cache_on {
                    let cache = if cell.cache_hit {
                        "hit"
                    } else if cell.cache_degraded {
                        "degrade"
                    } else {
                        "miss"
                    };
                    fields.push(("cache", json::s(cache)));
                }
                events.emit("cell_eval", fields);
                let n_rows = cell.rows.len();
                for (hash, row) in cell.rows {
                    let (_, verdict) = ckpt_ladder.run(|| writer.record(&hash, &row));
                    if matches!(
                        verdict,
                        LadderVerdict::Degraded | LadderVerdict::Quarantined
                    ) {
                        events.emit(
                            "checkpoint_degraded",
                            vec![
                                ("hash", json::s(hash.as_str())),
                                (
                                    "quarantined",
                                    json::Value::Bool(
                                        verdict == LadderVerdict::Quarantined,
                                    ),
                                ),
                            ],
                        );
                    }
                    reducer.push(row);
                }
                if writer.enabled() {
                    events.emit(
                        "checkpoint_append",
                        vec![
                            ("rows", json::num(n_rows as f64)),
                            ("records", json::num(writer.records_written() as f64)),
                        ],
                    );
                }
            }
            Ok(JobOutput::Slice { plan, slice, parts, eval_ns }) => {
                metrics.observe("stage.slice_eval_ns", eval_ns);
                events.emit(
                    "slice_eval",
                    vec![
                        ("hash", json::s(plan.todo[0].0.as_str())),
                        ("slice", json::num(slice as f64)),
                        ("slices", json::num(plan.n_slices as f64)),
                        ("eval_ns", json::num(eval_ns as f64)),
                    ],
                );
                let slots = pending
                    .entry(plan.cell_seq)
                    .or_insert_with(|| vec![None; plan.n_slices]);
                debug_assert!(slots[slice].is_none(), "slice delivered twice");
                slots[slice] = Some(parts);
                if !slots.iter().all(Option::is_some) {
                    return;
                }
                let slots = pending.remove(&plan.cell_seq).expect("just inserted");
                let in_order: Vec<_> =
                    slots.into_iter().map(|s| s.expect("all slices present")).collect();
                match sim::fold_cell_partials(in_order) {
                    Ok(outcomes) => {
                        traces_generated += 1;
                        events.emit(
                            "cell_assembled",
                            vec![
                                ("hash", json::s(plan.todo[0].0.as_str())),
                                ("scenarios", json::num(plan.todo.len() as f64)),
                                ("slices", json::num(plan.n_slices as f64)),
                            ],
                        );
                        debug_assert_eq!(outcomes.len(), plan.todo.len());
                        for ((hash, sc), out) in plan.todo.iter().zip(outcomes) {
                            debug_assert!(
                                out.method == sc.method && sc.run.seed == sc.seed
                            );
                            let row = ScenarioResult::from_summary(sc, &out.summary);
                            let (_, verdict) =
                                ckpt_ladder.run(|| writer.record(hash, &row));
                            if matches!(
                                verdict,
                                LadderVerdict::Degraded | LadderVerdict::Quarantined
                            ) {
                                events.emit(
                                    "checkpoint_degraded",
                                    vec![
                                        ("hash", json::s(hash.as_str())),
                                        (
                                            "quarantined",
                                            json::Value::Bool(
                                                verdict == LadderVerdict::Quarantined,
                                            ),
                                        ),
                                    ],
                                );
                            }
                            reducer.push(row);
                        }
                    }
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        },
    );
    if let Some(e) = first_err {
        return Err(e);
    }

    // Fold the run's execution facts into the mergeable registry.
    // Execution-only, like PoolStats: never part of the report.
    metrics.count("trace.generated", traces_generated as u64);
    metrics.count("trace.cached", traces_cached as u64);
    metrics.count("trace.degraded", traces_degraded as u64);
    metrics.count("sweep.executed", executed as u64);
    metrics.count("sweep.resumed", resumed as u64);
    metrics.count("sweep.skipped", skipped as u64);
    metrics.count("checkpoint.records_written", writer.records_written());
    metrics.count("checkpoint.skipped_lines", done.skipped_lines as u64);
    metrics.count("checkpoint.write_degraded", ckpt_ladder.degraded());
    metrics.count("pool.jobs", pool_stats.jobs_total());
    metrics.count("pool.steals_attempted", pool_stats.steals_attempted());
    metrics.count("pool.steals_succeeded", pool_stats.steals_succeeded());
    metrics.count("pool.blocked_sends", pool_stats.blocked_sends);
    metrics.gauge("pool.workers", pool_stats.workers.len() as f64);
    metrics.count("events.dropped", events.dropped());
    events.emit(
        "sweep_done",
        vec![
            ("executed", json::num(executed as f64)),
            ("resumed", json::num(resumed as f64)),
            ("cached", json::num(traces_cached as f64)),
            ("generated", json::num(traces_generated as f64)),
            ("degraded", json::num(traces_degraded as f64)),
            ("blocked_sends", json::num(pool_stats.blocked_sends as f64)),
            ("steals", json::num(pool_stats.steals_succeeded() as f64)),
            ("wall_ns", json::num(pool_stats.wall_ns as f64)),
            ("shard", json::s(shard_tag.as_deref().unwrap_or("-"))),
        ],
    );

    Ok(SweepRunSummary {
        report: reducer.finish(),
        total,
        resumed,
        executed,
        skipped,
        skipped_checkpoint_lines: done.skipped_lines,
        traces_generated,
        traces_cached,
        traces_degraded,
        pool: pool_stats,
        metrics,
    })
}

/// Run the full sweep on `workers` threads and reduce the results —
/// the plain path (no checkpointing/sharding) used by the CLI default,
/// examples and tests.
pub fn run_sweep(cfg: &SweepConfig, workers: usize) -> Result<SweepReport> {
    let opts = SweepRunOptions { workers, ..SweepRunOptions::default() };
    Ok(run_sweep_with(cfg, &opts)?.report)
}

/// The pre-trace-sharing execution path: every scenario draws its own
/// routing trace through the pure [`sim::run_scenario_sampled`], under
/// the engine's default sampler so it stays the A/B reference for the
/// default engine — `benches/sweep_scaling.rs` measures trace sharing
/// against it, and the unit tests pin both paths to identical bytes
/// (which is the trace-sharing correctness argument in one line).
pub fn run_sweep_legacy(cfg: &SweepConfig, workers: usize) -> Result<SweepReport> {
    let scenarios = grid::expand(cfg)?;
    let outcomes = pool::parallel_map_indexed(scenarios, workers, |_, sc| {
        debug_assert!(sc.run.method == sc.method && sc.run.seed == sc.seed);
        let out = sim::run_scenario_sampled(
            &sc.run,
            sc.method.clone(),
            sc.seed,
            RouterSampler::default(),
        );
        (sc, out)
    });
    let mut results = Vec::with_capacity(outcomes.len());
    for (sc, out) in outcomes {
        results.push(ScenarioResult::new(&sc, &out?));
    }
    Ok(SweepReport::build(cfg.clone(), TraceProvenance::default(), results))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    /// A small single-model grid whose 10 iterations cover the
    /// early-training chaos window (peak ~iteration 8), so the MACT
    /// cell demonstrably chunks and Method 1 demonstrably peaks.
    fn tiny_grid() -> SweepConfig {
        SweepConfig {
            models: vec!["i".into()],
            methods: vec![Method::FullRecompute, Method::Mact(vec![1, 2, 4, 8])],
            seeds: vec![7, 8],
            iterations: 10,
        }
    }

    #[test]
    fn sweep_runs_and_aggregates() {
        let report = run_sweep(&tiny_grid(), 2).unwrap();
        assert_eq!(report.scenarios.len(), 4);
        assert_eq!(report.cells.len(), 2);
        // MACT cell must report a positive activation reduction vs m1
        let mact = &report.cells[1];
        assert!(mact.act_reduction_vs_m1_pct.unwrap() > 0.0);
        // every scenario row carries real simulation output
        assert!(report.scenarios.iter().all(|s| s.peak_act_bytes > 0));
        assert!(report.scenarios.iter().all(|s| s.iterations == 10));
    }

    #[test]
    fn worker_count_does_not_change_the_report() {
        let a = run_sweep(&tiny_grid(), 1).unwrap();
        let b = run_sweep(&tiny_grid(), 4).unwrap();
        assert_eq!(a.scenarios, b.scenarios);
        assert_eq!(a.cells, b.cells);
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
    }

    #[test]
    fn pool_schedule_channel_and_pinning_do_not_change_bytes() {
        // The stealing runtime vs the legacy injector, bounded vs
        // unbounded channel, pinned vs unpinned — every combination
        // must emit the serial run's exact bytes (these are execution
        // knobs; the artifact depends only on the grid + sampler).
        let cfg = tiny_grid();
        let serial = run_sweep(&cfg, 1).unwrap().to_json().to_string_pretty();
        for schedule in [pool::Schedule::Stealing, pool::Schedule::Injector] {
            for channel in [pool::ChannelKind::Bounded, pool::ChannelKind::StdMpsc] {
                for pin_cores in [false, true] {
                    let opts = SweepRunOptions {
                        workers: 4,
                        pool: schedule,
                        channel,
                        pin_cores,
                        ..Default::default()
                    };
                    let summary = run_sweep_with(&cfg, &opts).unwrap();
                    let label = format!(
                        "schedule={} channel={} pin={pin_cores}",
                        schedule.tag(),
                        channel.tag()
                    );
                    assert_eq!(
                        serial,
                        summary.report.to_json().to_string_pretty(),
                        "{label}"
                    );
                    assert_eq!(summary.pool.schedule, schedule, "{label}");
                    assert_eq!(summary.pool.jobs_total() as usize, 2, "{label}"); // 2 cells
                }
            }
        }
    }

    #[test]
    fn sweep_summary_carries_pool_stats() {
        let summary = run_sweep_with(
            &tiny_grid(),
            &SweepRunOptions { workers: 2, ..Default::default() },
        )
        .unwrap();
        // 2 (model, seed) cells = 2 pool jobs over 2 workers
        assert_eq!(summary.pool.jobs_total(), 2);
        assert_eq!(summary.pool.workers.len(), 2);
        assert!(summary.pool.wall_ns > 0);
    }

    #[test]
    fn trace_sharing_matches_legacy_bytes() {
        // THE trace-sharing invariant at engine level: the (fused)
        // shared-trace engine and the per-scenario legacy path emit
        // identical bytes.
        let shared = run_sweep(&tiny_grid(), 2).unwrap();
        let legacy = run_sweep_legacy(&tiny_grid(), 2).unwrap();
        assert_eq!(
            shared.to_json().to_string_pretty(),
            legacy.to_json().to_string_pretty()
        );
    }

    #[test]
    fn fused_matches_unfused_and_legacy_bytes() {
        // The fusion invariant at engine level: fused (default),
        // unfused (per-method trace-shared) and legacy (per-scenario)
        // all emit identical bytes — on a grid that includes a
        // fixed-chunk method so cross-method kernel sharing is
        // exercised too.
        let mut cfg = tiny_grid();
        cfg.methods = vec![
            Method::FullRecompute,
            Method::FixedChunk(8),
            Method::Mact(vec![1, 2, 4, 8]),
        ];
        let fused = run_sweep(&cfg, 2).unwrap();
        let unfused_opts = SweepRunOptions { workers: 2, unfused: true, ..Default::default() };
        let unfused = run_sweep_with(&cfg, &unfused_opts).unwrap().report;
        let legacy = run_sweep_legacy(&cfg, 2).unwrap();
        let fused_json = fused.to_json().to_string_pretty();
        assert_eq!(fused_json, unfused.to_json().to_string_pretty());
        assert_eq!(fused_json, legacy.to_json().to_string_pretty());
    }

    #[test]
    fn fused_matches_unfused_under_seq_router() {
        // Same invariant on the sequential (pre-flip) sample: the
        // sampler changes the drawn trace, never the evaluation, so
        // fused and unfused still agree byte for byte.
        let fused_opts = SweepRunOptions {
            workers: 2,
            sampler: RouterSampler::Sequential,
            ..Default::default()
        };
        let unfused_opts = SweepRunOptions {
            workers: 2,
            sampler: RouterSampler::Sequential,
            unfused: true,
            ..Default::default()
        };
        let fused = run_sweep_with(&tiny_grid(), &fused_opts).unwrap().report;
        let unfused = run_sweep_with(&tiny_grid(), &unfused_opts).unwrap().report;
        assert_eq!(
            fused.to_json().to_string_pretty(),
            unfused.to_json().to_string_pretty()
        );
    }

    #[test]
    fn seq_router_is_deterministic_but_a_different_sample() {
        // Post-flip the splitting sampler is the default; the
        // sequential sampler stays reachable, deterministic, and a
        // different (hash-distinct) sample.
        let opts = |w| SweepRunOptions {
            workers: w,
            sampler: RouterSampler::Sequential,
            ..Default::default()
        };
        let a = run_sweep_with(&tiny_grid(), &opts(1)).unwrap();
        let b = run_sweep_with(&tiny_grid(), &opts(4)).unwrap();
        assert_eq!(
            a.report.to_json().to_string_pretty(),
            b.report.to_json().to_string_pretty()
        );
        let default = run_sweep(&tiny_grid(), 2).unwrap();
        // the default report stamps the split provenance, the opt-out
        // stamps seq — and the drawn samples differ
        assert_eq!(default.provenance.sampler, RouterSampler::Split);
        assert_eq!(a.report.provenance.sampler, RouterSampler::Sequential);
        assert_eq!(a.report.scenarios.len(), default.scenarios.len());
        assert!(a
            .report
            .scenarios
            .iter()
            .zip(&default.scenarios)
            .any(|(f, s)| f.peak_act_bytes != s.peak_act_bytes));
    }

    #[test]
    fn warm_trace_cache_is_byte_identical_and_reports_hits() {
        let mut dir = std::env::temp_dir();
        dir.push(format!("memfine-sweep-cache-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = tiny_grid();
        let opts = SweepRunOptions {
            workers: 2,
            trace_cache: Some(dir.clone()),
            ..Default::default()
        };
        let cold = run_sweep_with(&cfg, &opts).unwrap();
        assert_eq!(cold.traces_generated, 2); // one per (model, seed) cell
        assert_eq!(cold.traces_cached, 0);
        let warm = run_sweep_with(&cfg, &opts).unwrap();
        assert_eq!(warm.traces_generated, 0);
        assert_eq!(warm.traces_cached, 2);
        let no_cache = run_sweep(&cfg, 2).unwrap();
        // THE warm-cache invariant: cold, warm, and uncached runs all
        // emit identical bytes.
        let cold_json = cold.report.to_json().to_string_pretty();
        assert_eq!(cold_json, warm.report.to_json().to_string_pretty());
        assert_eq!(cold_json, no_cache.to_json().to_string_pretty());
        // a different sampler misses the cache (provenance-keyed)
        let seq_opts = SweepRunOptions {
            workers: 2,
            sampler: RouterSampler::Sequential,
            trace_cache: Some(dir.clone()),
            ..Default::default()
        };
        let seq = run_sweep_with(&cfg, &seq_opts).unwrap();
        assert_eq!(seq.traces_cached, 0);
        assert_eq!(seq.traces_generated, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_cache_survives_corruption_and_unfused_reads_it() {
        let mut dir = std::env::temp_dir();
        dir.push(format!("memfine-sweep-cache-corrupt-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = tiny_grid();
        let opts = SweepRunOptions {
            workers: 1,
            trace_cache: Some(dir.clone()),
            ..Default::default()
        };
        let baseline = run_sweep_with(&cfg, &opts).unwrap();
        let baseline_json = baseline.report.to_json().to_string_pretty();
        // corrupt every cached file: the sweep must regenerate (miss),
        // not fail, and still emit identical bytes
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            std::fs::write(&path, b"garbage").unwrap();
        }
        let healed = run_sweep_with(&cfg, &opts).unwrap();
        assert_eq!(healed.traces_cached, 0);
        assert_eq!(healed.traces_generated, 2);
        assert_eq!(baseline_json, healed.report.to_json().to_string_pretty());
        // the unfused A/B engine shares the same cache and bytes
        let unfused_opts = SweepRunOptions {
            workers: 1,
            unfused: true,
            trace_cache: Some(dir.clone()),
            ..Default::default()
        };
        let unfused = run_sweep_with(&cfg, &unfused_opts).unwrap();
        assert_eq!(unfused.traces_cached, 2);
        assert_eq!(baseline_json, unfused.report.to_json().to_string_pretty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_runs_partition_the_grid() {
        let cfg = tiny_grid();
        let shard = |i| SweepRunOptions {
            workers: 2,
            shard: Some(crate::config::ShardSpec { index: i, count: 2 }),
            ..Default::default()
        };
        let s0 = run_sweep_with(&cfg, &shard(0)).unwrap();
        let s1 = run_sweep_with(&cfg, &shard(1)).unwrap();
        assert_eq!(s0.executed + s1.executed, cfg.scenario_count());
        assert_eq!(s0.skipped, s1.executed);
        let mut indices: Vec<usize> = s0
            .report
            .scenarios
            .iter()
            .chain(&s1.report.scenarios)
            .map(|r| r.index)
            .collect();
        indices.sort_unstable();
        assert_eq!(indices, (0..cfg.scenario_count()).collect::<Vec<_>>());
    }

    #[test]
    fn limit_caps_executed_scenarios() {
        let cfg = tiny_grid();
        let opts = SweepRunOptions { workers: 1, limit: Some(3), ..Default::default() };
        let s = run_sweep_with(&cfg, &opts).unwrap();
        assert_eq!(s.executed, 3);
        assert_eq!(s.skipped, 1);
        assert_eq!(s.report.scenarios.len(), 3);
    }

    #[test]
    fn rng_v2_is_a_distinct_sample_with_v2_provenance() {
        let cfg = tiny_grid();
        let v1 = run_sweep(&cfg, 2).unwrap();
        let v2 = run_sweep_with(
            &cfg,
            &SweepRunOptions { workers: 2, rng: RngVersion::V2, ..Default::default() },
        )
        .unwrap()
        .report;
        assert_eq!(v1.provenance.rng_version, 1);
        assert_eq!(v2.provenance.rng_version, 2);
        // same grid shape, different draws
        assert_eq!(v1.scenarios.len(), v2.scenarios.len());
        assert!(v1
            .scenarios
            .iter()
            .zip(&v2.scenarios)
            .any(|(a, b)| a.peak_act_bytes != b.peak_act_bytes));
    }

    #[test]
    fn rng_v2_split_widths_and_worker_counts_are_byte_identical() {
        // THE intra-cell-split invariant at engine level: every
        // (workers, split width) combination — including widths that
        // cut mid-cell at awkward boundaries — emits the serial
        // unsplit run's exact bytes, fused and unfused alike.
        let cfg = tiny_grid(); // 10 iterations per cell
        let serial = run_sweep_with(
            &cfg,
            &SweepRunOptions { workers: 1, rng: RngVersion::V2, ..Default::default() },
        )
        .unwrap();
        let serial_json = serial.report.to_json().to_string_pretty();
        for workers in [1usize, 2, 8] {
            for split_iters in [0u64, 1, 3, 4, 7, 100] {
                let opts = SweepRunOptions {
                    workers,
                    rng: RngVersion::V2,
                    split_iters,
                    ..Default::default()
                };
                let s = run_sweep_with(&cfg, &opts).unwrap();
                assert_eq!(
                    serial_json,
                    s.report.to_json().to_string_pretty(),
                    "workers={workers} split_iters={split_iters}"
                );
            }
        }
        // forced width 3 on 10-iteration cells: 4 slices × 2 cells
        let forced = run_sweep_with(
            &cfg,
            &SweepRunOptions {
                workers: 2,
                rng: RngVersion::V2,
                split_iters: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(forced.pool.jobs_total(), 8);
        assert_eq!(forced.traces_generated, 2); // counted per cell, not per slice
        // the per-method unfused engine agrees byte-for-byte under v2
        let unfused = run_sweep_with(
            &cfg,
            &SweepRunOptions {
                workers: 2,
                rng: RngVersion::V2,
                unfused: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(serial_json, unfused.report.to_json().to_string_pretty());
    }

    #[test]
    fn rng_v2_auto_split_engages_on_a_dominant_cell_v1_never_splits() {
        // One (model, seed) cell, many workers: under v2 the auto
        // policy must cut the cell so the extra workers do something;
        // under v1 cells always stay whole (the frozen execution
        // graph), even when split_iters is forced.
        let cfg = SweepConfig {
            models: vec!["i".into()],
            methods: vec![Method::FullRecompute, Method::Mact(vec![1, 2, 4, 8])],
            seeds: vec![7],
            iterations: 40,
        };
        let auto = run_sweep_with(
            &cfg,
            &SweepRunOptions { workers: 8, rng: RngVersion::V2, ..Default::default() },
        )
        .unwrap();
        assert!(auto.pool.jobs_total() > 1, "auto split must engage");
        assert_eq!(auto.traces_generated, 1);
        let whole = run_sweep_with(
            &cfg,
            &SweepRunOptions { workers: 1, rng: RngVersion::V2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(whole.pool.jobs_total(), 1);
        assert_eq!(
            whole.report.to_json().to_string_pretty(),
            auto.report.to_json().to_string_pretty()
        );
        let v1_forced = run_sweep_with(
            &cfg,
            &SweepRunOptions { workers: 8, split_iters: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(v1_forced.pool.jobs_total(), 1, "v1 cells stay whole");
        assert_eq!(v1_forced.report.provenance.rng_version, 1);
    }

    #[test]
    fn rng_v2_split_sweep_checkpoints_and_resumes() {
        // Rows emitted by reassembled split cells must checkpoint and
        // resume exactly like whole-cell rows.
        let path = {
            let mut p = std::env::temp_dir();
            p.push(format!("memfine-sweep-v2-ckpt-{}", std::process::id()));
            p
        };
        std::fs::remove_file(&path).ok();
        let cfg = tiny_grid();
        let opts = SweepRunOptions {
            workers: 2,
            rng: RngVersion::V2,
            split_iters: 3,
            checkpoint: vec![path.clone()],
            ..Default::default()
        };
        let first = run_sweep_with(&cfg, &opts).unwrap();
        assert_eq!(first.executed, 4);
        let resume_opts = SweepRunOptions { resume: true, ..opts };
        let second = run_sweep_with(&cfg, &resume_opts).unwrap();
        assert_eq!(second.executed, 0);
        assert_eq!(second.resumed, 4);
        assert_eq!(
            first.report.to_json().to_string_pretty(),
            second.report.to_json().to_string_pretty()
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn telemetry_never_perturbs_artifact_bytes_and_records_events() {
        let mut path = std::env::temp_dir();
        path.push(format!("memfine-sweep-events-{}.jsonl", std::process::id()));
        std::fs::remove_file(&path).ok();
        let cfg = tiny_grid();
        let off =
            run_sweep_with(&cfg, &SweepRunOptions { workers: 2, ..Default::default() })
                .unwrap();
        let on = run_sweep_with(
            &cfg,
            &SweepRunOptions { workers: 2, events: Some(path.clone()), ..Default::default() },
        )
        .unwrap();
        // THE sidecar invariant: telemetry on vs off, identical bytes.
        assert_eq!(
            off.report.to_json().to_string_pretty(),
            on.report.to_json().to_string_pretty()
        );
        let (evs, skipped) = crate::obs::read_events(&path).unwrap();
        assert_eq!(skipped, 0);
        assert!(evs.iter().any(|e| e.kind == "sweep_start"));
        assert_eq!(evs.iter().filter(|e| e.kind == "cell_eval").count(), 2);
        assert!(evs.iter().any(|e| e.kind == "sweep_done"));
        // stage histograms + counters land in the mergeable registry
        assert_eq!(on.metrics.histogram("stage.eval_ns").unwrap().count(), 2);
        assert_eq!(on.metrics.histogram("stage.trace_ns").unwrap().count(), 2);
        assert_eq!(on.metrics.counter("trace.generated"), 2);
        assert_eq!(on.metrics.counter("sweep.executed"), 4);
        assert_eq!(on.metrics.counter("events.dropped"), 0);
        // a v2 split run additionally emits slice + assembly events and
        // still matches its own telemetry-off bytes
        let v2 = |events| SweepRunOptions {
            workers: 2,
            rng: RngVersion::V2,
            split_iters: 3,
            events,
            ..Default::default()
        };
        std::fs::remove_file(&path).ok();
        let v2_on = run_sweep_with(&cfg, &v2(Some(path.clone()))).unwrap();
        let v2_off = run_sweep_with(&cfg, &v2(None)).unwrap();
        assert_eq!(
            v2_on.report.to_json().to_string_pretty(),
            v2_off.report.to_json().to_string_pretty()
        );
        let (evs, _) = crate::obs::read_events(&path).unwrap();
        assert_eq!(evs.iter().filter(|e| e.kind == "slice_eval").count(), 8);
        assert_eq!(evs.iter().filter(|e| e.kind == "cell_assembled").count(), 2);
        assert_eq!(v2_on.metrics.histogram("stage.slice_eval_ns").unwrap().count(), 8);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trace_cache_write_failure_degrades_and_is_counted() {
        let mut dir = std::env::temp_dir();
        dir.push(format!("memfine-sweep-cache-degrade-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = tiny_grid();
        let opts = SweepRunOptions {
            workers: 1,
            trace_cache: Some(dir.clone()),
            ..Default::default()
        };
        let cold = run_sweep_with(&cfg, &opts).unwrap();
        assert_eq!(cold.traces_degraded, 0);
        let baseline = cold.report.to_json().to_string_pretty();
        // Replace every cached trace file with a *directory* of the
        // same name: loads fail (→ miss), and the save's tmp+rename
        // cannot land on a directory (→ write degrade) — even running
        // as root, unlike permission tricks.
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            std::fs::remove_file(&path).unwrap();
            std::fs::create_dir(&path).unwrap();
        }
        let degraded = run_sweep_with(&cfg, &opts).unwrap();
        assert_eq!(degraded.traces_cached, 0);
        assert_eq!(degraded.traces_generated, 2);
        assert_eq!(degraded.traces_degraded, 2);
        assert_eq!(degraded.metrics.counter("trace.degraded"), 2);
        // degraded-to-uncached still emits identical bytes
        assert_eq!(baseline, degraded.report.to_json().to_string_pretty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_without_checkpoint_errors() {
        let opts = SweepRunOptions { resume: true, ..Default::default() };
        assert!(run_sweep_with(&tiny_grid(), &opts).is_err());
    }

    #[test]
    fn default_workers_bounded() {
        assert!(default_workers(1) >= 1);
        assert!(default_workers(4) <= 4);
        assert!(default_workers(0) >= 1);
    }
}
