//! Crate-wide error type.

/// Unified error for every MemFine subsystem.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Configuration rejected by validation.
    #[error("config error: {0}")]
    Config(String),

    /// JSON parse/serialise failure (see [`crate::json`]).
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// CLI argument error.
    #[error("cli error: {0}")]
    Cli(String),

    /// A simulated or real device ran out of memory. Carries the
    /// requesting device and the attempted allocation so OOM tests can
    /// assert on the exact failure site.
    #[error("OOM on device {device}: requested {requested} B, used {used} B of {capacity} B")]
    Oom {
        device: usize,
        requested: u64,
        used: u64,
        capacity: u64,
    },

    /// Violation of a scheduling invariant (pipeline, dispatch, chunk).
    #[error("schedule error: {0}")]
    Schedule(String),

    /// PJRT runtime failure (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Underlying I/O failure.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor used across modules.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn schedule(msg: impl Into<String>) -> Self {
        Error::Schedule(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oom_message_carries_accounting() {
        let e = Error::Oom { device: 3, requested: 10, used: 60, capacity: 64 };
        let s = e.to_string();
        assert!(s.contains("device 3") && s.contains("10 B") && s.contains("64 B"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
