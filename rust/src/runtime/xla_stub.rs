//! Compile-time stand-in for the `xla` crate (xla_extension 0.5.1).
//!
//! The offline registry cannot carry the real `xla` dependency, so the
//! `pjrt`-gated execution path in [`super`] historically only compiled
//! in environments that patched the dependency in by hand — meaning CI
//! never type-checked it and drift went unnoticed. This module mirrors
//! exactly the slice of the `xla` API that `runtime` uses, letting
//! `cargo check --features pjrt` compile the whole execution path
//! against it (the CI stub compile check).
//!
//! With the real crate present, enable the `xla-backend` feature as
//! well (and add the path dependency per `Cargo.toml`); this module is
//! then compiled out and `xla::...` resolves to the real crate.
//!
//! Behavior: constructing the client succeeds (so `ArtifactStore::open`
//! keeps serving manifest metadata exactly like a no-`pjrt` build),
//! and every compile/execute entry point returns [`XlaError`], which
//! the callers surface as their usual `Error::Runtime` degradation.

#![allow(dead_code)]

/// Error type standing in for `xla::Error`; callers only format it
/// with `{:?}`.
#[derive(Debug)]
pub struct XlaError(pub &'static str);

type XlaResult<T> = std::result::Result<T, XlaError>;

const NO_BACKEND: &str =
    "xla stub: built with `pjrt` but without the real `xla` crate \
     (enable the `xla-backend` feature in an environment that has it)";

/// Stub of `xla::PjRtClient`.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Succeeds so manifest-only workflows behave like a no-`pjrt`
    /// build; execution fails later, at `compile`.
    pub fn cpu() -> XlaResult<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(XlaError(NO_BACKEND))
    }
}

/// Stub of `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<HloModuleProto> {
        Err(XlaError(NO_BACKEND))
    }
}

/// Stub of `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(NO_BACKEND))
    }
}

/// Stub of `xla::PjRtBuffer` (the device buffers `execute` returns).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(XlaError(NO_BACKEND))
    }
}

/// Stub of `xla::Literal`.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> XlaResult<Literal> {
        Err(XlaError(NO_BACKEND))
    }

    pub fn decompose_tuple(&mut self) -> XlaResult<Vec<Literal>> {
        Err(XlaError(NO_BACKEND))
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(XlaError(NO_BACKEND))
    }
}
