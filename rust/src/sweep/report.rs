//! Sweep reduction: per-scenario rows and per-(model, method) cell
//! aggregates, serialised as deterministic JSON.
//!
//! The reducer is **streaming**: workers hand over flat
//! [`ScenarioResult`]s (≈100 bytes each) the moment a scenario
//! finishes, and [`SweepReducer`] folds them into per-cell
//! accumulators incrementally — the heavyweight
//! [`RunOutcome`](crate::sim::RunOutcome)s (every iteration × layer
//! trace) die inside the worker, so sweep memory is O(cells) of
//! aggregate state plus the flat rows the artifact itself carries,
//! never O(scenarios × iterations × layers).
//!
//! **Ordering guarantee:** every float accumulates in ascending grid
//! index order, regardless of arrival order. The reducer folds the
//! contiguous frontier as results stream in and folds any remaining
//! (sparse, e.g. sharded) rows index-ascending at `finish()` — both
//! paths visit rows in the same total order, so the emitted bytes are
//! identical for any worker count, shard split, or resume point. The
//! integration suite asserts this bit-for-bit.
//!
//! The aggregates are the paper's own headline quantities: average TGS
//! (Eq. 10) over trained runs, OOM rates (Eq. 3 violations), peak
//! activation bytes (Eq. 2), and the memory-model deltas of each
//! method against Method 1 (Table 4's reduction percentages) — the
//! deltas are computed from the folded cell aggregates alone, so no
//! per-scenario state is retained for them either.

use crate::bench::{fmt_time, BenchReport};
use crate::config::SweepConfig;
use crate::json::{self, Value};
use crate::sim::{RunOutcome, RunSummary};
use crate::sweep::grid::Scenario;
use crate::sweep::pool::PoolStats;
use crate::trace::provenance::TraceProvenance;
use crate::util::fmt_bytes;

/// Flat result of one scenario — everything the aggregation and the
/// JSON artifact need, nothing the thread scheduler could perturb.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioResult {
    pub index: usize,
    pub model: String,
    pub method: String,
    pub seed: u64,
    pub iterations: u64,
    pub trained: bool,
    pub oom_iterations: u64,
    pub avg_tgs: f64,
    pub peak_act_bytes: u64,
    pub peak_total_bytes: u64,
    pub static_bytes: u64,
}

impl ScenarioResult {
    pub fn new(scenario: &Scenario, out: &RunOutcome) -> Self {
        ScenarioResult {
            index: scenario.index,
            model: scenario.model.clone(),
            method: scenario.method.name(),
            seed: scenario.seed,
            iterations: out.iterations.len() as u64,
            trained: out.trained(),
            oom_iterations: out.oom_iterations,
            avg_tgs: out.avg_tgs,
            peak_act_bytes: out.peak_act_bytes,
            peak_total_bytes: out
                .iterations
                .iter()
                .map(|i| i.peak_total_bytes)
                .max()
                .unwrap_or(0),
            static_bytes: out.static_bytes,
        }
    }

    /// Build a row from a fused-evaluation [`RunSummary`] — field for
    /// field the same mapping as [`ScenarioResult::new`] (the summary
    /// carries `peak_total_bytes` pre-folded), so the fused sweep path
    /// emits byte-identical rows without ever materialising a
    /// [`RunOutcome`].
    pub fn from_summary(scenario: &Scenario, s: &RunSummary) -> Self {
        ScenarioResult {
            index: scenario.index,
            model: scenario.model.clone(),
            method: scenario.method.name(),
            seed: scenario.seed,
            iterations: s.iterations,
            trained: s.trained(),
            oom_iterations: s.oom_iterations,
            avg_tgs: s.avg_tgs,
            peak_act_bytes: s.peak_act_bytes,
            peak_total_bytes: s.peak_total_bytes,
            static_bytes: s.static_bytes,
        }
    }

    /// Serialise one row — also the checkpoint line payload, so the
    /// fields must round-trip exactly (integers stay ≤ 2⁵³; floats go
    /// through the writer's shortest-round-trip formatting).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("index", json::num(self.index as f64)),
            ("model", json::s(self.model.clone())),
            ("method", json::s(self.method.clone())),
            ("seed", json::num(self.seed as f64)),
            ("iterations", json::num(self.iterations as f64)),
            ("trained", Value::Bool(self.trained)),
            ("oom_iterations", json::num(self.oom_iterations as f64)),
            ("avg_tgs", json::num(self.avg_tgs)),
            ("peak_act_bytes", json::num(self.peak_act_bytes as f64)),
            ("peak_total_bytes", json::num(self.peak_total_bytes as f64)),
            ("static_bytes", json::num(self.static_bytes as f64)),
        ])
    }

    /// Parse a row back (checkpoint resume path).
    pub fn from_json(v: &Value) -> crate::Result<Self> {
        Ok(ScenarioResult {
            index: v.req_u64("index")? as usize,
            model: v.req_str("model")?.to_string(),
            method: v.req_str("method")?.to_string(),
            seed: v.req_u64("seed")?,
            iterations: v.req_u64("iterations")?,
            trained: v
                .get("trained")
                .and_then(Value::as_bool)
                .ok_or_else(|| crate::Error::config("row missing 'trained'"))?,
            oom_iterations: v.req_u64("oom_iterations")?,
            avg_tgs: v.req_f64("avg_tgs")?,
            peak_act_bytes: v.req_u64("peak_act_bytes")?,
            peak_total_bytes: v.req_u64("peak_total_bytes")?,
            static_bytes: v.req_u64("static_bytes")?,
        })
    }
}

/// Aggregate of one (model, method) cell across its seeds.
#[derive(Clone, Debug, PartialEq)]
pub struct CellStats {
    pub model: String,
    pub method: String,
    pub runs: u64,
    pub trained_runs: u64,
    /// Fraction of runs with at least one OOM iteration.
    pub oom_run_rate: f64,
    /// Fraction of simulated iterations that violated Eq. 3.
    pub oom_iteration_rate: f64,
    /// Mean of per-run average TGS over trained runs (0 if none).
    pub avg_tgs: f64,
    /// Worst activation peak across the cell's runs (Eq. 2).
    pub peak_act_bytes: u64,
    /// Worst total (static + activation) peak across runs.
    pub peak_total_bytes: u64,
    pub static_bytes: u64,
    /// Memory-model delta vs the same model's Method 1 cell:
    /// activation reduction in percent (Table 4's headline), when a
    /// Method 1 cell exists in the grid.
    pub act_reduction_vs_m1_pct: Option<f64>,
    /// TGS delta vs Method 1 in percent, when Method 1 trained.
    pub tgs_vs_m1_pct: Option<f64>,
}

impl CellStats {
    fn to_json(&self) -> Value {
        let opt = |v: Option<f64>| v.map(json::num).unwrap_or(Value::Null);
        json::obj(vec![
            ("model", json::s(self.model.clone())),
            ("method", json::s(self.method.clone())),
            ("runs", json::num(self.runs as f64)),
            ("trained_runs", json::num(self.trained_runs as f64)),
            ("oom_run_rate", json::num(self.oom_run_rate)),
            ("oom_iteration_rate", json::num(self.oom_iteration_rate)),
            ("avg_tgs", json::num(self.avg_tgs)),
            ("peak_act_bytes", json::num(self.peak_act_bytes as f64)),
            ("peak_total_bytes", json::num(self.peak_total_bytes as f64)),
            ("static_bytes", json::num(self.static_bytes as f64)),
            ("act_reduction_vs_m1_pct", opt(self.act_reduction_vs_m1_pct)),
            ("tgs_vs_m1_pct", opt(self.tgs_vs_m1_pct)),
        ])
    }
}

/// The aggregated outcome of a sweep. Note: the worker count is
/// deliberately NOT part of the report — identical grids must emit
/// identical bytes however they were scheduled. The trace provenance
/// (sampler + RNG version) IS part of it: it decides the drawn sample,
/// and stamping it makes every artifact self-describing under the
/// default-sampler flip.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub config: SweepConfig,
    /// What the routing traces were drawn under.
    pub provenance: TraceProvenance,
    pub scenarios: Vec<ScenarioResult>,
    pub cells: Vec<CellStats>,
}

/// Running aggregate of one (model, method) cell — everything
/// [`CellStats`] derives from, foldable one [`ScenarioResult`] at a
/// time. Order sensitivity lives entirely in `tgs_sum` (float
/// accumulation), which is why the reducer pins the fold order.
#[derive(Clone, Debug, Default)]
struct CellAccumulator {
    runs: u64,
    trained_runs: u64,
    /// Sum of per-run avg TGS over trained runs, folded in ascending
    /// grid index order.
    tgs_sum: f64,
    total_iters: u64,
    oom_iters: u64,
    peak_act_bytes: u64,
    peak_total_bytes: u64,
    static_bytes: u64,
}

impl CellAccumulator {
    fn fold(&mut self, r: &ScenarioResult) {
        self.runs += 1;
        if r.trained {
            self.trained_runs += 1;
            self.tgs_sum += r.avg_tgs;
        }
        self.total_iters += r.iterations;
        self.oom_iters += r.oom_iterations;
        self.peak_act_bytes = self.peak_act_bytes.max(r.peak_act_bytes);
        self.peak_total_bytes = self.peak_total_bytes.max(r.peak_total_bytes);
        self.static_bytes = self.static_bytes.max(r.static_bytes);
    }

    fn stats(&self, model: &str, method: &str) -> CellStats {
        CellStats {
            model: model.to_string(),
            method: method.to_string(),
            runs: self.runs,
            trained_runs: self.trained_runs,
            oom_run_rate: (self.runs - self.trained_runs) as f64 / self.runs as f64,
            oom_iteration_rate: if self.total_iters == 0 {
                0.0
            } else {
                self.oom_iters as f64 / self.total_iters as f64
            },
            avg_tgs: if self.trained_runs == 0 {
                0.0
            } else {
                self.tgs_sum / self.trained_runs as f64
            },
            peak_act_bytes: self.peak_act_bytes,
            peak_total_bytes: self.peak_total_bytes,
            static_bytes: self.static_bytes,
            act_reduction_vs_m1_pct: None,
            tgs_vs_m1_pct: None,
        }
    }
}

/// Streaming sweep reduction: results arrive in any order (worker
/// completion, checkpoint replay, shard merge), get buffered by grid
/// index, and fold into [`CellAccumulator`]s strictly
/// **index-ascending** — the contiguous frontier folds as results
/// stream in; anything left sparse (sharded or `--limit`ed runs) folds
/// index-ascending at [`SweepReducer::finish`]. Since both paths visit
/// rows in the same total order, the finished report depends only on
/// the *set* of results, never on arrival order — the reducer-level
/// statement of the sweep determinism contract.
pub struct SweepReducer {
    config: SweepConfig,
    provenance: TraceProvenance,
    n_seeds: usize,
    rows: Vec<Option<ScenarioResult>>,
    folded: Vec<bool>,
    frontier: usize,
    cells: Vec<CellAccumulator>,
}

impl SweepReducer {
    pub fn new(config: SweepConfig, provenance: TraceProvenance) -> crate::Result<Self> {
        config.validate()?;
        let n = config.scenario_count();
        let n_cells = config.models.len() * config.methods.len();
        Ok(SweepReducer {
            n_seeds: config.seeds.len(),
            rows: (0..n).map(|_| None).collect(),
            folded: vec![false; n],
            frontier: 0,
            cells: vec![CellAccumulator::default(); n_cells],
            config,
            provenance,
        })
    }

    /// Number of results received so far.
    pub fn received(&self) -> usize {
        self.rows.iter().flatten().count()
    }

    /// Hand one result to the reducer. Panics on an out-of-grid index
    /// or a duplicate — both are caller bugs (the checkpoint layer
    /// dedups by scenario hash before results reach here).
    pub fn push(&mut self, r: ScenarioResult) {
        let idx = r.index;
        assert!(idx < self.rows.len(), "scenario index {idx} outside the grid");
        assert!(self.rows[idx].is_none(), "scenario index {idx} delivered twice");
        self.rows[idx] = Some(r);
        while self.frontier < self.rows.len() && self.rows[self.frontier].is_some() {
            self.fold_row(self.frontier);
            self.frontier += 1;
        }
    }

    fn fold_row(&mut self, idx: usize) {
        debug_assert!(!self.folded[idx]);
        let row = self.rows[idx].as_ref().expect("row present");
        // grid order is (model, method, seed): index / seeds = cell id
        // in (model-major, method-minor) enumeration
        let cell = idx / self.n_seeds;
        self.cells[cell].fold(row);
        self.folded[idx] = true;
    }

    /// Finish the reduction. Folds any still-unfolded rows in
    /// ascending index order (sparse grids: shards, limited runs),
    /// derives the per-cell stats in the config's model × method
    /// enumeration order (skipping cells with no runs), and computes
    /// the Table-4 deltas vs each model's Method 1 cell from the
    /// folded aggregates alone.
    pub fn finish(mut self) -> SweepReport {
        for idx in 0..self.rows.len() {
            if self.rows[idx].is_some() && !self.folded[idx] {
                self.fold_row(idx);
            }
        }
        let mut cells = Vec::with_capacity(self.cells.len());
        for (mi, model) in self.config.models.iter().enumerate() {
            for (me, method) in self.config.methods.iter().enumerate() {
                let acc = &self.cells[mi * self.config.methods.len() + me];
                if acc.runs == 0 {
                    continue;
                }
                cells.push(acc.stats(model, &method.name()));
            }
        }
        // Second pass: memory-model deltas vs each model's Method 1
        // cell (Table 4's reduction column).
        let m1_name = crate::config::Method::FullRecompute.name();
        let baselines: Vec<(String, u64, f64, u64)> = cells
            .iter()
            .filter(|c| c.method == m1_name)
            .map(|c| (c.model.clone(), c.peak_act_bytes, c.avg_tgs, c.trained_runs))
            .collect();
        for cell in &mut cells {
            if cell.method == m1_name {
                continue;
            }
            if let Some((_, m1_act, m1_tgs, m1_trained)) =
                baselines.iter().find(|(m, ..)| *m == cell.model)
            {
                if *m1_act > 0 {
                    cell.act_reduction_vs_m1_pct =
                        Some(100.0 * (1.0 - cell.peak_act_bytes as f64 / *m1_act as f64));
                }
                // a TGS delta needs throughput data on BOTH sides: a
                // cell that never trained has no measurement, not a
                // −100 % slowdown.
                if *m1_trained > 0 && *m1_tgs > 0.0 && cell.trained_runs > 0 {
                    cell.tgs_vs_m1_pct = Some(100.0 * (cell.avg_tgs / m1_tgs - 1.0));
                }
            }
        }
        SweepReport {
            config: self.config,
            provenance: self.provenance,
            scenarios: self.rows.into_iter().flatten().collect(),
            cells,
        }
    }
}

impl SweepReport {
    /// Reduce scenario results (any order) into the report via
    /// [`SweepReducer`] — retained as the collect-then-reduce
    /// convenience; the sweep engine streams into the reducer
    /// directly.
    pub fn build(
        config: SweepConfig,
        provenance: TraceProvenance,
        results: Vec<ScenarioResult>,
    ) -> Self {
        let mut reducer =
            SweepReducer::new(config, provenance).expect("valid sweep config");
        for r in results {
            reducer.push(r);
        }
        reducer.finish()
    }

    /// Deterministic JSON artifact (sorted keys, fixed array order).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("config", self.config.to_json()),
            ("provenance", self.provenance.to_json()),
            (
                "scenarios",
                json::arr(self.scenarios.iter().map(ScenarioResult::to_json).collect()),
            ),
            (
                "cells",
                json::arr(self.cells.iter().map(CellStats::to_json).collect()),
            ),
        ])
    }

    /// Human-readable per-cell table for the CLI.
    pub fn render_table(&self) -> String {
        let mut report = BenchReport::new(
            &format!(
                "sweep — {} scenarios ({} models × {} methods × {} seeds, {} iters)",
                self.scenarios.len(),
                self.config.models.len(),
                self.config.methods.len(),
                self.config.seeds.len(),
                self.config.iterations
            ),
            &[
                "model", "method", "trained", "OOM iter %", "avg TGS", "peak act",
                "Δact vs m1", "ΔTGS vs m1",
            ],
        );
        for c in &self.cells {
            let pct = |v: Option<f64>| {
                v.map(|x| format!("{x:+.1} %")).unwrap_or_else(|| "-".into())
            };
            report.row(&[
                c.model.clone(),
                c.method.clone(),
                format!("{}/{}", c.trained_runs, c.runs),
                format!("{:.1}", 100.0 * c.oom_iteration_rate),
                format!("{:.0}", c.avg_tgs),
                fmt_bytes(c.peak_act_bytes),
                pct(c.act_reduction_vs_m1_pct),
                pct(c.tgs_vs_m1_pct),
            ]);
        }
        report.render()
    }
}

/// Human-readable per-worker table of one pool run's execution facts.
/// Stderr/bench surface only: [`PoolStats`] are scheduling facts, and
/// the determinism contract forbids them from ever entering the JSON
/// artifact — note [`SweepReport::to_json`] takes no pool input.
pub fn render_pool_stats(stats: &PoolStats) -> String {
    let mut report = BenchReport::new(
        &format!(
            "pool — {}/{}: {} job(s) on {} worker(s) ({} pinned), wall {}, tail latency {}",
            stats.schedule.tag(),
            stats.channel.tag(),
            stats.jobs_total(),
            stats.workers.len(),
            stats.pinned_workers(),
            fmt_time(stats.wall_ns as f64 / 1e9),
            fmt_time(stats.tail_latency_ns() as f64 / 1e9),
        ),
        &["worker", "jobs", "steals ok/try", "max depth", "busy", "pinned"],
    );
    for (k, w) in stats.workers.iter().enumerate() {
        report.row(&[
            k.to_string(),
            w.jobs.to_string(),
            format!("{}/{}", w.steals_succeeded, w.steals_attempted),
            w.max_queue_depth.to_string(),
            fmt_time(w.busy_ns as f64 / 1e9),
            if w.pinned { "yes".into() } else { "no".into() },
        ]);
    }
    report.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::trace::provenance::TraceProvenance;

    fn result(
        index: usize,
        model: &str,
        method: &Method,
        seed: u64,
        trained: bool,
        avg_tgs: f64,
        peak_act: u64,
    ) -> ScenarioResult {
        ScenarioResult {
            index,
            model: model.into(),
            method: method.name(),
            seed,
            iterations: 10,
            trained,
            oom_iterations: if trained { 0 } else { 4 },
            avg_tgs,
            peak_act_bytes: peak_act,
            peak_total_bytes: peak_act + 1000,
            static_bytes: 500,
        }
    }

    fn two_cell_config() -> SweepConfig {
        SweepConfig {
            models: vec!["i".into()],
            methods: vec![Method::FullRecompute, Method::FixedChunk(8)],
            seeds: vec![1, 2],
            iterations: 10,
        }
    }

    #[test]
    fn build_sorts_and_aggregates() {
        let m1 = Method::FullRecompute;
        let m2 = Method::FixedChunk(8);
        // shuffled input order — build must sort by index
        let results = vec![
            result(3, "i", &m2, 2, true, 120.0, 400),
            result(0, "i", &m1, 1, true, 100.0, 1000),
            result(2, "i", &m2, 1, true, 110.0, 500),
            result(1, "i", &m1, 2, false, 0.0, 1200),
        ];
        let report = SweepReport::build(two_cell_config(), TraceProvenance::default(), results);
        assert_eq!(
            report.scenarios.iter().map(|r| r.index).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        assert_eq!(report.cells.len(), 2);
        let c1 = &report.cells[0];
        assert_eq!(c1.method, m1.name());
        assert_eq!(c1.runs, 2);
        assert_eq!(c1.trained_runs, 1);
        assert_eq!(c1.oom_run_rate, 0.5);
        assert_eq!(c1.oom_iteration_rate, 4.0 / 20.0);
        assert_eq!(c1.avg_tgs, 100.0); // only the trained run counts
        assert_eq!(c1.peak_act_bytes, 1200);
        let c2 = &report.cells[1];
        assert_eq!(c2.avg_tgs, 115.0);
        assert_eq!(c2.peak_act_bytes, 500);
        // deltas vs m1: 500 vs 1200 → 58.33 % reduction
        let red = c2.act_reduction_vs_m1_pct.unwrap();
        assert!((red - 100.0 * (1.0 - 500.0 / 1200.0)).abs() < 1e-9);
        let tgs = c2.tgs_vs_m1_pct.unwrap();
        assert!((tgs - 15.0).abs() < 1e-9);
        assert!(c1.act_reduction_vs_m1_pct.is_none());
    }

    #[test]
    fn json_is_input_order_independent() {
        let m1 = Method::FullRecompute;
        let m2 = Method::FixedChunk(8);
        let a = vec![
            result(0, "i", &m1, 1, true, 100.0, 1000),
            result(1, "i", &m1, 2, true, 101.0, 1100),
            result(2, "i", &m2, 1, true, 110.0, 500),
            result(3, "i", &m2, 2, true, 120.0, 400),
        ];
        let mut b = a.clone();
        b.reverse();
        let ja = SweepReport::build(two_cell_config(), TraceProvenance::default(), a).to_json().to_string_pretty();
        let jb = SweepReport::build(two_cell_config(), TraceProvenance::default(), b).to_json().to_string_pretty();
        assert_eq!(ja, jb);
        // and the artifact reparses
        crate::json::parse(&ja).unwrap();
    }

    #[test]
    fn reducer_arrival_order_does_not_change_bytes() {
        let m1 = Method::FullRecompute;
        let m2 = Method::FixedChunk(8);
        let rows = vec![
            result(0, "i", &m1, 1, true, 100.0, 1000),
            result(1, "i", &m1, 2, false, 0.0, 1200),
            result(2, "i", &m2, 1, true, 110.25, 500),
            result(3, "i", &m2, 2, true, 120.75, 400),
        ];
        // streamed in-order vs streamed reversed vs build()
        let mut fwd = SweepReducer::new(two_cell_config(), TraceProvenance::default()).unwrap();
        for r in rows.clone() {
            fwd.push(r);
        }
        let mut rev = SweepReducer::new(two_cell_config(), TraceProvenance::default()).unwrap();
        for r in rows.iter().rev().cloned() {
            rev.push(r);
        }
        let a = fwd.finish().to_json().to_string_pretty();
        let b = rev.finish().to_json().to_string_pretty();
        let c = SweepReport::build(two_cell_config(), TraceProvenance::default(), rows)
            .to_json()
            .to_string_pretty();
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn reducer_partial_grid_folds_sparse_rows() {
        // A shard that only ran (m2, seed 2): one row, index 3.
        let m2 = Method::FixedChunk(8);
        let mut red = SweepReducer::new(two_cell_config(), TraceProvenance::default()).unwrap();
        red.push(result(3, "i", &m2, 2, true, 120.0, 400));
        assert_eq!(red.received(), 1);
        let report = red.finish();
        assert_eq!(report.scenarios.len(), 1);
        assert_eq!(report.cells.len(), 1); // empty m1 cell skipped
        assert_eq!(report.cells[0].runs, 1);
        // no m1 baseline present → no delta
        assert!(report.cells[0].act_reduction_vs_m1_pct.is_none());
    }

    #[test]
    #[should_panic(expected = "delivered twice")]
    fn reducer_rejects_duplicate_index() {
        let m1 = Method::FullRecompute;
        let mut red = SweepReducer::new(two_cell_config(), TraceProvenance::default()).unwrap();
        red.push(result(0, "i", &m1, 1, true, 100.0, 1000));
        red.push(result(0, "i", &m1, 1, true, 100.0, 1000));
    }

    #[test]
    fn scenario_result_json_roundtrip_exact() {
        let m2 = Method::FixedChunk(8);
        let mut r = result(5, "ii", &m2, 9, true, 0.1 + 0.2, 123_456_789_012);
        r.avg_tgs = 12345.678901234567;
        let v = r.to_json();
        let text = v.to_string_compact();
        let back = ScenarioResult::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        // float round-trips to the exact same bits — the resume path's
        // byte-identity depends on it
        assert_eq!(back.avg_tgs.to_bits(), r.avg_tgs.to_bits());
    }

    #[test]
    fn pool_stats_table_renders_execution_facts() {
        use crate::sweep::pool::WorkerStats;
        let stats = PoolStats {
            workers: vec![
                WorkerStats {
                    jobs: 3,
                    steals_attempted: 4,
                    steals_succeeded: 2,
                    max_queue_depth: 5,
                    busy_ns: 1_000_000,
                    pinned: true,
                },
                WorkerStats { jobs: 1, ..WorkerStats::default() },
            ],
            wall_ns: 2_000_000,
            ..PoolStats::default()
        };
        let table = render_pool_stats(&stats);
        assert!(table.contains("stealing/bounded"));
        assert!(table.contains("4 job(s)"));
        assert!(table.contains("2/4"));
        assert!(table.contains("1 pinned"));
    }

    #[test]
    fn table_renders_all_cells() {
        let m1 = Method::FullRecompute;
        let results = vec![result(0, "i", &m1, 1, true, 100.0, 1000)];
        let mut cfg = two_cell_config();
        cfg.methods = vec![m1];
        cfg.seeds = vec![1];
        let table = SweepReport::build(cfg, TraceProvenance::default(), results).render_table();
        assert!(table.contains("method1/full-recompute"));
        assert!(table.contains("1/1"));
    }
}
