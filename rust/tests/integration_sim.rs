//! Cross-module integration + property tests for the simulation stack:
//! routing → dispatch → chunking → memory → perf → simulator, using the
//! crate's own property-testing harness (no proptest offline).

use memfine::chunk::{split_chunks, Mact, RecomputeSchedule};
use memfine::config::{model_i, model_ii, paper_parallel, paper_run, Method};
use memfine::dispatch;
use memfine::memory::{ActivationModel, StaticModel};
use memfine::prop::{assert_prop, Gen, PairGen, U64Range};
use memfine::router::{per_rank_from_experts, GatingSim};
use memfine::sim::Simulator;
use memfine::util::rng::Rng;

/// Generator for random top-k assignments over a small EP group.
struct AssignGen {
    ranks: usize,
    tokens: usize,
    experts: u32,
    top_k: usize,
}

impl Gen for AssignGen {
    type Value = Vec<Vec<Vec<u32>>>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (0..self.ranks)
            .map(|_| {
                (0..self.tokens)
                    .map(|_| {
                        let mut picks = Vec::with_capacity(self.top_k);
                        while picks.len() < self.top_k {
                            let e = rng.below(self.experts as u64) as u32;
                            if !picks.contains(&e) {
                                picks.push(e);
                            }
                        }
                        picks
                    })
                    .collect()
            })
            .collect()
    }
}

fn small_parallel(ep: u64) -> memfine::config::ParallelConfig {
    let mut p = paper_parallel();
    p.ep = ep;
    p
}

#[test]
fn prop_dispatch_conserves_and_places_uniquely() {
    let gen = AssignGen { ranks: 4, tokens: 24, experts: 16, top_k: 2 };
    assert_prop(11, 40, &gen, |assign| {
        let plan = dispatch::plan(&small_parallel(4), 16, assign, 24 * 2 * 4)
            .map_err(|e| e.to_string())?;
        let copies = 4 * 24 * 2;
        if plan.placements.len() != copies {
            return Err(format!("placements {} != {copies}", plan.placements.len()));
        }
        if plan.overflow != 0 {
            return Err(format!("drop-free capacity overflowed: {}", plan.overflow));
        }
        // unique slots
        let mut seen = std::collections::HashSet::new();
        for p in &plan.placements {
            let key = (p.dst_rank, p.local_expert, p.slot.unwrap());
            if !seen.insert(key) {
                return Err(format!("duplicate slot {key:?}"));
            }
        }
        // received == column sums of send matrix == expert ownership
        let recv = plan.received_per_rank();
        if recv.iter().sum::<u64>() != copies as u64 {
            return Err("received copies not conserved".into());
        }
        Ok(())
    });
}

#[test]
fn prop_combine_roundtrip_identity_top1() {
    let gen = AssignGen { ranks: 4, tokens: 16, experts: 8, top_k: 1 };
    assert_prop(13, 30, &gen, |assign| {
        let plan = dispatch::plan(&small_parallel(4), 8, assign, 16 * 4)
            .map_err(|e| e.to_string())?;
        let out = dispatch::combine_scalar(
            &plan,
            &[16, 16, 16, 16],
            |p| (p.route.src_rank as usize * 1000 + p.route.token as usize) as f64,
            |_| 1.0,
        );
        for (src, tokens) in out.iter().enumerate() {
            for (tok, &v) in tokens.iter().enumerate() {
                if v != (src * 1000 + tok) as f64 {
                    return Err(format!("roundtrip broke at ({src},{tok}): {v}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_chunk_split_conserves_tokens() {
    let gen = PairGen(U64Range(1, 100_000), U64Range(1, 64));
    assert_prop(17, 300, &gen, |&(tokens, c)| {
        let chunks = split_chunks(tokens, c);
        let total: u64 = chunks.iter().map(|ch| ch.len).sum();
        if total != tokens {
            return Err(format!("sum {total} != {tokens}"));
        }
        if chunks.iter().any(|ch| ch.len == 0) {
            return Err("empty chunk".into());
        }
        // contiguity
        let mut expect = 0;
        for ch in &chunks {
            if ch.start != expect {
                return Err(format!("gap at chunk {}", ch.index));
            }
            expect += ch.len;
        }
        // balanced: max−min ≤ 1
        let max = chunks.iter().map(|c| c.len).max().unwrap();
        let min = chunks.iter().map(|c| c.len).min().unwrap();
        if max - min > 1 {
            return Err(format!("imbalanced split {min}..{max}"));
        }
        Ok(())
    });
}

#[test]
fn prop_recompute_schedule_valid_and_single_chunk_peak() {
    let gen = PairGen(U64Range(1, 50_000), U64Range(1, 16));
    assert_prop(19, 200, &gen, |&(tokens, c)| {
        let s = RecomputeSchedule::build(tokens, c);
        if !s.validate() {
            return Err("invalid schedule".into());
        }
        let peak = s.peak_live_cost(|len| len);
        let max_chunk = s.chunks.iter().map(|ch| ch.len).max().unwrap_or(0);
        if peak != max_chunk {
            return Err(format!("peak {peak} != max chunk {max_chunk}"));
        }
        Ok(())
    });
}

#[test]
fn prop_mact_decision_respects_budget_when_feasible() {
    let run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
    let mact = Mact::new(&run, vec![1, 2, 4, 8]);
    let gen = PairGen(U64Range(0, 3), U64Range(1, 1_048_576));
    assert_prop(23, 400, &gen, |&(stage, s_recv)| {
        let d = mact.decide(stage, s_recv);
        if d.feasible {
            let per_chunk = s_recv.div_ceil(d.chosen_c);
            if per_chunk > d.s_prime_max {
                return Err(format!(
                    "feasible decision violates Eq.8: {per_chunk} > {}",
                    d.s_prime_max
                ));
            }
        }
        // chosen bin must be a configured bin
        if ![1, 2, 4, 8].contains(&d.chosen_c) {
            return Err(format!("non-bin chunk {}", d.chosen_c));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_model_monotone_in_s_recv_and_chunks() {
    let run = paper_run(model_i(), Method::FullRecompute);
    let act = ActivationModel::new(&run);
    let gen = PairGen(U64Range(0, 1_000_000), U64Range(1, 32));
    assert_prop(29, 300, &gen, |&(s_recv, c)| {
        let a = act.peak_bytes_chunked(1, s_recv, c, true);
        let b = act.peak_bytes_chunked(1, s_recv + 10_000, c, true);
        if b < a {
            return Err("not monotone in s'".into());
        }
        let d = act.peak_bytes_chunked(1, s_recv, c + 1, true);
        if d > a {
            return Err(format!("more chunks increased memory: {d} > {a}"));
        }
        Ok(())
    });
}

#[test]
fn prop_routing_conservation_any_seed() {
    let gen = PairGen(U64Range(0, 1000), U64Range(3, 15));
    assert_prop(31, 25, &gen, |&(seed, layer)| {
        let sim = GatingSim::new(model_i(), paper_parallel(), seed);
        let r = sim.route(seed % 25, layer);
        if r.per_expert.iter().sum::<u64>() != sim.total_copies() {
            return Err("per-expert not conserved".into());
        }
        if r.per_rank.iter().sum::<u64>() != sim.total_copies() {
            return Err("per-rank not conserved".into());
        }
        if per_rank_from_experts(&r.per_expert, 32) != r.per_rank {
            return Err("per-rank mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn simulator_end_to_end_table4_invariants() {
    // The three Table-4 relations must hold at arbitrary seeds, not
    // just the calibrated one.
    for seed in [3u64, 7, 42] {
        let mk = |model: memfine::config::ModelConfig, m: Method| {
            let mut run = paper_run(model, m);
            run.seed = seed;
            run.iterations = 20;
            Simulator::new(run).unwrap().run_all()
        };
        let m1 = mk(model_i(), Method::FullRecompute);
        let m2 = mk(model_i(), Method::FixedChunk(8));
        let m3 = mk(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        assert!(m2.trained(), "seed {seed}: m2 must train");
        assert!(m3.trained(), "seed {seed}: m3 must train");
        assert!(m2.peak_act_bytes < m3.peak_act_bytes);
        assert!(m3.peak_act_bytes < m1.peak_act_bytes);
        // Model II method 1 trains
        let m1_ii = mk(model_ii(), Method::FullRecompute);
        assert!(m1_ii.trained(), "seed {seed}: model II m1 must train");
    }
}

#[test]
fn simulator_static_matches_memory_model() {
    let run = paper_run(model_i(), Method::FullRecompute);
    let sta = StaticModel::new(&run);
    let mut run2 = run.clone();
    run2.iterations = 1;
    let out = Simulator::new(run2).unwrap().run_all();
    assert_eq!(out.static_bytes, sta.max_bytes());
}

#[test]
fn mact_bins_cover_fixed_methods() {
    // A MACT run restricted to a single bin must behave like the fixed
    // method with that bin (same chunk decisions everywhere).
    let mut run_fixed = paper_run(model_i(), Method::FixedChunk(8));
    run_fixed.iterations = 5;
    let mut run_mact = paper_run(model_i(), Method::Mact(vec![8]));
    run_mact.iterations = 5;
    let f = Simulator::new(run_fixed).unwrap().run_all();
    let m = Simulator::new(run_mact).unwrap().run_all();
    assert_eq!(f.chunks.records, m.chunks.records);
    assert_eq!(f.peak_act_bytes, m.peak_act_bytes);
}
