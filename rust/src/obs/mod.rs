//! Sidecar observability: log-bucketed timing histograms and a
//! best-effort, append-only JSON-lines campaign event log.
//!
//! Everything in this module is **strictly sidecar** to the sweep
//! engine's determinism contract: telemetry never participates in
//! scenario hashing or campaign identity, never perturbs artifact
//! bytes, and never fails a sweep. Event emission degrades to counting
//! dropped events on any I/O failure; opening an event log on an
//! unwritable path degrades to a disabled log plus one warning.
//!
//! The event log is one JSON object per line, appended with a single
//! `write_all` to an `O_APPEND` handle so concurrent shard processes
//! sharing `<dir>/events.jsonl` interleave whole lines. Every event
//! carries a monotonic `t_ms` stamp (the shared [`crate::logging`]
//! clock), the emitting `pid`, and a `type` tag; domain fields (shard
//! index, scenario hash, durations) ride alongside, so events join
//! against checkpoint rows and artifacts by hash. The reader applies
//! the checkpoint reader's torn-tail contract: lines that fail to
//! parse (the kill-mid-write case) are skipped and counted, never
//! fatal.
//!
//! [`Histogram`] is the mergeable replacement for ad-hoc
//! [`crate::metrics::Timer`] aggregation: 65 log-spaced buckets (one
//! per power of two of a `u64` observation, bucket 0 for zero), so
//! merge is elementwise addition — associative and commutative, which
//! is what lets per-shard histograms fold into one campaign view in
//! any order.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::Result;
use crate::json::{self, Value};

pub mod degrade;
pub mod watch;

pub use degrade::{DegradeLadder, LadderVerdict};
pub use watch::{Alert, WatchConfig, Watchdog};

/// Longest single event line either reader will buffer. Longer lines
/// are drained in bounded chunks and dropped with a counted skip, so
/// a corrupt log cannot balloon the reader's memory.
pub const MAX_EVENT_LINE_BYTES: usize = 1 << 20;

/// Consecutive failed appends before the event log quarantines itself
/// (it keeps counting drops, but stops issuing syscalls).
const EVENT_LOG_QUARANTINE_AFTER: u32 = 8;

/// Number of histogram buckets: one for zero plus one per power of
/// two representable in a `u64`.
pub const HIST_BUCKETS: usize = 65;

/// Log-bucketed histogram of `u64` observations (typically
/// nanoseconds). Bucket 0 holds exact zeros; bucket `i >= 1` holds
/// `[2^(i-1), 2^i)`. Merging is elementwise addition, so shard
/// histograms combine associatively into campaign totals.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; HIST_BUCKETS], total: 0, sum: 0 }
    }

    /// Bucket index of a value: 0 for 0, else `floor(log2(v)) + 1`.
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// Inclusive upper bound of a bucket (saturating at `u64::MAX`).
    pub fn bucket_hi(index: usize) -> u64 {
        if index == 0 {
            0
        } else if index >= 64 {
            u64::MAX
        } else {
            (1u64 << index) - 1
        }
    }

    pub fn observe(&mut self, v: u64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Fold another histogram in: elementwise bucket addition.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Approximate quantile: the inclusive upper bound of the bucket
    /// where the cumulative count first reaches `q * total` (so the
    /// true value is within 2x below the returned bound). `q` is
    /// clamped to `[0, 1]`; an empty histogram reports 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_hi(i);
            }
        }
        Self::bucket_hi(HIST_BUCKETS - 1)
    }

    /// Raw bucket counts (length [`HIST_BUCKETS`]).
    pub fn buckets(&self) -> &[u64] {
        &self.counts
    }

    /// Summary object: `{count, sum, mean, p50, p99}` — the flat form
    /// folded into metric expositions and bench artifacts.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("count", json::num(self.total as f64)),
            ("sum", json::num(self.sum as f64)),
            ("mean", json::num(self.mean())),
            ("p50", json::num(self.quantile(0.5) as f64)),
            ("p99", json::num(self.quantile(0.99) as f64)),
        ])
    }
}

/// Best-effort append-only JSON-lines event sink.
///
/// A disabled log (no path configured, or the open failed) accepts
/// `emit` calls as no-ops; write failures on an open log increment
/// [`EventLog::dropped`] and are otherwise swallowed — telemetry never
/// fails the work it observes.
pub struct EventLog {
    inner: Option<Mutex<std::fs::File>>,
    dropped: AtomicU64,
    ladder: DegradeLadder,
    pid: u32,
}

fn event_log_ladder() -> DegradeLadder {
    DegradeLadder::new(
        crate::faultfs::SITE_EVENT_LOG,
        0,
        EVENT_LOG_QUARANTINE_AFTER,
    )
}

impl EventLog {
    /// A log that drops everything (telemetry off).
    pub fn disabled() -> Self {
        EventLog {
            inner: None,
            dropped: AtomicU64::new(0),
            ladder: event_log_ladder(),
            pid: std::process::id(),
        }
    }

    /// Open (create + append) the event log at `path`. Failure warns
    /// once and returns a disabled log — never an error.
    pub fn open(path: &Path) -> Self {
        match std::fs::OpenOptions::new().create(true).append(true).open(path) {
            Ok(f) => EventLog {
                inner: Some(Mutex::new(f)),
                dropped: AtomicU64::new(0),
                ladder: event_log_ladder(),
                pid: std::process::id(),
            },
            Err(e) => {
                crate::logging::warn(
                    "obs",
                    format!("event log disabled ({}: {e})", path.display()),
                );
                EventLog::disabled()
            }
        }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Events dropped by write failures since open.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Whether the append path quarantined itself after
    /// [`EVENT_LOG_QUARANTINE_AFTER`] consecutive write failures.
    pub fn quarantined(&self) -> bool {
        self.ladder.is_quarantined()
    }

    /// Append one event line: `t_ms` (monotonic, shared logging
    /// clock), `pid`, `type`, plus the caller's fields. One
    /// `write_all` per line so concurrent appenders interleave whole
    /// lines on `O_APPEND` handles. Failures climb the degradation
    /// ladder: each failed append is a counted drop, and persistent
    /// failure quarantines the log (drops keep counting, syscalls
    /// stop).
    pub fn emit(&self, kind: &str, fields: Vec<(&str, Value)>) {
        let Some(inner) = &self.inner else {
            return;
        };
        if self.ladder.is_quarantined() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut rows = vec![
            ("t_ms", json::num(crate::logging::elapsed_ms())),
            ("pid", json::num(self.pid as f64)),
            ("type", json::s(kind)),
        ];
        rows.extend(fields);
        let mut line = json::obj(rows).to_string_compact();
        line.push('\n');
        let mut f = match inner.lock() {
            Ok(f) => f,
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        let (_, verdict) = self.ladder.run(|| {
            crate::faultfs::check(crate::faultfs::SITE_EVENT_LOG)?;
            f.write_all(line.as_bytes()).map_err(crate::Error::Io)
        });
        if verdict != LadderVerdict::Ok {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// One parsed event line.
#[derive(Clone, Debug)]
pub struct EventRecord {
    /// The `type` tag.
    pub kind: String,
    /// Monotonic emit time (ms since the emitting process's start).
    pub t_ms: f64,
    /// Emitting process id.
    pub pid: u64,
    /// The full parsed line (all fields, including the three above).
    pub fields: Value,
}

impl EventRecord {
    /// A `u64` field of the event, if present.
    pub fn field_u64(&self, key: &str) -> Option<u64> {
        self.fields.get(key).and_then(Value::as_u64)
    }

    /// A string field of the event, if present.
    pub fn field_str(&self, key: &str) -> Option<&str> {
        self.fields.get(key).and_then(Value::as_str)
    }
}

/// Read an event log, skipping (and counting) lines that fail to
/// parse, carry no `type`, are not UTF-8, or exceed
/// [`MAX_EVENT_LINE_BYTES`] — the same torn-tail tolerance as the
/// checkpoint reader (a killed shard may die mid-append), hardened so
/// one corrupt line can neither abort the read nor buffer unbounded
/// bytes into memory.
pub fn read_events(path: &Path) -> Result<(Vec<EventRecord>, usize)> {
    let (events, skipped, _) = read_events_from(path, 0)?;
    Ok((events, skipped))
}

/// Incremental form of [`read_events`]: read from byte offset `start`
/// and additionally return the offset one past the last
/// newline-terminated line consumed — the watchdog's tailing
/// primitive. An unterminated final line (a shard mid-append) is
/// parsed or counted like any other, but the returned offset stops
/// before it so a later scan re-reads it once completed (oversized
/// lines are the exception: always drained, consumed, and counted).
pub fn read_events_from(path: &Path, start: u64) -> Result<(Vec<EventRecord>, usize, u64)> {
    let mut file = std::fs::File::open(path)?;
    if start > 0 {
        file.seek(SeekFrom::Start(start))?;
    }
    let mut reader = BufReader::new(file);
    let mut events = Vec::new();
    let mut skipped = 0usize;
    let mut offset = start;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        let n = (&mut reader)
            .take(MAX_EVENT_LINE_BYTES as u64 + 1)
            .read_until(b'\n', &mut buf)?;
        if n == 0 {
            break;
        }
        let terminated = buf.last() == Some(&b'\n');
        if n > MAX_EVENT_LINE_BYTES {
            // Oversized: one counted drop, then drain to the next
            // newline in bounded chunks without buffering the line.
            skipped += 1;
            let mut consumed = n as u64;
            let mut done = terminated;
            while !done {
                let avail = reader.fill_buf()?;
                if avail.is_empty() {
                    break;
                }
                match avail.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        reader.consume(pos + 1);
                        consumed += (pos + 1) as u64;
                        done = true;
                    }
                    None => {
                        let len = avail.len();
                        reader.consume(len);
                        consumed += len as u64;
                    }
                }
            }
            offset += consumed;
            continue;
        }
        let content = if terminated { &buf[..n - 1] } else { &buf[..] };
        if terminated {
            offset += n as u64;
        }
        let Ok(text) = std::str::from_utf8(content) else {
            skipped += 1;
            continue;
        };
        if text.trim().is_empty() {
            continue;
        }
        let parsed = match json::parse(text) {
            Ok(v) => v,
            Err(_) => {
                skipped += 1;
                continue;
            }
        };
        let Some(kind) = parsed.get("type").and_then(Value::as_str).map(String::from) else {
            skipped += 1;
            continue;
        };
        events.push(EventRecord {
            kind,
            t_ms: parsed.get("t_ms").and_then(Value::as_f64).unwrap_or(0.0),
            pid: parsed.get("pid").and_then(Value::as_u64).unwrap_or(0),
            fields: parsed,
        });
    }
    Ok((events, skipped, offset))
}

/// Per-type event counts — the `memfine events --summary` view.
pub fn summarize(events: &[EventRecord]) -> BTreeMap<String, u64> {
    let mut counts = BTreeMap::new();
    for ev in events {
        *counts.entry(ev.kind.clone()).or_insert(0u64) += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_covers_u64_range() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(u64::MAX), 64);
        for v in [0u64, 1, 7, 1 << 20, u64::MAX] {
            assert!(Histogram::bucket_index(v) < HIST_BUCKETS);
            assert!(v <= Histogram::bucket_hi(Histogram::bucket_index(v)));
        }
    }

    #[test]
    fn histogram_observe_and_stats() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 2, 3, 100, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 1106);
        assert!((h.mean() - 1106.0 / 6.0).abs() < 1e-9);
        assert_eq!(h.quantile(0.0), 0);
        assert!(h.quantile(1.0) >= 1000);
        assert_eq!(Histogram::new().quantile(0.5), 0);
    }

    #[test]
    fn histogram_merge_is_associative_and_commutative() {
        let mk = |vals: &[u64]| {
            let mut h = Histogram::new();
            for &v in vals {
                h.observe(v);
            }
            h
        };
        let a = mk(&[1, 5, 9]);
        let b = mk(&[0, 2, 1 << 40]);
        let c = mk(&[7, 7, 7, u64::MAX]);
        // (a + b) + c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a + (b + c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
        // b + a == a + b
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        // merge equals observing the concatenation
        let all = mk(&[1, 5, 9, 0, 2, 1 << 40, 7, 7, 7, u64::MAX]);
        assert_eq!(left, all);
    }

    #[test]
    fn event_log_roundtrip() {
        let dir = std::env::temp_dir().join(format!("memfine-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip-events.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = EventLog::open(&path);
        assert!(log.enabled());
        log.emit("cell_eval", vec![
            ("hash", json::s("94fd0a31c7e02b44")),
            ("eval_ns", json::num(1234.0)),
        ]);
        log.emit("shard_spawned", vec![("shard", json::num(1.0))]);
        let (events, skipped) = read_events(&path).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, "cell_eval");
        assert_eq!(events[0].field_str("hash"), Some("94fd0a31c7e02b44"));
        assert_eq!(events[0].field_u64("eval_ns"), Some(1234));
        assert_eq!(events[1].kind, "shard_spawned");
        assert_eq!(events[1].pid, u64::from(std::process::id()));
        assert!(events[1].t_ms >= events[0].t_ms);
        assert_eq!(log.dropped(), 0);
        let counts = summarize(&events);
        assert_eq!(counts.get("cell_eval"), Some(&1));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn reader_skips_torn_tail_like_checkpoints() {
        let dir = std::env::temp_dir().join(format!("memfine-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn-events.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = EventLog::open(&path);
        log.emit("a", vec![]);
        log.emit("b", vec![]);
        // Simulate a kill mid-append: a torn, unterminated final line.
        {
            use std::io::Write as _;
            let mut f =
                std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"t_ms\":9,\"pid\":1,\"ty").unwrap();
        }
        let (events, skipped) = read_events(&path).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(skipped, 1);
        assert_eq!(events[0].kind, "a");
        assert_eq!(events[1].kind, "b");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn oversized_line_is_dropped_without_buffering() {
        let dir = std::env::temp_dir().join(format!("memfine-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("oversized-events.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = EventLog::open(&path);
        log.emit("a", vec![]);
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            let chunk = vec![b'x'; 64 * 1024];
            let mut written = 0usize;
            while written <= MAX_EVENT_LINE_BYTES {
                f.write_all(&chunk).unwrap();
                written += chunk.len();
            }
            f.write_all(b"\n").unwrap();
        }
        log.emit("b", vec![]);
        let (events, skipped) = read_events(&path).unwrap();
        assert_eq!(skipped, 1, "one counted drop for the oversized line");
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, "a");
        assert_eq!(events[1].kind, "b");
        // non-UTF-8 garbage is a counted drop, not an abort
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0xff, 0xfe, 0x80, b'\n']).unwrap();
        }
        let (events, skipped) = read_events(&path).unwrap();
        assert_eq!((events.len(), skipped), (2, 2));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn incremental_reader_resumes_at_the_returned_offset() {
        let dir = std::env::temp_dir().join(format!("memfine-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("incremental-events.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = EventLog::open(&path);
        log.emit("a", vec![]);
        log.emit("b", vec![]);
        let (events, _, offset) = read_events_from(&path, 0).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(offset, std::fs::metadata(&path).unwrap().len());
        log.emit("c", vec![]);
        let (events, skipped, next) = read_events_from(&path, offset).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, "c");
        assert_eq!(skipped, 0);
        assert_eq!(next, std::fs::metadata(&path).unwrap().len());
        // a torn (unterminated) tail is reported but not consumed
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"t_ms\":9,\"pid\":1,\"ty").unwrap();
        }
        let (events, skipped, after) = read_events_from(&path, next).unwrap();
        assert_eq!((events.len(), skipped), (0, 1));
        assert_eq!(after, next, "torn tail must not advance the cursor");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn persistent_write_failure_quarantines_the_log() {
        let dir = std::env::temp_dir().join(format!("memfine-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("quarantine-events.jsonl");
        std::fs::write(&path, b"").unwrap();
        // a read-only handle makes every append fail like a dying disk
        let log = EventLog {
            inner: Some(Mutex::new(std::fs::File::open(&path).unwrap())),
            dropped: AtomicU64::new(0),
            ladder: event_log_ladder(),
            pid: std::process::id(),
        };
        let n = u64::from(EVENT_LOG_QUARANTINE_AFTER) + 3;
        for _ in 0..n {
            log.emit("doomed", vec![]);
        }
        assert_eq!(log.dropped(), n, "every failed emit is a counted drop");
        assert!(log.quarantined());
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn disabled_log_is_a_noop() {
        let log = EventLog::disabled();
        assert!(!log.enabled());
        log.emit("anything", vec![("k", json::s("v"))]);
        assert_eq!(log.dropped(), 0);
    }

    #[test]
    fn open_failure_degrades_to_disabled() {
        let log = EventLog::open(Path::new("/definitely/not/a/dir/events.jsonl"));
        assert!(!log.enabled());
        log.emit("anything", vec![]);
        assert_eq!(log.dropped(), 0);
    }
}
