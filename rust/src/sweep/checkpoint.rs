//! Resumable sweeps: a JSON-lines checkpoint of completed scenarios,
//! keyed by content hash, mergeable across shards and hosts.
//!
//! Every scenario is identified by [`scenario_hash`] — FNV-1a 64 over
//! the canonical compact JSON of its fully-resolved
//! [`RunConfig`](crate::config::RunConfig) plus the router-sampler tag.
//! The hash therefore captures *what will be simulated* (model,
//! parallelism, method, seed, iterations, memory envelope, sampler)
//! and deliberately excludes *how it is executed* (worker count,
//! shard split, grid position): two hosts running different shards of
//! the same grid, or re-runs of a reordered/extended grid, agree on
//! every hash.
//!
//! The file format is one line per completed scenario:
//!
//! ```text
//! {"hash":"94fd0a31c7e02b44","result":{...ScenarioResult row...}}
//! ```
//!
//! appended and flushed as each scenario finishes, so a killed sweep
//! loses at most the in-flight cells. Loading tolerates a torn final
//! line (the kill-mid-write case) by skipping lines that fail to
//! parse and reporting the count; merging is file concatenation or
//! passing several `--checkpoint` paths — duplicate hashes collapse
//! (results are deterministic, so duplicates are identical).
//!
//! On resume the stored row's `index` is re-derived from the *current*
//! grid (hashes are position-independent), which keeps the final
//! artifact byte-identical to an uninterrupted run of that grid — the
//! kill-and-resume integration test pins this.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::sweep::report::ScenarioResult;
use crate::util::fnv1a_64;

/// Content hash of one scenario: FNV-1a 64 (16 hex chars) over the
/// canonical run JSON plus the router-sampler tag. `fast_router`
/// changes the drawn trace (same distribution, different bits), so it
/// is part of the identity — a checkpoint written with one sampler
/// never silently satisfies a sweep run with the other.
pub fn scenario_hash(run: &RunConfig, fast_router: bool) -> String {
    let doc = json::obj(vec![
        ("router", json::s(if fast_router { "split" } else { "seq" }.to_string())),
        ("run", run.to_json()),
    ]);
    format!("{:016x}", fnv1a_64(doc.to_string_compact().as_bytes()))
}

/// Completed scenarios loaded from checkpoint files, keyed by hash.
#[derive(Debug, Default)]
pub struct CheckpointSet {
    map: BTreeMap<String, ScenarioResult>,
    /// Lines that failed to parse (torn tail of a killed run, stray
    /// garbage) — skipped, surfaced so the CLI can report them.
    pub skipped_lines: usize,
    /// Files that existed and were read.
    pub loaded_files: usize,
}

impl CheckpointSet {
    pub fn empty() -> Self {
        CheckpointSet::default()
    }

    /// Load and merge checkpoint files. Missing files are fine (a
    /// shard that never started); unreadable lines are skipped and
    /// counted. Later files win on duplicate hashes — by the
    /// determinism contract duplicates carry identical results, so
    /// the choice is immaterial.
    pub fn load(paths: &[PathBuf]) -> Result<Self> {
        let mut set = CheckpointSet::empty();
        for path in paths {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => {
                    return Err(Error::Io(std::io::Error::new(
                        e.kind(),
                        format!("checkpoint {}: {e}", path.display()),
                    )))
                }
            };
            set.loaded_files += 1;
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                match Self::parse_line(line) {
                    Ok((hash, result)) => {
                        set.map.insert(hash, result);
                    }
                    Err(_) => set.skipped_lines += 1,
                }
            }
        }
        Ok(set)
    }

    fn parse_line(line: &str) -> Result<(String, ScenarioResult)> {
        let v = json::parse(line)?;
        let hash = v.req_str("hash")?.to_string();
        let result = ScenarioResult::from_json(
            v.get("result")
                .ok_or_else(|| Error::config("checkpoint line missing result"))?,
        )?;
        Ok((hash, result))
    }

    pub fn get(&self, hash: &str) -> Option<&ScenarioResult> {
        self.map.get(hash)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Appends one line per completed scenario, flushed immediately so a
/// kill loses at most in-flight work. `disabled()` is the no-op used
/// when no `--checkpoint` path is configured.
#[derive(Debug)]
pub struct CheckpointWriter {
    out: Option<std::fs::File>,
}

impl CheckpointWriter {
    pub fn disabled() -> Self {
        CheckpointWriter { out: None }
    }

    /// Start a fresh checkpoint (truncates an existing file — the
    /// non-`--resume` path).
    pub fn create(path: &Path) -> Result<Self> {
        let f = std::fs::File::create(path).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("create checkpoint {}: {e}", path.display()),
            ))
        })?;
        Ok(CheckpointWriter { out: Some(f) })
    }

    /// Append to an existing checkpoint (the `--resume` path; the file
    /// may not exist yet). If a previous run died mid-write the file
    /// ends in a torn fragment without a newline — terminate it first
    /// so the next record starts on its own line (the fragment stays
    /// unparseable and is skipped on load; its scenario simply re-runs).
    pub fn append(path: &Path) -> Result<Self> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = std::fs::File::options()
            .read(true)
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| {
                Error::Io(std::io::Error::new(
                    e.kind(),
                    format!("append checkpoint {}: {e}", path.display()),
                ))
            })?;
        if f.metadata().map_err(Error::Io)?.len() > 0 {
            f.seek(SeekFrom::End(-1)).map_err(Error::Io)?;
            let mut last = [0u8; 1];
            f.read_exact(&mut last).map_err(Error::Io)?;
            if last[0] != b'\n' {
                // append mode: the write lands at EOF regardless of
                // the read cursor
                f.write_all(b"\n").map_err(Error::Io)?;
            }
        }
        Ok(CheckpointWriter { out: Some(f) })
    }

    /// Record one completed scenario. One compact-JSON line, written
    /// and flushed atomically enough for the torn-line loader: a kill
    /// mid-write corrupts at most the final line.
    pub fn record(&mut self, hash: &str, result: &ScenarioResult) -> Result<()> {
        let Some(f) = self.out.as_mut() else {
            return Ok(());
        };
        let line = json::obj(vec![
            ("hash", json::s(hash.to_string())),
            ("result", result.to_json()),
        ])
        .to_string_compact();
        f.write_all(line.as_bytes())
            .and_then(|_| f.write_all(b"\n"))
            .and_then(|_| f.flush())
            .map_err(Error::Io)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, paper_run, Method};

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-ckpt-test-{}-{name}", std::process::id()));
        p
    }

    fn sample_result(index: usize, seed: u64) -> ScenarioResult {
        ScenarioResult {
            index,
            model: "i".into(),
            method: Method::FixedChunk(8).name(),
            seed,
            iterations: 10,
            trained: true,
            oom_iterations: 0,
            avg_tgs: 1234.5678901234,
            peak_act_bytes: 9_876_543_210,
            peak_total_bytes: 19_876_543_210,
            static_bytes: 5_000_000_000,
        }
    }

    #[test]
    fn hash_is_stable_and_content_sensitive() {
        let run = paper_run(model_i(), Method::FullRecompute);
        let h = scenario_hash(&run, false);
        assert_eq!(h.len(), 16);
        assert_eq!(h, scenario_hash(&run, false));
        // every identity-bearing field perturbs the hash
        let mut seed = run.clone();
        seed.seed += 1;
        assert_ne!(h, scenario_hash(&seed, false));
        let mut iters = run.clone();
        iters.iterations += 1;
        assert_ne!(h, scenario_hash(&iters, false));
        let mut method = run.clone();
        method.method = Method::FixedChunk(8);
        assert_ne!(h, scenario_hash(&method, false));
        let mut mem = run.clone();
        mem.gpu_mem_bytes /= 2;
        assert_ne!(h, scenario_hash(&mem, false));
        // the sampler tag is part of the identity
        assert_ne!(h, scenario_hash(&run, true));
    }

    #[test]
    fn writer_then_loader_roundtrip() {
        let path = tmp_path("roundtrip");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        let hash = scenario_hash(&run, false);
        let result = sample_result(3, 7);
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&hash, &result).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.skipped_lines, 0);
        let back = set.get(&hash).unwrap();
        assert_eq!(back, &result);
        assert_eq!(back.avg_tgs.to_bits(), result.avg_tgs.to_bits());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loader_skips_torn_final_line() {
        let path = tmp_path("torn");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        let hash = scenario_hash(&run, false);
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&hash, &sample_result(0, 7)).unwrap();
        }
        // simulate a kill mid-write: half a second line, no newline
        {
            use std::io::Write as _;
            let mut f = std::fs::File::options().append(true).open(&path).unwrap();
            f.write_all(b"{\"hash\":\"deadbeef\",\"resu").unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.skipped_lines, 1);
        assert!(set.get(&hash).is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loader_merges_files_and_missing_files_are_fine() {
        let a = tmp_path("merge-a");
        let b = tmp_path("merge-b");
        let run1 = paper_run(model_i(), Method::FullRecompute);
        let run2 = paper_run(model_i(), Method::FixedChunk(8));
        let (h1, h2) = (scenario_hash(&run1, false), scenario_hash(&run2, false));
        {
            let mut w = CheckpointWriter::create(&a).unwrap();
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        {
            let mut w = CheckpointWriter::create(&b).unwrap();
            w.record(&h2, &sample_result(1, 7)).unwrap();
            // duplicate of h1: collapses
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        let missing = tmp_path("never-written");
        let set =
            CheckpointSet::load(&[a.clone(), b.clone(), missing]).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.loaded_files, 2);
        assert!(set.get(&h1).is_some() && set.get(&h2).is_some());
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn append_terminates_torn_tail_before_writing() {
        let path = tmp_path("torn-append");
        let run1 = paper_run(model_i(), Method::FullRecompute);
        let run2 = paper_run(model_i(), Method::FixedChunk(8));
        let (h1, h2) = (scenario_hash(&run1, false), scenario_hash(&run2, false));
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::File::options().append(true).open(&path).unwrap();
            f.write_all(b"{\"hash\":\"torn").unwrap();
        }
        {
            let mut w = CheckpointWriter::append(&path).unwrap();
            w.record(&h2, &sample_result(1, 7)).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        // both complete records load; only the torn fragment is lost
        assert_eq!(set.len(), 2);
        assert_eq!(set.skipped_lines, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn create_truncates_append_preserves() {
        let path = tmp_path("trunc");
        let run = paper_run(model_i(), Method::FullRecompute);
        let hash = scenario_hash(&run, false);
        {
            let mut w = CheckpointWriter::create(&path).unwrap();
            w.record(&hash, &sample_result(0, 7)).unwrap();
        }
        {
            let mut w = CheckpointWriter::append(&path).unwrap();
            let run2 = paper_run(model_i(), Method::FixedChunk(8));
            w.record(&scenario_hash(&run2, false), &sample_result(1, 7)).unwrap();
        }
        assert_eq!(CheckpointSet::load(std::slice::from_ref(&path)).unwrap().len(), 2);
        {
            let _w = CheckpointWriter::create(&path).unwrap();
        }
        assert!(CheckpointSet::load(std::slice::from_ref(&path)).unwrap().is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn disabled_writer_is_a_noop() {
        let mut w = CheckpointWriter::disabled();
        w.record("abc", &sample_result(0, 1)).unwrap();
    }
}
