//! Performance model: per-layer compute/communication timing, method
//! overheads, and the paper's TGS metric (Eq. 10).
//!
//! The EP group runs synchronously: each MoE layer's step time is gated
//! by the *hottest* rank (max received tokens) for both expert compute
//! and the imbalanced all-to-all — this coupling of load imbalance to
//! throughput is why Fig. 4's curves dip exactly where Fig. 2's
//! imbalance peaks.
//!
//! Why the three methods order as Fig. 4 shows (Model II:
//! M3 > M1 > M2):
//!
//! * **Method 1** executes dispatch → expert → combine **serially** on
//!   the full token set, and full recomputation repeats all of it in
//!   the backward pass.
//! * **MemFine** (Methods 2/3) runs the same stages **chunk-pipelined**
//!   (Eq. 6): chunk i's expert compute overlaps chunk i+1's dispatch,
//!   so the MoE wall-clock approaches `max(comm, compute)` instead of
//!   their sum — a large win exactly when imbalance makes the hot
//!   rank's all-to-all expensive.
//! * Chunking is not free: smaller per-chunk grouped GEMMs lose MXU
//!   efficiency and smaller per-peer messages lose fabric efficiency
//!   (saturating roofline curves below). A fixed c=8 (Method 2)
//!   over-chunks the *balanced* iterations and ends up slower than
//!   Method 1 on average; MACT (Method 3) picks c=1 when balanced and
//!   c>1 only under pressure — best of both.

use crate::collective::Fabric;
use crate::config::{ModelConfig, ParallelConfig};

/// Hardware envelope of one simulated GPU.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// Peak sustained BF16 throughput in FLOP/s at large tile sizes.
    pub flops: f64,
    /// Fixed kernel-launch / scheduling overhead per fused region.
    pub launch_s: f64,
    /// Grouped-GEMM half-saturation point: per-expert token count at
    /// which the MXU reaches 50 % of peak (wave-quantisation model).
    pub gemm_half_sat_tokens: f64,
}

impl Default for GpuSpec {
    fn default() -> Self {
        // 64 GB-class accelerator, ~40 % MFU of ~320 TFLOP/s peak.
        // Half-saturation at 1536 tokens/expert: grouped GEMMs over
        // DeepSeek-dim experts need ≥ a few thousand rows to fill the
        // MXU/SM waves — this is what penalises over-chunking (Fig. 4,
        // Method 2's −5.4 %).
        GpuSpec { flops: 128e12, launch_s: 25e-6, gemm_half_sat_tokens: 1536.0 }
    }
}

impl GpuSpec {
    /// Efficiency of a grouped GEMM whose per-expert token count is
    /// `tokens`: saturating `t/(t + t_half)` roofline.
    pub fn gemm_efficiency(&self, tokens: f64) -> f64 {
        if tokens <= 0.0 {
            return 1.0;
        }
        tokens / (tokens + self.gemm_half_sat_tokens)
    }
}

/// Per-layer FLOP counts for one micro-batch on one rank (tp split
/// applied). All counts are multiply-add pairs × 2.
#[derive(Clone, Debug)]
pub struct FlopModel {
    pub model: ModelConfig,
    pub parallel: ParallelConfig,
}

impl FlopModel {
    pub fn new(model: ModelConfig, parallel: ParallelConfig) -> Self {
        FlopModel { model, parallel }
    }

    fn per_rank(&self, flops: u64) -> f64 {
        flops as f64 / self.parallel.tp as f64
    }

    /// Attention block forward FLOPs (projections + scores + context).
    pub fn attention_fwd(&self) -> f64 {
        let m = &self.model;
        let s = m.seq * self.parallel.micro_batch;
        let proj = 2 * s * m.hidden * (m.heads * m.head_dim + 2 * m.kv_heads * m.head_dim)
            + 2 * s * (m.heads * m.head_dim) * m.hidden;
        let attn = 2 * 2 * s * s * m.heads * m.head_dim / self.parallel.cp;
        self.per_rank(proj + attn)
    }

    /// Dense SwiGLU FFN forward FLOPs.
    pub fn dense_ffn_fwd(&self) -> f64 {
        let m = &self.model;
        let s = m.seq * self.parallel.micro_batch;
        self.per_rank(6 * s * m.hidden * m.ffn_dense)
    }

    /// Router forward FLOPs.
    pub fn router_fwd(&self) -> f64 {
        let m = &self.model;
        let s = m.seq * self.parallel.micro_batch;
        self.per_rank(2 * s * m.hidden * m.n_experts)
    }

    /// Expert FFN forward FLOPs for `recv` received token copies on
    /// this rank (SwiGLU: 3 GEMMs).
    pub fn expert_fwd(&self, recv: u64) -> f64 {
        let m = &self.model;
        self.per_rank(6 * recv * m.hidden * m.ffn_expert)
    }

    /// Bytes landing on the hottest rank in one all-to-all direction.
    pub fn a2a_bytes(&self, recv: u64, dtype_bytes: u64) -> u64 {
        recv * self.model.hidden * dtype_bytes / self.parallel.tp
    }

    /// Local experts per EP rank.
    pub fn local_experts(&self) -> u64 {
        self.model.n_experts / self.parallel.ep
    }
}

/// Timing of one layer's forward+backward under a given method.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerTime {
    /// Compute on the critical path (attention, router, experts).
    pub compute_s: f64,
    /// All-to-all on the critical path (after overlap).
    pub comm_s: f64,
    /// Fixed per-chunk/per-kernel overheads.
    pub overhead_s: f64,
}

impl LayerTime {
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s + self.overhead_s
    }

    fn add(self, o: LayerTime) -> LayerTime {
        LayerTime {
            compute_s: self.compute_s + o.compute_s,
            comm_s: self.comm_s + o.comm_s,
            overhead_s: self.overhead_s + o.overhead_s,
        }
    }
}

/// The method-aware per-layer timing engine.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub flops: FlopModel,
    pub gpu: GpuSpec,
    pub fabric: Fabric,
    pub dtype_bytes: u64,
}

impl PerfModel {
    pub fn new(model: ModelConfig, parallel: ParallelConfig, dtype_bytes: u64) -> Self {
        PerfModel {
            flops: FlopModel::new(model, parallel),
            gpu: GpuSpec::default(),
            fabric: Fabric::default(),
            dtype_bytes,
        }
    }

    fn t(&self, f: f64) -> f64 {
        f / self.gpu.flops + self.gpu.launch_s
    }

    /// Expert compute time for `recv` copies split into `c` chunks:
    /// FLOPs are constant, efficiency follows the per-chunk per-expert
    /// token count.
    fn expert_time(&self, recv: u64, c: u64) -> f64 {
        if recv == 0 {
            return 0.0;
        }
        let per_chunk_per_expert =
            recv as f64 / (c as f64 * self.flops.local_experts() as f64);
        let eff = self.gpu.gemm_efficiency(per_chunk_per_expert);
        self.flops.expert_fwd(recv) / (self.gpu.flops * eff)
    }

    /// One all-to-all pass (dispatch or combine) for `recv` copies at
    /// the hottest rank, split into `c` chunks (α paid per chunk; β
    /// paid once).
    fn a2a_time(&self, recv: u64, c: u64) -> f64 {
        let per_chunk = recv.div_ceil(c);
        (0..c)
            .map(|_| {
                self.fabric.all_to_all_imbalanced(
                    self.flops.parallel.ep,
                    self.flops.a2a_bytes(per_chunk, self.dtype_bytes),
                )
            })
            .sum()
    }

    /// Chunk-pipelined stage composition: dispatch `d`, compute `x`,
    /// combine `k` (full-volume times) over `c` chunks:
    /// `T = (d + x + k)/c + (c−1)/c · max(d, x, k)`.
    /// c = 1 degenerates to the serial sum; c → ∞ approaches the
    /// bottleneck stage (perfect overlap).
    fn pipelined(d: f64, x: f64, k: f64, c: u64) -> f64 {
        let c = c.max(1) as f64;
        (d + x + k) / c + (c - 1.0) / c * d.max(x).max(k)
    }

    /// Dense layer (no MoE): forward + backward (+ full recompute).
    pub fn dense_layer(&self, full_recompute: bool) -> LayerTime {
        let fwd = self.t(self.flops.attention_fwd()) + self.t(self.flops.dense_ffn_fwd());
        let rc = if full_recompute { fwd } else { 0.0 };
        LayerTime { compute_s: 3.0 * fwd + rc, comm_s: 0.0, overhead_s: 0.0 }
    }

    /// MoE layer under Method 1: serial dispatch → expert → combine on
    /// the full token set; full recompute re-runs the whole layer
    /// (attention included) in backward.
    pub fn moe_layer_method1(&self, max_recv: u64) -> LayerTime {
        let attn = self.t(self.flops.attention_fwd());
        let router = self.t(self.flops.router_fwd());
        let x = self.expert_time(max_recv, 1);
        let d = self.a2a_time(max_recv, 1);
        // forward + full-layer recompute + backward (2× compute, grads
        // cross the fabric twice) — all serial.
        let fwd = attn + router + x;
        let compute = fwd + fwd + 2.0 * fwd;
        let comm = 2.0 * d /*fwd*/ + 2.0 * d /*recompute*/ + 2.0 * d /*bwd grads*/;
        LayerTime { compute_s: compute, comm_s: comm, overhead_s: 2.0 * self.gpu.launch_s }
    }

    /// MoE layer under MemFine with `c` chunks: chunk-pipelined
    /// dispatch/expert/combine in forward, chunked recompute + backward
    /// (Eq. 7) with the same overlap.
    ///
    /// `recompute_attn = false` is MemFine's *selective* recomputation:
    /// with the MoE peak tamed by chunking, the attention activations
    /// of the stage fit in the freed headroom and need no re-run — the
    /// throughput edge over Method 1 (paper: +4.42 % on Model II). The
    /// simulator grants it only when the memory model proves the stored
    /// dense part fits (sim::iteration).
    pub fn moe_layer_memfine(&self, max_recv: u64, c: u64, recompute_attn: bool) -> LayerTime {
        assert!(c >= 1);
        let attn = self.t(self.flops.attention_fwd());
        let router = self.t(self.flops.router_fwd());
        let x = self.expert_time(max_recv, c);
        let d = self.a2a_time(max_recv, c);
        // forward: pipelined D|X|K; recompute: same; backward: 2× the
        // expert compute with grad dispatch/combine, also pipelined.
        let fwd_moe = Self::pipelined(d, x, d, c);
        let rc_moe = fwd_moe;
        let bwd_moe = Self::pipelined(d, 2.0 * x, d, c);
        // dense blocks: fwd + 2× bwd, plus recompute unless selective.
        let dense = if recompute_attn {
            4.0 * (attn + router)
        } else {
            3.0 * attn + 4.0 * router
        };
        // Split the pipelined MoE times into comm/compute attribution
        // for reporting: attribute min(d·2, moe_time) to comm.
        let moe_total = fwd_moe + rc_moe + bwd_moe;
        let moe_comm = (6.0 * d / c as f64).min(moe_total); // β floor after overlap
        LayerTime {
            compute_s: dense + (moe_total - moe_comm),
            comm_s: moe_comm,
            overhead_s: 2.0 * c as f64 * 3.0 * self.gpu.launch_s,
        }
    }

    /// Time of one micro-batch through one pipeline stage hosting
    /// `dense_layers` dense and the given per-MoE-layer (recv, chunks).
    pub fn stage_time(
        &self,
        dense_layers: u64,
        moe: &[(u64, u64)],
        method1: bool,
    ) -> f64 {
        let mut t = LayerTime::default();
        for _ in 0..dense_layers {
            t = t.add(self.dense_layer(true));
        }
        for &(recv, c) in moe {
            t = t.add(if method1 {
                self.moe_layer_method1(recv)
            } else {
                self.moe_layer_memfine(recv, c, true)
            });
        }
        t.total()
    }

    /// Iteration time over the whole pipeline: bottleneck stage time ×
    /// (m + p − 1) (1F1B bubble).
    pub fn iteration_time(&self, per_stage_mb_time: &[f64], micro_batches: u64) -> f64 {
        let bottleneck = per_stage_mb_time.iter().cloned().fold(0.0, f64::max);
        bottleneck * (micro_batches + per_stage_mb_time.len() as u64 - 1) as f64
    }

    /// Eq. 10: tokens per GPU per second.
    pub fn tgs(&self, iteration_s: f64) -> f64 {
        let p = &self.flops.parallel;
        let n = p.world_size();
        (p.global_batch * self.flops.model.seq) as f64 / (iteration_s * n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, paper_parallel};

    fn pm() -> PerfModel {
        PerfModel::new(model_i(), paper_parallel(), 2)
    }

    #[test]
    fn expert_flops_linear_in_recv() {
        let p = pm();
        assert!((p.flops.expert_fwd(2000) - 2.0 * p.flops.expert_fwd(1000)).abs() < 1.0);
        assert_eq!(p.flops.expert_fwd(0), 0.0);
    }

    #[test]
    fn gemm_efficiency_saturates() {
        let g = GpuSpec::default();
        let half = g.gemm_half_sat_tokens;
        assert!(g.gemm_efficiency(half / 10.0) < 0.2);
        assert!(g.gemm_efficiency(half * 20.0) > 0.9);
        assert!((g.gemm_efficiency(half) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn pipelined_degenerates_serial_at_c1() {
        let t1 = PerfModel::pipelined(1.0, 2.0, 1.5, 1);
        assert!((t1 - 4.5).abs() < 1e-12);
    }

    #[test]
    fn pipelined_approaches_bottleneck() {
        let t = PerfModel::pipelined(1.0, 2.0, 1.0, 1000);
        assert!(t < 2.01 && t >= 2.0);
    }

    #[test]
    fn overlap_wins_at_high_imbalance() {
        // At peak imbalance the hot rank is comm-heavy; MemFine c=2
        // must beat Method 1's serial pipeline.
        let p = pm();
        let recv = 600_000;
        let m1 = p.moe_layer_method1(recv).total();
        let m3 = p.moe_layer_memfine(recv, 2, true).total();
        assert!(m3 < m1, "m3 {m3} !< m1 {m1}");
    }

    #[test]
    fn overchunking_loses_when_balanced() {
        // On a balanced iteration (s' = s·t_k), fixed c=8 over-chunks:
        // per-expert-chunk tokens drop into the inefficient GEMM regime
        // → slower than Method 1 (Fig. 4 Model II, Method 2 −5.4 %).
        let p = pm();
        let balanced = 4096 * 8;
        let m1 = p.moe_layer_method1(balanced).total();
        let m2 = p.moe_layer_memfine(balanced, 8, true).total();
        assert!(m2 > m1, "m2 {m2} !> m1 {m1}");
    }

    #[test]
    fn mact_choice_best_of_both() {
        // c=1 when balanced ≈ Method 1 minus serial penalty; never
        // worse than c=8 at balance, never worse than c=1 at extreme.
        let p = pm();
        let balanced = 4096 * 8;
        let c1 = p.moe_layer_memfine(balanced, 1, true).total();
        let c8 = p.moe_layer_memfine(balanced, 8, true).total();
        assert!(c1 < c8);
        let extreme = 600_000;
        let e2 = p.moe_layer_memfine(extreme, 2, true).total();
        let e1 = p.moe_layer_memfine(extreme, 1, true).total();
        assert!(e2 < e1);
    }

    #[test]
    fn hotter_rank_slower_layer() {
        let p = pm();
        let cold = p.moe_layer_method1(50_000).total();
        let hot = p.moe_layer_method1(500_000).total();
        assert!(hot > 2.0 * cold);
    }

    #[test]
    fn stage_time_accumulates_layers() {
        let p = pm();
        let one = p.stage_time(0, &[(100_000, 1)], true);
        let two = p.stage_time(0, &[(100_000, 1), (100_000, 1)], true);
        assert!((two - 2.0 * one).abs() < 1e-9);
        let with_dense = p.stage_time(2, &[(100_000, 1)], true);
        assert!(with_dense > one);
    }

    #[test]
    fn iteration_time_bubble_factor() {
        let p = pm();
        let stage_times = vec![0.01, 0.012, 0.011, 0.0115];
        let t = p.iteration_time(&stage_times, 960);
        assert!((t - 0.012 * 963.0).abs() < 1e-9);
    }

    #[test]
    fn tgs_matches_eq10() {
        let p = pm();
        let t_iter = 10.0;
        let want = (960.0 * 4096.0) / (10.0 * 128.0);
        assert!((p.tgs(t_iter) - want).abs() < 1e-9);
    }

    #[test]
    fn dense_layer_recompute_toggle() {
        let p = pm();
        let with = p.dense_layer(true);
        let without = p.dense_layer(false);
        assert!(with.total() > without.total());
    }
}
