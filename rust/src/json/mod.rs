//! Minimal JSON parser + writer.
//!
//! The offline registry has no `serde`/`serde_json`, so MemFine ships a
//! small, strict JSON implementation: enough for config files, the AOT
//! `manifest.json`, and metric dumps. Numbers parse to f64 (with exact
//! u64/i64 accessors), strings support the standard escapes, and the
//! writer emits deterministic, sorted-key output so golden tests are
//! stable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Objects use a BTreeMap so serialisation is
/// deterministic (sorted keys).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) => {
                Some(*n as i64)
            }
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access, `None` if not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Required-field accessors used by config loading.
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(Value::as_u64)
            .ok_or_else(|| Error::config(format!("missing/invalid u64 field '{key}'")))
    }
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::config(format!("missing/invalid f64 field '{key}'")))
    }
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| Error::config(format!("missing/invalid string field '{key}'")))
    }

    /// Serialise compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialise with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}
pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_u64().unwrap(), 2);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn error_carries_offset() {
        match parse("[1, x]") {
            Err(Error::Json { offset, .. }) => assert_eq!(offset, 4),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.to_string_compact(), src);
    }

    #[test]
    fn roundtrip_pretty_reparses() {
        let v = obj(vec![
            ("name", s("memfine")),
            ("nums", arr(vec![num(1.0), num(2.0)])),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(num(42.0).to_string_compact(), "42");
        assert_eq!(num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn u64_accessor_rejects_floats_and_negatives() {
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("-2").unwrap().as_u64(), None);
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn req_accessors_give_config_errors() {
        let v = parse(r#"{"n": 3}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 3);
        assert!(matches!(v.req_u64("missing"), Err(Error::Config(_))));
        assert!(matches!(v.req_str("n"), Err(Error::Config(_))));
    }
}
