//! `memfine` — CLI for the MemFine reproduction.
//!
//! Subcommands map 1:1 onto the paper's artifacts (DESIGN.md §4):
//!
//! ```text
//! memfine plan    [--model i|ii]             memory model walkthrough (Eq. 1–3, 8)
//! memfine simulate [--model i|ii] [--method 1|2|3] [--iters N]
//! memfine sweep   [--models i,ii] [--methods 1,2,3] [--seeds N|a,b,...]
//!                 [--workers N] [--out FILE] [--checkpoint F[,F...]]
//!                 [--resume] [--shard i/n] [--limit N] [--fast-router]
//!                 parallel scenario grid, resumable/shardable
//! memfine repro   table4|fig2|fig4|fig5      regenerate a paper artifact
//! memfine train   [--steps N] [--artifacts DIR]  E2E mini-model training
//! memfine coord   [--policy mact|fixed] [--budget-mb N]  real EP layer pass
//! ```

use memfine::cli::{usage, Args, OptSpec};
use memfine::config::{
    derive_seeds, model_i, model_ii, paper_run, Method, ModelConfig, SweepConfig,
};
use memfine::coordinator::ep::{ChunkPolicy, EpCoordinator};
use memfine::coordinator::train::TrainDriver;
use memfine::memory::{ActivationModel, StaticModel};
use memfine::runtime::ArtifactStore;
use memfine::sim::Simulator;
use memfine::util::fmt_bytes;

const VALUE_OPTS: &[&str] = &[
    "model", "method", "iters", "seed", "steps", "artifacts", "policy",
    "budget-mb", "bins", "chunk", "models", "methods", "seeds", "workers",
    "out", "checkpoint", "shard", "limit",
];

fn main() {
    memfine::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match Args::parse(args, VALUE_OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if parsed.command.is_none() || parsed.has_flag("help") {
        print_usage();
        return;
    }
    let cmd = parsed.command.clone().unwrap();
    let result = match cmd.as_str() {
        "plan" => cmd_plan(&parsed),
        "simulate" => cmd_simulate(&parsed),
        "sweep" => cmd_sweep(&parsed),
        "repro" => cmd_repro(&parsed),
        "train" => cmd_train(&parsed),
        "coord" => cmd_coord(&parsed),
        other => {
            eprintln!("unknown command '{other}'");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    print!(
        "{}",
        usage(
            "memfine",
            "MemFine: memory-aware fine-grained scheduling for MoE training",
            &[
                ("plan", "memory model walkthrough (Eq. 1-3, Eq. 8)"),
                ("simulate", "simulate a training run (methods 1/2/3)"),
                ("sweep", "parallel scenario grid: models x methods x seeds"),
                ("repro", "regenerate a paper artifact: table4|fig2|fig4|fig5"),
                ("train", "end-to-end mini-model training via PJRT"),
                ("coord", "real EP coordinator layer pass"),
            ],
            &[
                OptSpec { name: "model", help: "table-3 model: i or ii", takes_value: true, default: Some("i") },
                OptSpec { name: "method", help: "1=full-recompute 2=fixed-chunk 3=mact", takes_value: true, default: Some("3") },
                OptSpec { name: "chunk", help: "fixed chunk bin for method 2", takes_value: true, default: Some("8") },
                OptSpec { name: "iters", help: "iterations to simulate", takes_value: true, default: Some("25") },
                OptSpec { name: "steps", help: "training steps (train)", takes_value: true, default: Some("50") },
                OptSpec { name: "seed", help: "rng seed", takes_value: true, default: Some("7") },
                OptSpec { name: "models", help: "sweep models, comma-separated (i,ii)", takes_value: true, default: Some("i,ii") },
                OptSpec { name: "methods", help: "sweep methods: 1 | 2[:c] | 3[:b.b...]", takes_value: true, default: Some("1,2,3") },
                OptSpec { name: "seeds", help: "sweep seeds: a count (derived from --seed) or a,b,... list (trailing comma forces list)", takes_value: true, default: Some("4") },
                OptSpec { name: "workers", help: "sweep worker threads (0 = all cores)", takes_value: true, default: Some("0") },
                OptSpec { name: "out", help: "sweep JSON output path (- = stdout only)", takes_value: true, default: Some("-") },
                OptSpec { name: "checkpoint", help: "sweep checkpoint file(s), comma-separated; first is the write target", takes_value: true, default: None },
                OptSpec { name: "resume", help: "skip scenarios already in the checkpoint file(s)", takes_value: false, default: None },
                OptSpec { name: "shard", help: "run shard i of n (i/n) of the sweep grid", takes_value: true, default: None },
                OptSpec { name: "limit", help: "execute at most N sweep scenarios this run", takes_value: true, default: None },
                OptSpec { name: "fast-router", help: "binomial-splitting routing draw (faster; different sample)", takes_value: false, default: None },
                OptSpec { name: "artifacts", help: "artifact directory", takes_value: true, default: Some("artifacts") },
                OptSpec { name: "policy", help: "coord policy: mact or fixed", takes_value: true, default: Some("mact") },
                OptSpec { name: "budget-mb", help: "coord per-rank memory budget", takes_value: true, default: Some("48") },
            ],
        )
    );
}

fn model_arg(args: &Args) -> Result<ModelConfig, memfine::Error> {
    match args.get_or("model", "i").as_str() {
        "i" | "I" | "1" => Ok(model_i()),
        "ii" | "II" | "2" => Ok(model_ii()),
        other => Err(memfine::Error::Cli(format!("unknown model '{other}'"))),
    }
}

fn method_arg(args: &Args) -> Result<Method, memfine::Error> {
    match args.get_or("method", "3").as_str() {
        "1" => Ok(Method::FullRecompute),
        "2" => Ok(Method::FixedChunk(args.get_u64("chunk", 8)?)),
        "3" => Ok(Method::Mact(args.get_u64_list("bins", &[1, 2, 4, 8])?)),
        other => Err(memfine::Error::Cli(format!("unknown method '{other}'"))),
    }
}

fn cmd_plan(args: &Args) -> memfine::Result<()> {
    let model = model_arg(args)?;
    let run = paper_run(model, Method::Mact(vec![1, 2, 4, 8]));
    let act = ActivationModel::new(&run);
    let sta = StaticModel::new(&run);
    let budget = (run.alpha * run.gpu_mem_bytes as f64) as u64;
    println!(
        "MemFine memory plan — {} layers, e={}, p={}",
        run.model.layers, run.parallel.ep, run.parallel.pp
    );
    println!("GPU budget α·M = {}", fmt_bytes(budget));
    println!("theoretical peak s' = {}", act.s_prime_theoretical_peak());
    println!();
    println!(
        "{:>5} {:>12} {:>12} {:>14} {:>10}",
        "stage", "static", "dense act", "s'_max (Eq.8)", "ideal c"
    );
    for stage in 0..run.parallel.pp {
        let st = sta.bytes_on_rank(stage);
        let s_max = act.s_prime_max(stage, st, budget, true);
        let worst = act.s_prime_theoretical_peak();
        let need = worst.div_ceil(s_max.max(1));
        println!(
            "{:>5} {:>12} {:>12} {:>14} {:>10}",
            stage,
            fmt_bytes(st),
            fmt_bytes(act.dense_bytes()),
            s_max,
            need
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> memfine::Result<()> {
    let model = model_arg(args)?;
    let method = method_arg(args)?;
    let mut run = paper_run(model, method);
    run.iterations = args.get_u64("iters", 25)?;
    run.seed = args.get_u64("seed", 7)?;
    let sim = Simulator::new(run)?;
    let out = sim.run_all();
    println!("method: {}", out.method.name());
    println!("static memory (max stage): {}", fmt_bytes(out.static_bytes));
    println!("peak activation: {}", fmt_bytes(out.peak_act_bytes));
    println!("OOM iterations: {}/{}", out.oom_iterations, out.iterations.len());
    println!("avg TGS (non-OOM): {:.0}", out.avg_tgs);
    for it in &out.iterations {
        println!(
            "  iter {:>2}  act={}  t={:.2}s  TGS={:>7.0}{}",
            it.iteration,
            fmt_bytes(it.peak_act_bytes),
            it.iteration_s,
            it.tgs,
            if it.oom { "  ** OOM **" } else { "" }
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> memfine::Result<()> {
    let models: Vec<String> = args
        .get_or("models", "i,ii")
        .split(',')
        .map(|m| m.trim().to_string())
        .filter(|m| !m.is_empty())
        .collect();
    let methods = args
        .get_or("methods", "1,2,3")
        .split(',')
        .map(Method::parse)
        .collect::<memfine::Result<Vec<Method>>>()?;
    // --seeds takes either a count (derived from --seed) or an
    // explicit comma-separated list; a trailing comma forces list
    // mode, so a single literal seed is expressible as `--seeds 42,`.
    let seeds_spec = args.get_or("seeds", "4");
    let seeds = if seeds_spec.contains(',') {
        seeds_spec
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(|p| {
                p.parse().map_err(|_| {
                    memfine::Error::Cli(format!("--seeds list has bad entry '{p}'"))
                })
            })
            .collect::<memfine::Result<Vec<u64>>>()?
    } else {
        let n: usize = seeds_spec.trim().parse().map_err(|_| {
            memfine::Error::Cli(format!("--seeds expects a count or list, got '{seeds_spec}'"))
        })?;
        derive_seeds(args.get_u64("seed", 7)?, n)
    };
    let cfg = SweepConfig {
        models,
        methods,
        seeds,
        iterations: args.get_u64("iters", 25)?,
    };
    let checkpoint: Vec<std::path::PathBuf> = args
        .get("checkpoint")
        .map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(std::path::PathBuf::from)
                .collect()
        })
        .unwrap_or_default();
    let shard = args
        .get("shard")
        .map(memfine::config::ShardSpec::parse)
        .transpose()?;
    let limit = args.get("limit").map(|_| args.get_u64("limit", 0)).transpose()?;
    let opts = memfine::sweep::SweepRunOptions {
        workers: args.get_u64("workers", 0)? as usize,
        checkpoint,
        resume: args.has_flag("resume"),
        shard,
        limit: limit.map(|n| n as usize),
        fast_router: args.has_flag("fast-router"),
    };
    eprintln!(
        "sweep: {} scenarios{}{}",
        cfg.scenario_count(),
        match opts.shard {
            Some(s) => format!(", shard {}/{}", s.index, s.count),
            None => String::new(),
        },
        if opts.resume { ", resuming" } else { "" },
    );
    let summary = memfine::sweep::run_sweep_with(&cfg, &opts)?;
    eprintln!(
        "sweep: {} executed, {} resumed, {} skipped (shard/limit){}",
        summary.executed,
        summary.resumed,
        summary.skipped,
        if summary.skipped_checkpoint_lines > 0 {
            format!(
                ", {} unreadable checkpoint line(s) ignored",
                summary.skipped_checkpoint_lines
            )
        } else {
            String::new()
        },
    );
    let report = summary.report;
    // Human-readable table goes to stderr so stdout carries only the
    // JSON artifact — `memfine sweep | jq .` and `> sweep.json` both
    // see a clean, parseable document.
    eprint!("{}", report.render_table());
    let json = report.to_json().to_string_pretty();
    match args.get_or("out", "-").as_str() {
        "-" => println!("{json}"),
        path => {
            std::fs::write(path, format!("{json}\n"))?;
            eprintln!("report written to {path}");
        }
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> memfine::Result<()> {
    let what = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("table4");
    match what {
        "table4" => memfine::sim::repro::table4(args.get_u64("seed", 7)?),
        "fig2" => memfine::sim::repro::fig2(args.get_u64("seed", 7)?, 7),
        "fig4" => memfine::sim::repro::fig4(args.get_u64("seed", 7)?, args.get_u64("iters", 25)?),
        "fig5" => memfine::sim::repro::fig5(args.get_u64("seed", 7)?, args.get_u64("iters", 25)?),
        other => Err(memfine::Error::Cli(format!(
            "unknown artifact '{other}' (table4|fig2|fig4|fig5)"
        ))),
    }
}

fn cmd_train(args: &Args) -> memfine::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let steps = args.get_u64("steps", 50)?;
    let store = ArtifactStore::open(&dir)?;
    let driver = TrainDriver::new(store)?;
    println!(
        "training {} steps (tokens/step = {})",
        steps,
        driver.tokens_per_step()
    );
    let report = driver.train(steps, args.get_u64("seed", 7)?, |log| {
        if log.step == 1 || log.step % 10 == 0 {
            println!(
                "step {:>4}  loss {:.4}  {:.2}s  TGS {:.0}",
                log.step, log.loss, log.step_s, log.tgs
            );
        }
    })?;
    println!(
        "done: first loss {:.4} → final {:.4} (tail-5 {:.4}), mean TGS {:.0}, total {:.1}s",
        report.first_loss,
        report.final_loss,
        report.tail_loss(5),
        report.mean_tgs,
        report.total_s
    );
    Ok(())
}

fn cmd_coord(args: &Args) -> memfine::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let budget = args.get_u64("budget-mb", 48)? << 20;
    let policy = match args.get_or("policy", "mact").as_str() {
        "mact" => ChunkPolicy::Mact { budget_bytes: budget },
        "fixed" => ChunkPolicy::Fixed(args.get_u64("chunk", 8)?),
        other => return Err(memfine::Error::Cli(format!("unknown policy '{other}'"))),
    };
    let coord = EpCoordinator::new(dir, policy, args.get_u64("seed", 7)?)?;
    println!(
        "EP coordinator: {} ranks × {} local experts, {} tokens/rank, top-{}",
        coord.topo.ep, coord.topo.local_experts, coord.topo.tokens_per_rank, coord.topo.top_k
    );
    let d = coord.decide()?;
    println!(
        "decision: chunk bin {} (capacity {}, buffers {})",
        d.chunk_bin,
        d.capacity,
        fmt_bytes(d.buffer_bytes)
    );
    let result = coord.run_layer()?;
    println!("received per rank: {:?}", result.received);
    println!(
        "peak tracked bytes per rank: {:?}",
        result
            .peak_bytes
            .iter()
            .map(|&b| fmt_bytes(b))
            .collect::<Vec<_>>()
    );
    let norm: f32 = result.outputs[0].iter().map(|x| x * x).sum::<f32>().sqrt();
    println!("rank-0 output L2 = {norm:.3} (layer pass complete)");
    Ok(())
}
