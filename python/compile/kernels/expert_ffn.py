"""Layer-1 Pallas kernel: chunked grouped SwiGLU expert FFN.

This is the paper's compute hot-spot (the "expert computation" stage of
dispatch-computation-combine). The FCDA chunk is the unit of invocation:
one kernel call processes one chunk's worth of gathered tokens, so the
live activation footprint is bounded by the chunk capacity C — the same
memory bound MemFine establishes on GPU, expressed here as a Pallas
BlockSpec schedule.

Hardware adaptation (paper targets GPU, we target the TPU model — see
DESIGN.md §Hardware-Adaptation):

  * GPU threadblock over (expert, token tile)  →  Pallas grid (E, C/Tc)
  * shared-memory staging of A/B tiles         →  BlockSpec HBM→VMEM
    blocks: x tile (Tc, H), per-expert weights (H, G)/(G, H)
  * epilogue fusion of SiLU·up into the second GEMM's producer →
    single kernel body computing w2 @ (silu(x·w1) * (x·w3))

VMEM footprint per grid step (fp32 words):
    Tc·H (x) + 2·H·G (w1,w3) + G·H (w2) + Tc·G (act scratch) + Tc·H (out)
which is independent of the total token count — only the tile and model
dims matter. The rust `perf` module uses the same formula for the
MXU-utilisation estimate recorded in EXPERIMENTS.md §Perf.

Kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers to plain HLO that the
rust runtime executes directly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default token-tile. 8 divides every chunk capacity the AOT pipeline
# emits (bins × tokens are powers of two) and keeps the VMEM estimate
# comfortably under 16 MiB for the Table-3 dims.
DEFAULT_TOKEN_TILE = 8


def _ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, mask_ref, o_ref):
    """One (expert, token-tile) grid step of the grouped SwiGLU FFN.

    Refs carry the BlockSpec-selected tiles:
      x_ref:    (1, Tc, H)   token tile for this expert
      w1_ref:   (1, H, G)    gate projection of this expert
      w3_ref:   (1, H, G)    up projection
      w2_ref:   (1, G, H)    down projection
      mask_ref: (1, Tc)      validity of each token slot
      o_ref:    (1, Tc, H)   output tile
    """
    x = x_ref[0]  # (Tc, H)
    w1 = w1_ref[0]  # (H, G)
    w3 = w3_ref[0]
    w2 = w2_ref[0]  # (G, H)
    mask = mask_ref[0]  # (Tc,)

    # Fused SwiGLU epilogue: both GEMMs hit the MXU; silu/mul are VPU ops
    # on the (Tc, G) tile that never round-trips to HBM.
    gate = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    up = jnp.dot(x, w3, preferred_element_type=jnp.float32)
    act = (gate * jax.lax.logistic(gate)) * up
    out = jnp.dot(act.astype(x.dtype), w2, preferred_element_type=jnp.float32)
    out = out * mask[:, None].astype(out.dtype)
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("token_tile",))
def expert_ffn(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    w2: jnp.ndarray,
    mask: jnp.ndarray,
    token_tile: int = DEFAULT_TOKEN_TILE,
) -> jnp.ndarray:
    """Chunked grouped expert FFN via Pallas.

    Args:
      x:    (E, C, H) tokens gathered per local expert (one FCDA chunk).
      w1:   (E, H, G) gate projections.
      w3:   (E, H, G) up projections.
      w2:   (E, G, H) down projections.
      mask: (E, C) slot validity (1.0 real token / 0.0 padding).
      token_tile: Tc, the per-grid-step token count; must divide C.

    Returns:
      (E, C, H) expert outputs, zero at padded slots. Matches
      ref.expert_ffn_ref to float tolerance (pytest invariant).
    """
    e, c, h = x.shape
    g = w1.shape[2]
    if c % token_tile != 0:
        raise ValueError(f"chunk capacity {c} not divisible by tile {token_tile}")
    grid = (e, c // token_tile)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, token_tile, h), lambda ei, ti: (ei, ti, 0)),
            pl.BlockSpec((1, h, g), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, h, g), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, g, h), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, token_tile), lambda ei, ti: (ei, ti)),
        ],
        out_specs=pl.BlockSpec((1, token_tile, h), lambda ei, ti: (ei, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, h), x.dtype),
        interpret=True,
    )(x, w1, w3, w2, mask)


def vmem_bytes(token_tile: int, h: int, g: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (used by EXPERIMENTS §Perf
    and mirrored by rust perf::kernel_vmem_bytes)."""
    words = (
        token_tile * h  # x tile
        + 2 * h * g  # w1 + w3
        + g * h  # w2
        + 2 * token_tile * g  # gate/up scratch
        + token_tile * h  # out tile
    )
    return words * dtype_bytes


def mxu_flops(c: int, h: int, g: int) -> int:
    """MAC-pair flops of one expert's chunk: 3 GEMMs (gate, up, down)."""
    return 2 * c * h * g * 3


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward + chunked-recompute backward.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_expert_ffn_ad(token_tile: int):
    """Build a custom-VJP expert FFN for a given token tile.

    The VJP embodies the paper's chunked recomputation (Eq. 7): the
    forward stores ONLY the kernel inputs (the chunk boundary), and the
    backward re-runs the forward math to rebuild intermediates before
    differentiating. No (E, C, G) activations survive the forward pass.
    """

    @jax.custom_vjp
    def fn(x, w1, w3, w2, mask):
        return expert_ffn(x, w1, w3, w2, mask, token_tile=token_tile)

    def fwd(x, w1, w3, w2, mask):
        out = expert_ffn(x, w1, w3, w2, mask, token_tile=token_tile)
        # Residuals = chunk inputs only: this IS the memory saving.
        # Storing gate/up activations would cost 2·E·C·G extra words.
        return out, (x, w1, w3, w2, mask)

    def bwd(res, g_out):
        x, w1, w3, w2, mask = res
        # Chunked recomputation: rebuild intermediates through the
        # reference formulas (identical math) and differentiate those.
        def f(x_, w1_, w3_, w2_):
            return ref.expert_ffn_ref(x_, w1_, w3_, w2_, mask)

        _, vjp = jax.vjp(f, x, w1, w3, w2)
        gx, gw1, gw3, gw2 = vjp(g_out)
        return gx, gw1, gw3, gw2, None

    fn.defvjp(fwd, bwd)
    return fn


def expert_ffn_ad(x, w1, w3, w2, mask, token_tile: int | None = None):
    """Differentiable chunked expert FFN (Pallas fwd, recompute bwd).

    token_tile defaults to the largest power-of-two tile ≤ 128 that
    divides the chunk capacity — large tiles amortise grid overhead on
    CPU while staying inside the VMEM budget on TPU (see vmem_bytes).
    """
    c = x.shape[1]
    if token_tile is None:
        token_tile = 8
        while token_tile < 128 and c % (token_tile * 2) == 0:
            token_tile *= 2
        if c % token_tile != 0:
            token_tile = 1
    return _make_expert_ffn_ad(token_tile)(x, w1, w3, w2, mask)
