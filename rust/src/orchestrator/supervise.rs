//! Shard-fleet supervision: spawn one child process per [`ShardPlan`],
//! watch liveness through checkpoint-growth heartbeats
//! ([`crate::orchestrator::health`]), kill and relaunch crashed or
//! stalled shards with `--resume` (bounded by a per-shard retry
//! budget), and summarise each shard's fate.
//!
//! The supervisor is generic over the *spawner* — any
//! `FnMut(&ShardPlan, attempt) -> Result<Child>` — so tests can
//! inject wedged or crashing fakes without touching the real `memfine
//! sweep` command line, and every decision it makes is surfaced as a
//! [`ShardEvent`] through the caller's callback.
//!
//! Correctness never depends on supervision: children checkpoint every
//! completed scenario, relaunches resume from those checkpoints, and
//! the merge step audits coverage and re-runs any gap in-process — so
//! a kill at any point (including the injected chaos kill) costs only
//! the in-flight work, never the artifact's bytes.

use std::process::Child;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::orchestrator::health::{probe_len, HeartbeatMonitor};
use crate::orchestrator::plan::ShardPlan;

/// Supervision knobs (see [`crate::config::LaunchConfig`] for the
/// serialisable source of these values).
#[derive(Clone, Debug)]
pub struct SuperviseOptions {
    /// Kill a shard whose checkpoint has not changed for this long.
    /// The heartbeat ticks once per completed trace cell, so this
    /// must exceed the slowest cell's runtime; as a guard against a
    /// deterministic kill-retry livelock when it doesn't, the
    /// effective timeout doubles on each relaunch of a shard.
    pub stall_timeout: Duration,
    /// How often to poll child exits and heartbeats.
    pub poll_interval: Duration,
    /// Relaunches allowed per shard beyond its initial spawn.
    pub max_retries: u32,
    /// Chaos injection: once, kill the first shard observed with
    /// checkpoint progress — falling back to any running shard after
    /// a few polls, so the drill always fires while the fleet is
    /// alive (the crash-recovery drill the launch smoke tests and CI
    /// run). The injected kill does not consume the shard's retry
    /// budget.
    pub chaos_kill_one: bool,
}

/// What happened to a shard, as told to the event callback.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardEventKind {
    /// A child process started (attempt 1 = initial spawn).
    Spawned { pid: u32, attempt: u32 },
    /// The shard's checkpoint changed size.
    Progress { checkpoint_bytes: u64 },
    /// The chaos drill killed this shard's child.
    ChaosKilled { pid: u32 },
    /// No checkpoint change for longer than the stall timeout; the
    /// child was killed and is eligible for relaunch.
    Stalled { idle_ms: u64 },
    /// The child exited unsuccessfully.
    Crashed { exit_code: Option<i32> },
    /// The child exited successfully.
    Completed,
    /// The supervisor stopped trying (retry budget exhausted, or a
    /// relaunch failed to spawn — the reason says which). The merge
    /// catch-up will re-run this shard's missing scenarios
    /// in-process.
    GaveUp { reason: String },
}

impl ShardEventKind {
    /// Stable event-type tag for the campaign event log
    /// ([`crate::obs`]) — `memfine events --type shard_crashed` and
    /// friends filter on these names.
    pub fn tag(&self) -> &'static str {
        match self {
            ShardEventKind::Spawned { .. } => "shard_spawned",
            ShardEventKind::Progress { .. } => "shard_progress",
            ShardEventKind::ChaosKilled { .. } => "shard_chaos_killed",
            ShardEventKind::Stalled { .. } => "shard_stalled",
            ShardEventKind::Crashed { .. } => "shard_crashed",
            ShardEventKind::Completed => "shard_completed",
            ShardEventKind::GaveUp { .. } => "shard_gave_up",
        }
    }
}

/// One supervision event, tagged by shard index.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEvent {
    pub shard: usize,
    pub kind: ShardEventKind,
}

/// Per-shard summary of a supervision run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardOutcome {
    pub shard: usize,
    /// Child processes launched (1 = clean first run).
    pub spawns: u32,
    /// Stall kills.
    pub stalls: u32,
    /// Unsuccessful exits (not counting stall/chaos kills).
    pub crashes: u32,
    /// Injected chaos kills.
    pub chaos_kills: u32,
    /// Whether some attempt exited successfully.
    pub completed: bool,
    /// Exit code of the last observed exit (`None` after a kill).
    pub last_exit_code: Option<i32>,
}

struct ShardState {
    child: Option<Child>,
    monitor: HeartbeatMonitor,
    retries_used: u32,
    outcome: ShardOutcome,
}

fn kill_and_reap(mut child: Child) {
    // kill on an already-exited child errors; either way wait() reaps
    let _ = child.kill();
    let _ = child.wait();
}

fn spawn_into<S, E>(
    shard: usize,
    plan: &ShardPlan,
    st: &mut ShardState,
    spawn: &mut S,
    on_event: &mut E,
) -> Result<()>
where
    S: FnMut(&ShardPlan, u32) -> Result<Child>,
    E: FnMut(&ShardEvent),
{
    let attempt = st.outcome.spawns + 1;
    let child = spawn(plan, attempt)?;
    st.outcome.spawns = attempt;
    st.monitor.reset(Instant::now());
    on_event(&ShardEvent {
        shard,
        kind: ShardEventKind::Spawned { pid: child.id(), attempt },
    });
    st.child = Some(child);
    Ok(())
}

/// Run the fleet to completion: spawn every shard, poll exits and
/// heartbeats, heal crashes/stalls within the retry budget, and return
/// one [`ShardOutcome`] per shard. A shard that exhausts its budget is
/// reported (`completed: false`) rather than failing the call — the
/// merge layer decides whether the launch can still be healed. Only a
/// *first* spawn failure is fatal (a broken binary/config would fail
/// every shard identically); on that path all already-spawned children
/// are killed before returning.
pub fn supervise<S, E>(
    shards: &[ShardPlan],
    mut spawn: S,
    opts: &SuperviseOptions,
    mut on_event: E,
) -> Result<Vec<ShardOutcome>>
where
    S: FnMut(&ShardPlan, u32) -> Result<Child>,
    E: FnMut(&ShardEvent),
{
    let now = Instant::now();
    let mut states: Vec<ShardState> = (0..shards.len())
        .map(|i| ShardState {
            child: None,
            monitor: HeartbeatMonitor::new(now),
            retries_used: 0,
            outcome: ShardOutcome {
                shard: i,
                spawns: 0,
                stalls: 0,
                crashes: 0,
                chaos_kills: 0,
                completed: false,
                last_exit_code: None,
            },
        })
        .collect();

    for i in 0..states.len() {
        if let Err(e) =
            spawn_into(i, &shards[i], &mut states[i], &mut spawn, &mut on_event)
        {
            for st in states.iter_mut() {
                if let Some(child) = st.child.take() {
                    kill_and_reap(child);
                }
            }
            return Err(e);
        }
    }

    let mut chaos_pending = opts.chaos_kill_one;
    let mut polls: u64 = 0;
    loop {
        polls += 1;
        for i in 0..states.len() {
            let st = &mut states[i];
            let Some(child) = st.child.as_mut() else { continue };
            let mut respawn = false;
            match child.try_wait() {
                Ok(Some(status)) => {
                    st.child = None;
                    st.outcome.last_exit_code = status.code();
                    if status.success() {
                        st.outcome.completed = true;
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Completed,
                        });
                    } else {
                        st.outcome.crashes += 1;
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Crashed { exit_code: status.code() },
                        });
                        respawn = true;
                    }
                }
                Ok(None) => {
                    let now = Instant::now();
                    let len = probe_len(&shards[i].checkpoint);
                    // escalate per relaunch: a cell that is slower
                    // than the configured timeout (rather than a
                    // wedged child) eventually gets room to finish
                    // instead of being killed identically forever
                    let timeout = opts.stall_timeout
                        * (1u32 << (st.outcome.spawns.saturating_sub(1)).min(6));
                    if st.monitor.observe(len, now) {
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Progress {
                                checkpoint_bytes: len.unwrap_or(0),
                            },
                        });
                    } else if st.monitor.stalled(timeout, now) {
                        let idle_ms = st.monitor.idle(now).as_millis() as u64;
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::Stalled { idle_ms },
                        });
                        if let Some(child) = st.child.take() {
                            kill_and_reap(child);
                        }
                        st.outcome.stalls += 1;
                        st.outcome.last_exit_code = None;
                        respawn = true;
                    }
                }
                Err(_) => {
                    // the OS lost track of the child; reclaim and
                    // treat it as a crash
                    if let Some(child) = st.child.take() {
                        kill_and_reap(child);
                    }
                    st.outcome.crashes += 1;
                    st.outcome.last_exit_code = None;
                    on_event(&ShardEvent {
                        shard: i,
                        kind: ShardEventKind::Crashed { exit_code: None },
                    });
                    respawn = true;
                }
            }
            if respawn {
                let st = &mut states[i];
                if st.retries_used < opts.max_retries {
                    st.retries_used += 1;
                    if let Err(e) =
                        spawn_into(i, &shards[i], st, &mut spawn, &mut on_event)
                    {
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::GaveUp {
                                reason: format!("relaunch failed to spawn: {e}"),
                            },
                        });
                    }
                } else {
                    on_event(&ShardEvent {
                        shard: i,
                        kind: ShardEventKind::GaveUp {
                            reason: format!(
                                "retry budget exhausted ({} relaunches)",
                                opts.max_retries
                            ),
                        },
                    });
                }
            }
        }

        // Chaos drill: kill one child, exactly once — preferably the
        // first still-running shard with demonstrable checkpoint
        // progress (a true mid-flight kill); if no child has shown
        // progress after a few polls, any running child will do, so
        // the drill cannot silently no-op on fast grids. Relaunch is
        // unconditional — an injected fault must not consume the
        // shard's own retry budget.
        if chaos_pending {
            let running_with_progress = (0..states.len()).find(|&i| {
                states[i].child.is_some()
                    && states[i].monitor.last_len().unwrap_or(0) > 0
            });
            let target = running_with_progress.or_else(|| {
                if polls >= 3 {
                    (0..states.len()).find(|&i| states[i].child.is_some())
                } else {
                    None
                }
            });
            if let Some(i) = target {
                let st = &mut states[i];
                // a candidate that exited between polls is no strike:
                // leave the drill pending and let the normal exit path
                // reap it next iteration
                let still_running = matches!(
                    st.child.as_mut().expect("target is running").try_wait(),
                    Ok(None)
                );
                if still_running {
                    let child = st.child.take().expect("target is running");
                    let pid = child.id();
                    kill_and_reap(child);
                    st.outcome.chaos_kills += 1;
                    st.outcome.last_exit_code = None;
                    on_event(&ShardEvent {
                        shard: i,
                        kind: ShardEventKind::ChaosKilled { pid },
                    });
                    if let Err(e) =
                        spawn_into(i, &shards[i], st, &mut spawn, &mut on_event)
                    {
                        on_event(&ShardEvent {
                            shard: i,
                            kind: ShardEventKind::GaveUp {
                                reason: format!("relaunch failed to spawn: {e}"),
                            },
                        });
                    }
                    chaos_pending = false;
                }
            }
        }

        if states.iter().all(|s| s.child.is_none()) {
            break;
        }
        std::thread::sleep(opts.poll_interval);
    }

    Ok(states.into_iter().map(|s| s.outcome).collect())
}

#[cfg(test)]
#[cfg(unix)]
mod tests {
    use super::*;
    use crate::config::ShardSpec;
    use std::path::PathBuf;
    use std::process::{Command, Stdio};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-supervise-{}-{name}", std::process::id()));
        p
    }

    fn one_shard(name: &str) -> Vec<ShardPlan> {
        vec![ShardPlan {
            index: 0,
            count: 1,
            spec: ShardSpec { index: 0, count: 1 },
            checkpoint: tmp(&format!("{name}.jsonl")),
            log: tmp(&format!("{name}.log")),
            cells: 1,
            scenarios: 1,
        }]
    }

    fn sh(script: String) -> Result<Child> {
        Command::new("sh")
            .arg("-c")
            .arg(script)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .map_err(crate::Error::Io)
    }

    fn fast_opts() -> SuperviseOptions {
        SuperviseOptions {
            stall_timeout: Duration::from_millis(400),
            poll_interval: Duration::from_millis(20),
            max_retries: 2,
            chaos_kill_one: false,
        }
    }

    #[test]
    fn event_kind_tags_are_distinct_shard_names() {
        let kinds = [
            ShardEventKind::Spawned { pid: 1, attempt: 1 },
            ShardEventKind::Progress { checkpoint_bytes: 0 },
            ShardEventKind::ChaosKilled { pid: 1 },
            ShardEventKind::Stalled { idle_ms: 0 },
            ShardEventKind::Crashed { exit_code: None },
            ShardEventKind::Completed,
            ShardEventKind::GaveUp { reason: String::new() },
        ];
        let tags: std::collections::BTreeSet<_> =
            kinds.iter().map(|k| k.tag()).collect();
        assert_eq!(tags.len(), kinds.len());
        assert!(tags.iter().all(|t| t.starts_with("shard_")));
    }

    #[test]
    fn clean_child_completes_first_spawn() {
        let shards = one_shard("clean");
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, _| sh(format!("printf line >> {}", plan.checkpoint.display())),
            &fast_opts(),
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 1);
        assert_eq!(outcomes[0].crashes + outcomes[0].stalls, 0);
        assert_eq!(outcomes[0].last_exit_code, Some(0));
        assert!(events
            .iter()
            .any(|e| e.kind == ShardEventKind::Completed));
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn crash_is_retried_until_budget_exhausts() {
        let shards = one_shard("crashy");
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |_, _| sh("exit 3".into()),
            &fast_opts(),
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        // initial spawn + max_retries relaunches, then give up
        assert!(!outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 3);
        assert_eq!(outcomes[0].crashes, 3);
        assert_eq!(outcomes[0].last_exit_code, Some(3));
        assert!(events
            .iter()
            .any(|e| matches!(&e.kind, ShardEventKind::GaveUp { reason }
                if reason.contains("retry budget exhausted"))));
    }

    #[test]
    fn retry_budget_is_lifetime_even_when_episodes_heal() {
        // Pins the current retry shape: `retries_used` never resets,
        // so a shard that shows fresh checkpoint progress before every
        // crash still exhausts its lifetime budget and gives up — even
        // though each episode healed. A long campaign with occasional
        // independent failures therefore dies by attrition.
        let shards = one_shard("lifetime");
        std::fs::remove_file(&shards[0].checkpoint).ok();
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, _| {
                // every attempt appends (observable progress), lingers
                // long enough for the supervisor to see it, then dies
                sh(format!(
                    "printf line >> {}; sleep 0.3; exit 1",
                    plan.checkpoint.display()
                ))
            },
            &fast_opts(),
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert!(
            events
                .iter()
                .any(|e| matches!(e.kind, ShardEventKind::Progress { .. })),
            "progress must have been observed between crashes"
        );
        assert!(!outcomes[0].completed);
        // initial spawn + max_retries relaunches, healing notwithstanding
        assert_eq!(outcomes[0].spawns, 3);
        assert!(events
            .iter()
            .any(|e| matches!(&e.kind, ShardEventKind::GaveUp { reason }
                if reason.contains("retry budget exhausted"))));
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn crash_then_success_heals_within_budget() {
        let shards = one_shard("flaky");
        let outcomes = supervise(
            &shards,
            |plan, attempt| {
                if attempt == 1 {
                    sh("exit 1".into())
                } else {
                    sh(format!("printf line >> {}", plan.checkpoint.display()))
                }
            },
            &fast_opts(),
            |_| {},
        )
        .unwrap();
        assert!(outcomes[0].completed);
        assert_eq!(outcomes[0].spawns, 2);
        assert_eq!(outcomes[0].crashes, 1);
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn stalled_child_is_killed_and_relaunched() {
        let shards = one_shard("wedged");
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, attempt| {
                if attempt == 1 {
                    // wedge without ever touching the checkpoint
                    sh("sleep 30".into())
                } else {
                    sh(format!("printf line >> {}", plan.checkpoint.display()))
                }
            },
            &fast_opts(),
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert!(outcomes[0].completed);
        assert_eq!(outcomes[0].stalls, 1);
        assert_eq!(outcomes[0].spawns, 2);
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, ShardEventKind::Stalled { .. })));
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn chaos_kills_a_progressing_child_once_and_heals() {
        let shards = one_shard("chaos");
        std::fs::remove_file(&shards[0].checkpoint).ok();
        let opts = SuperviseOptions { chaos_kill_one: true, ..fast_opts() };
        let mut events = Vec::new();
        let outcomes = supervise(
            &shards,
            |plan, _| {
                // write progress immediately, then linger long enough
                // for the supervisor to observe it and strike
                sh(format!(
                    "printf line >> {}; sleep 2",
                    plan.checkpoint.display()
                ))
            },
            &SuperviseOptions { stall_timeout: Duration::from_secs(30), ..opts },
            |ev| events.push(ev.clone()),
        )
        .unwrap();
        assert_eq!(outcomes[0].chaos_kills, 1);
        assert_eq!(outcomes[0].spawns, 2);
        // the relaunch ran the same script to completion
        assert!(outcomes[0].completed);
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, ShardEventKind::ChaosKilled { .. })));
        std::fs::remove_file(&shards[0].checkpoint).ok();
    }

    #[test]
    fn first_spawn_failure_is_fatal_and_reaps_the_fleet() {
        let mut shards = one_shard("fatal-0");
        shards.push(ShardPlan {
            index: 1,
            count: 2,
            spec: ShardSpec { index: 1, count: 2 },
            checkpoint: tmp("fatal-1.jsonl"),
            log: tmp("fatal-1.log"),
            cells: 1,
            scenarios: 1,
        });
        let err = supervise(
            &shards,
            |plan, _| {
                if plan.index == 0 {
                    sh("sleep 30".into())
                } else {
                    Err(crate::Error::config("no such binary"))
                }
            },
            &fast_opts(),
            |_| {},
        );
        assert!(err.is_err());
    }
}
