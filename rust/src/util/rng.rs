//! Deterministic pseudo-random number generation.
//!
//! The offline registry carries no `rand` crate, so MemFine ships its
//! own: splitmix64 for seeding, xoshiro256** as the main generator
//! (Blackman–Vigna 2018), plus the distribution samplers the routing
//! simulator needs (uniform, normal, gamma/Dirichlet, zipf,
//! multinomial). All paths are deterministic given the seed.
//!
//! ## Chunked fixed-lane batch kernels
//!
//! The hot samplers ([`Rng::gamma_batch`], [`Rng::normal_batch`], the
//! small-`n` Bernoulli path of [`Rng::binomial`]) run over fixed-width
//! lane chunks: a chunk's raw `u64`s are drawn up front, converted and
//! transformed in straight-line per-lane loops the compiler can
//! vectorise/pipeline, and the rare rejection branches are hoisted to
//! one accept-scan per chunk. **Bit-stability is absolute**: rejection
//! samplers speculate — the generator state is snapshotted before each
//! chunk, and on the first lane whose draw the scalar path would have
//! retried, the state is rewound past the accepted lanes' draws and
//! that slot finishes on the scalar path — so the batch kernels
//! consume the stream in exactly the scalar order and are pinned
//! bit-identical to per-draw sampling (unit + property tests). The
//! Bernoulli chunk has no rejection at all: one `u64` per trial either
//! way, so it is the same sampler with the branches lifted out.

/// Lane width of the chunked batch kernels. Eight f64 lanes: two AVX2
/// registers' worth, small enough that a speculation failure wastes
/// little work.
const BATCH_LANES: usize = 8;

/// The uniform-[0,1) mapping every `f64` draw uses (53 mantissa bits).
#[inline]
fn u64_to_f64(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// xoshiro256** PRNG seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// Marsaglia–Tsang constants for Gamma(shape ≥ 1): `d = shape − 1/3`,
/// `c = 1/√(9d)`. Pure in `shape`, so batch samplers hoist them out of
/// their draw loops with bit-identical results.
#[inline]
fn gamma_dc(shape: f64) -> (f64, f64) {
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    (d, c)
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per layer, per iteration)
    /// without correlating with the parent.
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        u64_to_f64(self.next_u64())
    }

    /// Fill `out` with raw generator words, in stream order. The
    /// chunked batch kernels draw a whole chunk's words through this
    /// before doing any lane math.
    #[inline]
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        for slot in out.iter_mut() {
            *slot = self.next_u64();
        }
    }

    /// Uniform integer in [0, n). Lemire multiply-shift with rejection
    /// of the biased low band.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let t = n.wrapping_neg() % n; // 2^64 mod n
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            if (m as u64) >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill `out` with independent standard normals. Chunked fixed-lane
    /// rewrite of per-draw [`Rng::normal`], bit-identical to it: each
    /// chunk's `2·lanes` uniforms are drawn up front and the Box–Muller
    /// transform runs as a straight-line lane loop; the (astronomically
    /// rare) `u1 ≤ 1e-300` rejection rewinds the snapshot past the
    /// accepted lanes and finishes that slot on the scalar path, so the
    /// stream is consumed in exactly the scalar order.
    pub fn normal_batch(&mut self, out: &mut [f64]) {
        let mut raw = [0u64; 2 * BATCH_LANES];
        let mut vals = [0.0f64; BATCH_LANES];
        let mut ok = [false; BATCH_LANES];
        let mut i = 0;
        while i < out.len() {
            let k = BATCH_LANES.min(out.len() - i);
            let snap = self.s;
            self.fill_u64(&mut raw[..2 * k]);
            for j in 0..k {
                let u1 = u64_to_f64(raw[2 * j]);
                let u2 = u64_to_f64(raw[2 * j + 1]);
                ok[j] = u1 > 1e-300;
                vals[j] = (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
            // lanes past the first rejection consumed stream words the
            // scalar path would have spent differently — discard them
            let accepted = ok[..k].iter().take_while(|&&b| b).count();
            out[i..i + accepted].copy_from_slice(&vals[..accepted]);
            i += accepted;
            if accepted < k {
                self.s = snap;
                for _ in 0..2 * accepted {
                    self.next_u64();
                }
                out[i] = self.normal();
                i += 1;
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let (d, c) = gamma_dc(shape);
        self.gamma_core(d, c)
    }

    /// The Marsaglia–Tsang accept-reject loop for precomputed `(d, c)`
    /// (see [`gamma_dc`]). Shared by [`Rng::gamma`] and
    /// [`Rng::gamma_batch`] so the two are the same sampler by
    /// construction.
    #[inline]
    fn gamma_core(&mut self, d: f64, c: f64) -> f64 {
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Fill `out` with independent Gamma(shape, 1) draws. Bit-identical
    /// to calling [`Rng::gamma`] once per slot — the Marsaglia–Tsang
    /// constants (a division plus a square root per call, and the
    /// `1/shape` boost exponent below 1) are hoisted out of the loop,
    /// and the accept-reject loop is run as a chunked fixed-lane
    /// speculative kernel ([`Rng::gamma_chunks`]): the common case — a
    /// lane that passes the squeeze test on its first attempt — runs
    /// branch-free over pre-drawn chunk words; any lane the scalar
    /// sampler would have retried rewinds to its exact stream position
    /// and finishes scalar. This is the Dirichlet hot path: hundreds of
    /// gammas of one shared shape per (iteration, layer).
    pub fn gamma_batch(&mut self, shape: f64, out: &mut [f64]) {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a), constants hoisted
            let (d, c) = gamma_dc(shape + 1.0);
            let inv_shape = 1.0 / shape;
            self.gamma_chunks(d, c, Some(inv_shape), out);
        } else {
            let (d, c) = gamma_dc(shape);
            self.gamma_chunks(d, c, None, out);
        }
    }

    /// The chunked speculative Marsaglia–Tsang kernel behind
    /// [`Rng::gamma_batch`]. Per lane the scalar sampler's first
    /// attempt consumes exactly `u1, u2` (Box–Muller), `u` (squeeze
    /// test) and — on the boost path (`inv_shape = Some(1/a)`) — one
    /// boost uniform; the chunk pre-draws that many words per lane and
    /// replays the identical arithmetic. A lane is committed only when
    /// the scalar path would have accepted that very attempt (`u1`
    /// above the Box–Muller floor, `v > 0`, squeeze accept, boost
    /// uniform nonzero); at the first failing lane the snapshot is
    /// rewound past the committed lanes' words and the slot finishes on
    /// the scalar [`Rng::gamma_core`] path — same draws, same bits.
    fn gamma_chunks(&mut self, d: f64, c: f64, inv_shape: Option<f64>, out: &mut [f64]) {
        let per = if inv_shape.is_some() { 4 } else { 3 };
        let mut raw = [0u64; 4 * BATCH_LANES];
        let mut vals = [0.0f64; BATCH_LANES];
        let mut ok = [false; BATCH_LANES];
        let mut i = 0;
        while i < out.len() {
            let k = BATCH_LANES.min(out.len() - i);
            let snap = self.s;
            self.fill_u64(&mut raw[..per * k]);
            for j in 0..k {
                let u1 = u64_to_f64(raw[per * j]);
                let u2 = u64_to_f64(raw[per * j + 1]);
                let u = u64_to_f64(raw[per * j + 2]);
                let x = (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
                let v = 1.0 + c * x;
                // first-attempt acceptance, exactly the scalar tests
                // (a `v <= 0` attempt would not even have consumed `u`)
                ok[j] = u1 > 1e-300 && v > 0.0 && u < 1.0 - 0.0331 * x.powi(4);
                let v = v * v * v;
                vals[j] = d * v;
            }
            if let Some(inv) = inv_shape {
                for j in 0..k {
                    let bu = u64_to_f64(raw[per * j + 3]);
                    ok[j] = ok[j] && bu > 0.0;
                    vals[j] *= bu.powf(inv);
                }
            }
            let accepted = ok[..k].iter().take_while(|&&b| b).count();
            out[i..i + accepted].copy_from_slice(&vals[..accepted]);
            i += accepted;
            if accepted < k {
                // rewind to the chunk start, burn the committed lanes'
                // words, finish this slot on the scalar path (which
                // handles retries and the second-chance log test)
                self.s = snap;
                for _ in 0..per * accepted {
                    self.next_u64();
                }
                out[i] = match inv_shape {
                    Some(inv) => {
                        let g = self.gamma_core(d, c);
                        let bu = loop {
                            let u = self.f64();
                            if u > 0.0 {
                                break u;
                            }
                        };
                        g * bu.powf(inv)
                    }
                    None => self.gamma_core(d, c),
                };
                i += 1;
            }
        }
    }

    /// Dirichlet(alpha) sample of dimension `alpha.len()` — the expert
    /// popularity vector of the routing simulator. Smaller alpha ⇒ more
    /// concentrated (imbalanced) distributions.
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let draws: Vec<f64> = alpha.iter().map(|&a| self.gamma(a)).collect();
        Self::normalize_simplex(draws)
    }

    /// Symmetric `Dirichlet(alpha·1)` of dimension `n`: bit-identical to
    /// `dirichlet(&vec![alpha; n])` (same gamma draw sequence) without
    /// materialising the concentration vector — the routing hot path
    /// calls this once per (iteration, layer).
    pub fn dirichlet_symmetric(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; n];
        self.dirichlet_symmetric_into(alpha, &mut out);
        out
    }

    /// Allocation-free symmetric Dirichlet: fill `out` with a
    /// `Dirichlet(alpha·1)` sample of dimension `out.len()`.
    /// Bit-identical to [`Rng::dirichlet_symmetric`] (which delegates
    /// here) — batched gamma draws, normalised in place. The trace
    /// generator reuses one buffer across every (iteration, layer)
    /// draw of a cell.
    pub fn dirichlet_symmetric_into(&mut self, alpha: f64, out: &mut [f64]) {
        self.gamma_batch(alpha, out);
        Self::normalize_simplex_in_place(out);
    }

    fn normalize_simplex(mut draws: Vec<f64>) -> Vec<f64> {
        Self::normalize_simplex_in_place(&mut draws);
        draws
    }

    fn normalize_simplex_in_place(draws: &mut [f64]) {
        let sum: f64 = draws.iter().sum();
        if sum <= 0.0 {
            // pathological underflow: fall back to uniform
            let n = draws.len() as f64;
            draws.fill(1.0 / n);
            return;
        }
        for d in draws.iter_mut() {
            *d /= sum;
        }
    }

    /// Multinomial: distribute `n` trials over `probs` (must sum ≈ 1).
    /// O(n) sequential sampling via inverse CDF per trial would be slow
    /// for n≈10⁵; uses the conditional-binomial decomposition instead.
    ///
    /// This is the reference ("slow") path: one conditional binomial
    /// per category, left to right. [`Rng::multinomial_split`] is the
    /// same decomposition over a balanced split tree — cheaper on the
    /// peaky distributions the router produces — but consumes the
    /// stream in a different order, so the two samplers are equal in
    /// distribution, not bit-equal. Callers that have pinned byte-level
    /// outputs (the routing trace) stay on this path by default.
    pub fn multinomial(&mut self, n: u64, probs: &[f64]) -> Vec<u64> {
        let mut out = vec![0u64; probs.len()];
        self.multinomial_into(n, probs, &mut out);
        out
    }

    /// Allocation-free form of [`Rng::multinomial`]: write the counts
    /// into a caller-owned buffer (zeroed here; `out.len()` must equal
    /// `probs.len()`). Bit-identical — the allocating form delegates
    /// here — so the trace generator reuses one count buffer across
    /// every (iteration, layer) draw.
    pub fn multinomial_into(&mut self, n: u64, probs: &[f64], out: &mut [u64]) {
        assert_eq!(out.len(), probs.len(), "multinomial buffer shape");
        out.fill(0);
        let mut remaining = n;
        let mut rest: f64 = 1.0;
        for (i, &p) in probs.iter().enumerate() {
            if remaining == 0 {
                break;
            }
            if i + 1 == probs.len() || rest <= 0.0 {
                out[i] = remaining;
                remaining = 0;
                break;
            }
            let q = (p / rest).clamp(0.0, 1.0);
            let k = self.binomial(remaining, q);
            out[i] = k;
            remaining -= k;
            rest -= p;
        }
        if remaining > 0 {
            let last = out.len() - 1;
            out[last] += remaining;
        }
    }

    /// Multinomial via recursive binomial splitting: draw the total of
    /// the left half as one binomial, recurse into both halves. Exact
    /// (same conditional-binomial decomposition as [`Rng::multinomial`],
    /// applied to a balanced split tree instead of a left-to-right
    /// chain), and much cheaper when the distribution is peaky: any
    /// subtree whose drawn total is zero fills its whole range without
    /// touching the generator, so the cost scales with the number of
    /// *populated* categories rather than with `probs.len()`. This is
    /// the router fast path for paper-scale draws (n ≈ 10⁶ copies over
    /// 256 experts with strongly non-uniform popularity).
    ///
    /// `split_range` with a degenerate "first element vs rest" split is
    /// the sequential algorithm itself — the unit tests pin that mode
    /// bit-identical to `multinomial` on paper-scale inputs, which is
    /// what makes the balanced mode trustworthy as the same sampler.
    pub fn multinomial_split(&mut self, n: u64, probs: &[f64]) -> Vec<u64> {
        let mut out = vec![0u64; probs.len()];
        self.multinomial_split_into(n, probs, &mut out);
        out
    }

    /// Allocation-free form of [`Rng::multinomial_split`] (which
    /// delegates here): zero `out` and run the splitting recursion in
    /// place. Same sampler, same bits, reusable buffer.
    pub fn multinomial_split_into(&mut self, n: u64, probs: &[f64], out: &mut [u64]) {
        assert_eq!(out.len(), probs.len(), "multinomial buffer shape");
        out.fill(0);
        if probs.is_empty() {
            debug_assert_eq!(n, 0, "multinomial_split: trials with no categories");
            return;
        }
        self.split_range(out, probs, 0..probs.len(), (n, 1.0), true);
    }

    /// Conditional-binomial split over `probs[range]` holding the
    /// `(trials, rest)` state, where `rest` is the probability mass not
    /// yet assigned to the left of the range (the sequential
    /// algorithm's running `rest`). `balanced` picks the split point:
    /// midpoint (fast path) or `lo + 1` (degenerate mode, bit-identical
    /// to `multinomial`).
    ///
    /// Runs the recursion on an explicit stack, left child first, so
    /// the binomial draw order — node, whole left subtree, right
    /// subtree — is exactly the recursive order (bit-identical), with
    /// no call overhead and no recursion-depth concern on the
    /// degenerate chain. The left-half sums stay per-node left-to-right
    /// reductions: caching them tree-wide would change float
    /// association and the drawn bits.
    fn split_range(
        &mut self,
        out: &mut [u64],
        probs: &[f64],
        range: std::ops::Range<usize>,
        state: (u64, f64),
        balanced: bool,
    ) {
        // Balanced splits halve the range (stack depth ≤ word size);
        // the degenerate chain resolves its left leaf immediately
        // (depth ≤ 2). 2·64 covers both with headroom.
        let mut stack: Vec<(std::ops::Range<usize>, (u64, f64))> =
            Vec::with_capacity(2 * u64::BITS as usize);
        stack.push((range, state));
        while let Some((range, (t, rest))) = stack.pop() {
            let (lo, hi) = (range.start, range.end);
            debug_assert!(lo < hi);
            if t == 0 {
                continue;
            }
            if hi - lo == 1 || rest <= 0.0 {
                // single category — or no mass left to condition on, in
                // which case the sequential path also dumps the
                // remainder on the current category.
                out[lo] = t;
                continue;
            }
            let mid = if balanced { lo + (hi - lo) / 2 } else { lo + 1 };
            let p_left: f64 = probs[lo..mid].iter().sum();
            let q = (p_left / rest).clamp(0.0, 1.0);
            let k = self.binomial(t, q);
            // right pushed first so the left half pops (and draws) next
            stack.push((mid..hi, (t - k, rest - p_left)));
            stack.push((lo..mid, (k, p_left)));
        }
    }

    /// Binomial(n, p) — BTPE would be overkill; the simulator needs
    /// n up to ~10⁶ with often-tiny p (multinomial tail), so the slow
    /// paths must stay O(min(n, n·p)):
    ///   * large variance → normal approximation,
    ///   * small n → exact Bernoulli inversion,
    ///   * large n, small mean → Poisson approximation (Knuth,
    ///     O(mean) iterations).
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if p <= 0.0 || n == 0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if p > 0.5 {
            return n - self.binomial(n, 1.0 - p);
        }
        let nf = n as f64;
        let var = nf * p * (1.0 - p);
        if var > 30.0 {
            let mean = nf * p;
            let sd = var.sqrt();
            let x = (mean + sd * self.normal() + 0.5).floor();
            return x.clamp(0.0, nf) as u64;
        }
        if n <= 64 {
            // Chunked Bernoulli inversion: one generator word per trial
            // either way, so pre-drawing the whole block and counting
            // in a straight-line compare loop (which autovectorises) is
            // the same sampler bit for bit, minus the per-trial branch.
            let mut raw = [0u64; 64];
            let lanes = &mut raw[..n as usize];
            self.fill_u64(lanes);
            let mut k = 0u64;
            for &r in lanes.iter() {
                k += u64::from(u64_to_f64(r) < p);
            }
            return k;
        }
        // n large, mean ≤ ~30: Poisson(n·p) via Knuth, clamped to n.
        let l = (-nf * p).exp();
        let mut k = 0u64;
        let mut prod = self.f64();
        while prod > l && k < n {
            k += 1;
            prod *= self.f64();
        }
        k.min(n)
    }

    /// Zipf-like rank sampler over [0, n) with exponent `s` (synthetic
    /// corpus generator). Uses rejection-inversion (Hörmann).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // simple inverse-CDF on precomputed-free harmonic approximation
        debug_assert!(n >= 1);
        let u = self.f64();
        if (s - 1.0).abs() < 1e-9 {
            let hn = (n as f64).ln();
            return ((hn * u).exp() - 1.0).clamp(0.0, (n - 1) as f64) as u64;
        }
        let a = 1.0 - s;
        let hn = ((n as f64).powf(a) - 1.0) / a;
        let x = (1.0 + hn * u * a).powf(1.0 / a) - 1.0;
        (x.clamp(0.0, (n - 1) as f64)) as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

// ======================================================================
// RNG v2: counter-based Philox streams
// ======================================================================
//
// Version 2 replaces the sequential xoshiro stream with a counter-based
// generator: every word is a pure function of a `(key, site, lane,
// word-index)` coordinate, evaluated by [`philox4x64`]. Three
// properties fall out, none of which the v1 stream can offer:
//
// * **O(1) random access** — any position in any stream is one block
//   evaluation away ([`CounterRng::skip`] is integer arithmetic, not a
//   replay), so a cell's iterations can be evaluated from any starting
//   point without drawing the prefix. This is what makes intra-cell
//   iteration splitting possible in the sweep engine.
// * **Lane-oblivious wide sampling** — each element of a vector draw
//   owns its own lane coordinate, so a rejection retry advances only
//   that lane's counter. The v1 chunked kernels' snapshot-rewind-replay
//   machinery (needed to keep batch == scalar on one shared stream)
//   disappears: batch == scalar holds *by construction*, because both
//   read the same pure function at the same coordinates.
// * **Trivial parallel determinism** — no generator state is shared
//   between lanes, sites or iterations, so any execution order of any
//   partition of the work reads identical bits.
//
// v2 draws different bits than v1 (it is a different, equally valid
// sample), so it is selected per run via `--rng v2` and recorded as
// `rng_version: 2` in every scenario hash, checkpoint header and trace
// key ([`crate::trace::provenance`]). v1 remains the default.

/// Philox rounds. 10 is the Random123 recommendation for 4x64.
pub const PHILOX_ROUNDS: u32 = 10;
/// Philox4x64 multipliers and Weyl key increments (Random123).
const PHILOX_M0: u64 = 0xD2E7_470E_E14C_6C93;
const PHILOX_M1: u64 = 0xCA5A_8263_9512_1157;
const PHILOX_W0: u64 = 0x9E37_79B9_7F4A_7C15;
const PHILOX_W1: u64 = 0xBB67_AE85_84CA_A73B;

/// High and low 64-bit halves of the 128-bit product `a · b`.
#[inline]
fn mulhilo(a: u64, b: u64) -> (u64, u64) {
    let p = (a as u128) * (b as u128);
    ((p >> 64) as u64, p as u64)
}

/// One Philox4x64-10 block: 256 counter bits + 128 key bits → 4 output
/// words. Pure and stateless — the whole v2 design hangs off this
/// being a plain function of its arguments.
#[inline]
pub fn philox4x64(key: [u64; 2], counter: [u64; 4]) -> [u64; 4] {
    let mut c = counter;
    let (mut k0, mut k1) = (key[0], key[1]);
    for _ in 0..PHILOX_ROUNDS {
        let (hi0, lo0) = mulhilo(PHILOX_M0, c[0]);
        let (hi1, lo1) = mulhilo(PHILOX_M1, c[2]);
        c = [hi1 ^ c[1] ^ k0, lo1, hi0 ^ c[3] ^ k1, lo0];
        k0 = k0.wrapping_add(PHILOX_W0);
        k1 = k1.wrapping_add(PHILOX_W1);
    }
    c
}

/// A v2 word stream: the lane `(key, site, lane)` of the counter
/// space, read sequentially. Word `w` of the stream is word `w mod 4`
/// of the Philox block at counter `[w / 4, lane, site[0], site[1]]` —
/// a pure function, so two `CounterRng`s at the same coordinate always
/// produce identical bits regardless of who read what before.
///
/// The scalar samplers here ([`CounterRng::normal`],
/// [`CounterRng::gamma`], [`CounterRng::binomial`]) are the v2
/// reference semantics; the wide kernels ([`gamma_many2`],
/// [`normal_many2`], [`multinomial_split_into2`]) are pinned
/// bit-identical to running these per lane.
#[derive(Clone, Debug)]
pub struct CounterRng {
    key: [u64; 2],
    site: [u64; 2],
    lane: u64,
    /// Words consumed so far (the stream position).
    pos: u64,
    buf: [u64; 4],
    /// Block index held in `buf` (`u64::MAX` = none yet).
    buf_block: u64,
}

impl CounterRng {
    /// Open the stream at `(key, site, lane)`, position 0.
    pub fn new(key: [u64; 2], site: [u64; 2], lane: u64) -> Self {
        CounterRng { key, site, lane, pos: 0, buf: [0; 4], buf_block: u64::MAX }
    }

    /// Words consumed so far.
    pub fn position(&self) -> u64 {
        self.pos
    }

    /// Jump `words` ahead in O(1): counter arithmetic, no replay. A
    /// stream skipped to position `p` produces exactly the words a
    /// sequential reader sees from its `p`-th draw on.
    pub fn skip(&mut self, words: u64) {
        self.pos += words;
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let block = self.pos / 4;
        if self.buf_block != block {
            self.buf = philox4x64(self.key, [block, self.lane, self.site[0], self.site[1]]);
            self.buf_block = block;
        }
        let w = self.buf[(self.pos % 4) as usize];
        self.pos += 1;
        w
    }

    /// Uniform in [0, 1) — same 53-bit mapping as the v1 stream.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        u64_to_f64(self.next_u64())
    }

    /// Standard normal via Box–Muller (same transform as
    /// [`Rng::normal`], drawn from this lane's counter stream).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang — the same sampler as
    /// [`Rng::gamma`] on this lane's stream. A rejection retries on
    /// *this lane only*: the counter advances, nobody else notices.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let (d, c) = gamma_dc(shape);
        self.gamma_core(d, c)
    }

    /// Marsaglia–Tsang accept-reject for precomputed `(d, c)` —
    /// structurally identical to [`Rng::gamma_core`].
    #[inline]
    fn gamma_core(&mut self, d: f64, c: f64) -> f64 {
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Binomial(n, p) — the same algorithm tiers as [`Rng::binomial`]
    /// (reflection, normal approximation, Bernoulli block, Poisson),
    /// drawing from this lane's stream. The small-`n` Bernoulli block
    /// needs no speculation here: one counter word per trial, read
    /// straight out of the lane's 4-word Philox blocks.
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if p <= 0.0 || n == 0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if p > 0.5 {
            return n - self.binomial(n, 1.0 - p);
        }
        let nf = n as f64;
        let var = nf * p * (1.0 - p);
        if var > 30.0 {
            let mean = nf * p;
            let sd = var.sqrt();
            let x = (mean + sd * self.normal() + 0.5).floor();
            return x.clamp(0.0, nf) as u64;
        }
        if n <= 64 {
            let mut k = 0u64;
            for _ in 0..n {
                k += u64::from(self.f64() < p);
            }
            return k;
        }
        let l = (-nf * p).exp();
        let mut k = 0u64;
        let mut prod = self.f64();
        while prod > l && k < n {
            k += 1;
            prod *= self.f64();
        }
        k.min(n)
    }
}

/// Fill `out` with independent Gamma(shape, 1) draws, element `e` from
/// lane `e` of `(key, site)`. The lane-oblivious v2 counterpart of
/// [`Rng::gamma_batch`]: the common case (first-attempt squeeze
/// accept) runs as a straight-line fixed-lane loop over each lane's
/// first Philox block, and a lane the scalar sampler would retry
/// simply finishes on its own lane stream — **no snapshot, no rewind,
/// no replay**, because no state is shared between lanes. Pinned
/// bit-identical to `CounterRng::new(key, site, e).gamma(shape)` per
/// element.
pub fn gamma_many2(key: [u64; 2], site: [u64; 2], shape: f64, out: &mut [f64]) {
    assert!(shape > 0.0);
    let (boost, d, c, inv) = if shape < 1.0 {
        let (d, c) = gamma_dc(shape + 1.0);
        (true, d, c, 1.0 / shape)
    } else {
        let (d, c) = gamma_dc(shape);
        (false, d, c, 0.0)
    };
    let mut raw = [[0u64; 4]; BATCH_LANES];
    let mut i = 0;
    while i < out.len() {
        let k = BATCH_LANES.min(out.len() - i);
        // Each lane's entire first attempt (u1, u2, squeeze u, boost u)
        // is its block 0 — one Philox evaluation per lane, no ordering
        // between lanes.
        for (j, slot) in raw[..k].iter_mut().enumerate() {
            *slot = philox4x64(key, [0, (i + j) as u64, site[0], site[1]]);
        }
        for j in 0..k {
            let u1 = u64_to_f64(raw[j][0]);
            let u2 = u64_to_f64(raw[j][1]);
            let u = u64_to_f64(raw[j][2]);
            let x = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            let v = 1.0 + c * x;
            // first-attempt acceptance, exactly the scalar tests
            let mut ok = u1 > 1e-300 && v > 0.0 && u < 1.0 - 0.0331 * x.powi(4);
            let v = v * v * v;
            let mut val = d * v;
            if boost {
                let bu = u64_to_f64(raw[j][3]);
                ok = ok && bu > 0.0;
                val *= bu.powf(inv);
            }
            out[i + j] = if ok {
                val
            } else {
                // retries stay on lane (i + j); every other lane's bits
                // are untouched by construction
                CounterRng::new(key, site, (i + j) as u64).gamma(shape)
            };
        }
        i += k;
    }
}

/// Fill `out` with independent standard normals, element `e` from lane
/// `e` — the lane-oblivious v2 [`Rng::normal_batch`]. Bit-identical to
/// `CounterRng::new(key, site, e).normal()` per element.
pub fn normal_many2(key: [u64; 2], site: [u64; 2], out: &mut [f64]) {
    for (e, slot) in out.iter_mut().enumerate() {
        let b = philox4x64(key, [0, e as u64, site[0], site[1]]);
        let u1 = u64_to_f64(b[0]);
        let u2 = u64_to_f64(b[1]);
        *slot = if u1 > 1e-300 {
            (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
        } else {
            CounterRng::new(key, site, e as u64).normal()
        };
    }
}

/// Symmetric `Dirichlet(alpha·1)` under v2: lane-per-element gammas
/// ([`gamma_many2`]) normalised in place, with the same
/// underflow-to-uniform fallback as the v1 path.
pub fn dirichlet_symmetric2(key: [u64; 2], site: [u64; 2], alpha: f64, out: &mut [f64]) {
    gamma_many2(key, site, alpha, out);
    Rng::normalize_simplex_in_place(out);
}

/// Left-to-right conditional-binomial multinomial under v2: category
/// `i`'s binomial draws from lane `i`. Same decomposition as
/// [`Rng::multinomial_into`], different (v2) bits.
pub fn multinomial_into2(key: [u64; 2], site: [u64; 2], n: u64, probs: &[f64], out: &mut [u64]) {
    assert_eq!(out.len(), probs.len(), "multinomial buffer shape");
    out.fill(0);
    let mut remaining = n;
    let mut rest: f64 = 1.0;
    for (i, &p) in probs.iter().enumerate() {
        if remaining == 0 {
            break;
        }
        if i + 1 == probs.len() || rest <= 0.0 {
            out[i] = remaining;
            remaining = 0;
            break;
        }
        let q = (p / rest).clamp(0.0, 1.0);
        let k = CounterRng::new(key, site, i as u64).binomial(remaining, q);
        out[i] = k;
        remaining -= k;
        rest -= p;
    }
    if remaining > 0 {
        let last = out.len() - 1;
        out[last] += remaining;
    }
}

/// Recursive binomial-splitting multinomial under v2: each split-tree
/// node `[lo, hi)` draws its binomial from lane `(lo << 32) | hi`, a
/// coordinate unique to the node. Because no node shares generator
/// state with any other, the walk order of the tree is irrelevant to
/// the drawn bits — the v1 sampler's carefully pinned
/// node-then-left-subtree draw order ([`Rng::split_range`]) is a
/// non-constraint here. Same decomposition, different (v2) bits.
pub fn multinomial_split_into2(
    key: [u64; 2],
    site: [u64; 2],
    n: u64,
    probs: &[f64],
    out: &mut [u64],
) {
    assert_eq!(out.len(), probs.len(), "multinomial buffer shape");
    out.fill(0);
    if probs.is_empty() {
        debug_assert_eq!(n, 0, "multinomial_split: trials with no categories");
        return;
    }
    debug_assert!(
        probs.len() < (1usize << 32),
        "split lane coordinates pack (lo, hi) into 32 bits each"
    );
    let mut stack: Vec<(std::ops::Range<usize>, (u64, f64))> =
        Vec::with_capacity(2 * u64::BITS as usize);
    stack.push((0..probs.len(), (n, 1.0)));
    while let Some((range, (t, rest))) = stack.pop() {
        let (lo, hi) = (range.start, range.end);
        debug_assert!(lo < hi);
        if t == 0 {
            continue;
        }
        if hi - lo == 1 || rest <= 0.0 {
            out[lo] = t;
            continue;
        }
        let mid = lo + (hi - lo) / 2;
        let p_left: f64 = probs[lo..mid].iter().sum();
        let q = (p_left / rest).clamp(0.0, 1.0);
        let lane = ((lo as u64) << 32) | hi as u64;
        let k = CounterRng::new(key, site, lane).binomial(t, q);
        stack.push((mid..hi, (t - k, rest - p_left)));
        stack.push((lo..mid, (k, p_left)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        for &shape in &[0.3, 1.0, 4.5] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(17);
        let p = r.dirichlet(&[0.5; 16]);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn dirichlet_small_alpha_is_peaky() {
        let mut r = Rng::new(19);
        // With alpha = 0.05 the max component should usually dominate.
        let mut dominated = 0;
        for _ in 0..50 {
            let p = r.dirichlet(&[0.05; 8]);
            let max = p.iter().cloned().fold(0.0, f64::max);
            if max > 0.5 {
                dominated += 1;
            }
        }
        assert!(dominated > 25, "only {dominated}/50 peaky");
    }

    /// Run the splitting recursion in degenerate "first element vs
    /// rest" mode — structurally the sequential algorithm.
    fn multinomial_split_first(rng: &mut Rng, n: u64, probs: &[f64]) -> Vec<u64> {
        let mut out = vec![0u64; probs.len()];
        rng.split_range(&mut out, probs, 0..probs.len(), (n, 1.0), false);
        out
    }

    /// Paper-scale inputs: 256 experts, ~2²⁰ token copies, popularity
    /// from a Dirichlet of the given concentration.
    fn paper_scale_probs(seed: u64, alpha: f64) -> Vec<f64> {
        Rng::new(seed).dirichlet_symmetric(alpha, 256)
    }

    #[test]
    fn split_recursion_bit_identical_to_slow_path_paper_scale() {
        // The binomial-splitting sampler is the same conditional-
        // binomial decomposition as the sequential slow path; in
        // degenerate split-first mode the two must agree *bit for bit*
        // from the same generator state. Pin it on paper-scale inputs,
        // both peaky (deep-layer chaos) and near-uniform (calm/dense).
        for (seed, alpha) in [(7u64, 0.02f64), (8, 0.02), (9, 0.55), (10, 50.0)] {
            let probs = paper_scale_probs(seed, alpha);
            let n = 1u64 << 20;
            let slow = Rng::new(seed ^ 0xABCD).multinomial(n, &probs);
            let fast = multinomial_split_first(&mut Rng::new(seed ^ 0xABCD), n, &probs);
            assert_eq!(slow, fast, "seed {seed} alpha {alpha}");
            assert_eq!(slow.iter().sum::<u64>(), n);
        }
    }

    #[test]
    fn multinomial_split_conserves_and_tracks_paper_scale() {
        let n = 1u64 << 20;
        for (seed, alpha) in [(1u64, 0.02f64), (2, 0.55), (3, 50.0)] {
            let probs = paper_scale_probs(seed, alpha);
            let counts = Rng::new(seed).multinomial_split(n, &probs);
            assert_eq!(counts.iter().sum::<u64>(), n, "alpha {alpha}");
            // every populated category tracks its probability to within
            // a loose sampling band
            for (i, (&c, &p)) in counts.iter().zip(&probs).enumerate() {
                let expect = n as f64 * p;
                let slack = 6.0 * (expect.max(1.0)).sqrt() + 8.0;
                assert!(
                    (c as f64 - expect).abs() < slack,
                    "seed {seed} cat {i}: count {c} vs expect {expect:.1}"
                );
            }
        }
    }

    #[test]
    fn multinomial_split_deterministic_and_seed_sensitive() {
        let probs = paper_scale_probs(5, 0.1);
        let a = Rng::new(42).multinomial_split(1 << 20, &probs);
        let b = Rng::new(42).multinomial_split(1 << 20, &probs);
        assert_eq!(a, b);
        let c = Rng::new(43).multinomial_split(1 << 20, &probs);
        assert_ne!(a, c);
    }

    #[test]
    fn multinomial_split_edges() {
        let mut r = Rng::new(11);
        assert_eq!(r.multinomial_split(0, &[0.5, 0.5]), vec![0, 0]);
        assert_eq!(r.multinomial_split(100, &[1.0]), vec![100]);
        // zero-probability category between two live halves stays empty
        let counts = r.multinomial_split(10_000, &[0.5, 0.0, 0.5]);
        assert_eq!(counts[1], 0);
        assert_eq!(counts.iter().sum::<u64>(), 10_000);
        let empty: Vec<u64> = r.multinomial_split(0, &[]);
        assert!(empty.is_empty());
    }

    #[test]
    fn dirichlet_symmetric_bit_identical_to_general() {
        let general = Rng::new(17).dirichlet(&[0.3; 16]);
        let symmetric = Rng::new(17).dirichlet_symmetric(0.3, 16);
        assert_eq!(general, symmetric);
        let s: f64 = symmetric.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gamma_batch_bit_identical_to_per_draw() {
        // Both the boost path (shape < 1, the routing regime) and the
        // direct Marsaglia–Tsang path must replay the exact per-draw
        // stream: same generator state in, same bits out.
        for &shape in &[0.02, 0.3, 0.999, 1.0, 4.5, 50.0] {
            let mut a = Rng::new(23);
            let per_draw: Vec<f64> = (0..257).map(|_| a.gamma(shape)).collect();
            let mut b = Rng::new(23);
            let mut batched = vec![0.0; 257];
            b.gamma_batch(shape, &mut batched);
            for (i, (x, y)) in per_draw.iter().zip(&batched).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "shape {shape} draw {i}: {x} vs {y}"
                );
            }
            // and the generators end in the same state
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn normal_batch_bit_identical_to_per_draw() {
        // Chunk boundaries, tails and the empty batch must all replay
        // the exact per-draw stream and leave the generator in the
        // same state.
        for &n in &[0usize, 1, 7, 8, 9, 64, 257] {
            let mut a = Rng::new(31);
            let per_draw: Vec<f64> = (0..n).map(|_| a.normal()).collect();
            let mut b = Rng::new(31);
            let mut batched = vec![0.0; n];
            b.normal_batch(&mut batched);
            for (i, (x, y)) in per_draw.iter().zip(&batched).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n {n} draw {i}");
            }
            assert_eq!(a.next_u64(), b.next_u64(), "n {n} end state");
        }
    }

    #[test]
    fn binomial_small_n_matches_scalar_bernoulli_replica() {
        // The chunked Bernoulli block must be the scalar per-trial loop
        // bit for bit (same words, same compares), across the whole
        // small-n regime and both p reflections.
        let scalar = |rng: &mut Rng, n: u64, p: f64| -> u64 {
            let mut k = 0u64;
            for _ in 0..n {
                if rng.f64() < p {
                    k += 1;
                }
            }
            k
        };
        for &(seed, n, p) in &[
            (3u64, 1u64, 0.2f64),
            (4, 7, 0.49),
            (5, 64, 0.01),
            (6, 64, 0.5),
            (7, 33, 0.3),
        ] {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let want = scalar(&mut a, n, p);
            assert_eq!(b.binomial(n, p), want, "seed {seed} n {n} p {p}");
            assert_eq!(a.next_u64(), b.next_u64(), "seed {seed} end state");
        }
    }

    #[test]
    fn dirichlet_symmetric_into_bit_identical_and_alloc_free() {
        for &(seed, alpha, n) in &[(17u64, 0.3f64, 16usize), (7, 0.02, 256), (9, 50.0, 64)] {
            let fresh = Rng::new(seed).dirichlet_symmetric(alpha, n);
            // a dirty reused buffer must not leak into the sample
            let mut buf = vec![123.456; n];
            Rng::new(seed).dirichlet_symmetric_into(alpha, &mut buf);
            assert_eq!(fresh, buf, "seed {seed} alpha {alpha}");
        }
    }

    #[test]
    fn multinomial_into_variants_bit_identical() {
        let probs = paper_scale_probs(5, 0.1);
        let n = 1u64 << 20;
        let fresh = Rng::new(42).multinomial(n, &probs);
        let mut buf = vec![999u64; probs.len()];
        Rng::new(42).multinomial_into(n, &probs, &mut buf);
        assert_eq!(fresh, buf);
        let fresh_split = Rng::new(42).multinomial_split(n, &probs);
        let mut buf_split = vec![999u64; probs.len()];
        Rng::new(42).multinomial_split_into(n, &probs, &mut buf_split);
        assert_eq!(fresh_split, buf_split);
        // the two samplers still differ (different stream consumption)
        assert_ne!(fresh, fresh_split);
    }

    #[test]
    fn multinomial_conserves_total() {
        let mut r = Rng::new(23);
        let probs = [0.1, 0.2, 0.3, 0.4];
        for n in [0u64, 1, 10, 1000, 98765] {
            let counts = r.multinomial(n, &probs);
            assert_eq!(counts.iter().sum::<u64>(), n);
        }
    }

    #[test]
    fn multinomial_tracks_probs() {
        let mut r = Rng::new(29);
        let probs = [0.7, 0.2, 0.1];
        let counts = r.multinomial(100_000, &probs);
        assert!((counts[0] as f64 / 1e5 - 0.7).abs() < 0.02);
    }

    #[test]
    fn binomial_edges() {
        let mut r = Rng::new(31);
        assert_eq!(r.binomial(100, 0.0), 0);
        assert_eq!(r.binomial(100, 1.0), 100);
        let k = r.binomial(100, 0.5);
        assert!(k <= 100);
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let mut r = Rng::new(37);
        let mut counts = [0u64; 16];
        for _ in 0..20_000 {
            counts[r.zipf(16, 1.2) as usize] += 1;
        }
        assert!(counts[0] > counts[8], "{counts:?}");
        assert!(counts[1] > counts[12]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(41);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    // ---------------- RNG v2 (counter-based Philox) ----------------

    #[test]
    fn philox_is_pure_and_coordinate_sensitive() {
        let key = [7u64, 11];
        let ctr = [0u64, 1, 2, 3];
        assert_eq!(philox4x64(key, ctr), philox4x64(key, ctr));
        // every coordinate word perturbs the block
        for i in 0..4 {
            let mut c = ctr;
            c[i] ^= 1;
            assert_ne!(philox4x64(key, c), philox4x64(key, ctr), "counter word {i}");
        }
        assert_ne!(philox4x64([8, 11], ctr), philox4x64(key, ctr));
        assert_ne!(philox4x64([7, 12], ctr), philox4x64(key, ctr));
        // and the output is not the counter (the rounds did something)
        assert_ne!(philox4x64(key, ctr), ctr);
    }

    #[test]
    fn counter_rng_skip_is_jump_ahead() {
        // O(1) random access: skipping to position p yields exactly the
        // sequential reader's p-th word, across block boundaries.
        let key = [3u64, 99];
        let site = [5u64, 17];
        let mut seq = CounterRng::new(key, site, 2);
        let words: Vec<u64> = (0..64).map(|_| seq.next_u64()).collect();
        for p in [0u64, 1, 3, 4, 5, 7, 8, 31, 63] {
            let mut jumped = CounterRng::new(key, site, 2);
            jumped.skip(p);
            assert_eq!(jumped.next_u64(), words[p as usize], "offset {p}");
            assert_eq!(jumped.position(), p + 1);
        }
    }

    #[test]
    fn counter_rng_lanes_and_sites_are_independent() {
        let key = [1u64, 2];
        let a: Vec<u64> = {
            let mut r = CounterRng::new(key, [0, 0], 0);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = CounterRng::new(key, [0, 0], 1);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = CounterRng::new(key, [0, 1], 0);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn counter_rng_normal_and_gamma_moments() {
        let key = [13u64, 0];
        let n = 20_000u64;
        let mean_normal: f64 = (0..n)
            .map(|lane| CounterRng::new(key, [0, 0], lane).normal())
            .sum::<f64>()
            / n as f64;
        assert!(mean_normal.abs() < 0.03, "normal mean {mean_normal}");
        for &shape in &[0.3, 1.0, 4.5] {
            let mean = (0..n)
                .map(|lane| CounterRng::new(key, [1, 0], lane).gamma(shape))
                .sum::<f64>()
                / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn gamma_many2_bit_identical_to_per_lane_scalar() {
        // THE lane-oblivious pin: the wide kernel must equal running
        // the scalar sampler independently on every lane — no rewind
        // machinery exists to get wrong.
        let key = [23u64, 5];
        let site = [9u64, 4];
        for &shape in &[0.02, 0.3, 0.999, 1.0, 4.5, 50.0] {
            let mut wide = vec![0.0f64; 257];
            gamma_many2(key, site, shape, &mut wide);
            for (e, &w) in wide.iter().enumerate() {
                let s = CounterRng::new(key, site, e as u64).gamma(shape);
                assert_eq!(w.to_bits(), s.to_bits(), "shape {shape} lane {e}");
            }
        }
    }

    #[test]
    fn normal_many2_bit_identical_to_per_lane_scalar() {
        let key = [31u64, 8];
        let site = [2u64, 7];
        for &n in &[0usize, 1, 7, 8, 9, 64, 257] {
            let mut wide = vec![0.0f64; n];
            normal_many2(key, site, &mut wide);
            for (e, &w) in wide.iter().enumerate() {
                let s = CounterRng::new(key, site, e as u64).normal();
                assert_eq!(w.to_bits(), s.to_bits(), "n {n} lane {e}");
            }
        }
    }

    #[test]
    fn counter_rng_binomial_edges_and_moments() {
        let mut r = CounterRng::new([5, 5], [0, 0], 0);
        assert_eq!(r.binomial(100, 0.0), 0);
        assert_eq!(r.binomial(100, 1.0), 100);
        assert_eq!(r.binomial(0, 0.5), 0);
        // mean over many lanes tracks n·p in every algorithm tier
        for &(n, p) in &[(40u64, 0.3f64), (1000, 0.4), (100_000, 0.0001)] {
            let trials = 2000u64;
            let sum: u64 = (0..trials)
                .map(|lane| CounterRng::new([5, 5], [1, 0], lane).binomial(n, p))
                .sum();
            let mean = sum as f64 / trials as f64;
            let expect = n as f64 * p;
            let sd = (n as f64 * p * (1.0 - p)).sqrt();
            assert!(
                (mean - expect).abs() < 6.0 * sd / (trials as f64).sqrt() + 0.5,
                "n {n} p {p}: mean {mean} vs {expect}"
            );
        }
    }

    #[test]
    fn multinomial2_conserves_and_variants_differ() {
        let key = [77u64, 3];
        let site = [4u64, 9];
        let probs = paper_scale_probs(5, 0.1);
        let n = 1u64 << 20;
        let mut seq = vec![0u64; probs.len()];
        multinomial_into2(key, site, n, &probs, &mut seq);
        let mut split = vec![0u64; probs.len()];
        multinomial_split_into2(key, site, n, &probs, &mut split);
        assert_eq!(seq.iter().sum::<u64>(), n);
        assert_eq!(split.iter().sum::<u64>(), n);
        // different decompositions, different (equally valid) samples
        assert_ne!(seq, split);
        // deterministic
        let mut again = vec![0u64; probs.len()];
        multinomial_split_into2(key, site, n, &probs, &mut again);
        assert_eq!(split, again);
        // and both track the distribution
        for (i, (&c, &p)) in split.iter().zip(&probs).enumerate() {
            let expect = n as f64 * p;
            let slack = 6.0 * (expect.max(1.0)).sqrt() + 8.0;
            assert!(
                (c as f64 - expect).abs() < slack,
                "split cat {i}: count {c} vs expect {expect:.1}"
            );
        }
    }

    #[test]
    fn multinomial_split2_edges() {
        let key = [1u64, 1];
        let site = [0u64, 0];
        let mut out = vec![0u64; 2];
        multinomial_split_into2(key, site, 0, &[0.5, 0.5], &mut out);
        assert_eq!(out, vec![0, 0]);
        let mut one = vec![0u64; 1];
        multinomial_split_into2(key, site, 100, &[1.0], &mut one);
        assert_eq!(one, vec![100]);
        let mut three = vec![0u64; 3];
        multinomial_split_into2(key, site, 10_000, &[0.5, 0.0, 0.5], &mut three);
        assert_eq!(three[1], 0);
        assert_eq!(three.iter().sum::<u64>(), 10_000);
        let mut empty: Vec<u64> = Vec::new();
        multinomial_split_into2(key, site, 0, &[], &mut empty);
        assert!(empty.is_empty());
    }

    #[test]
    fn dirichlet_symmetric2_sums_to_one_and_is_seed_sensitive() {
        let mut p = vec![0.0f64; 256];
        dirichlet_symmetric2([9, 1], [3, 7], 0.02, &mut p);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&x| x >= 0.0));
        let mut q = vec![0.0f64; 256];
        dirichlet_symmetric2([10, 1], [3, 7], 0.02, &mut q);
        assert_ne!(p, q);
        // a dirty buffer must not leak into the sample
        let mut dirty = vec![123.456f64; 256];
        dirichlet_symmetric2([9, 1], [3, 7], 0.02, &mut dirty);
        assert_eq!(p, dirty);
    }
}
