"""AOT pipeline: artifacts exist, parse as HLO text, manifest consistent."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.export(out, M.TINY, seed=7)
    return out, manifest


class TestExport:
    def test_all_entries_written(self, exported):
        out, manifest = exported
        names = {e["name"] for e in manifest["entries"]}
        assert {"train_step", "fwd_loss", "router_topk"} <= names
        for c in aot.CHUNK_BINS:
            assert f"expert_ffn_c{c}" in names
        for e in manifest["entries"]:
            assert os.path.exists(os.path.join(out, e["file"]))

    def test_hlo_text_format(self, exported):
        """Every artifact must be HLO text starting with HloModule —
        the only format xla_extension 0.5.1 round-trips (DESIGN.md §5)."""
        out, manifest = exported
        for e in manifest["entries"]:
            head = open(os.path.join(out, e["file"])).read(200)
            assert head.startswith("HloModule"), e["name"]
            assert "ENTRY" in open(os.path.join(out, e["file"])).read()

    def test_params_bin_size(self, exported):
        out, manifest = exported
        n = manifest["param_count"]
        assert os.path.getsize(os.path.join(out, "params.bin")) == 4 * n

    def test_params_bin_reproducible_by_seed(self, tmp_path):
        a = str(tmp_path / "a")
        b = str(tmp_path / "b")
        aot.export(a, M.TINY, seed=3)
        aot.export(b, M.TINY, seed=3)
        pa = np.fromfile(os.path.join(a, "params.bin"), "<f4")
        pb = np.fromfile(os.path.join(b, "params.bin"), "<f4")
        np.testing.assert_array_equal(pa, pb)

    def test_manifest_layout_matches_model(self, exported):
        _, manifest = exported
        want = [(n, list(s)) for n, s in M.param_shapes(M.TINY)]
        got = [(e["name"], e["shape"]) for e in manifest["param_layout"]]
        assert got == want

    def test_manifest_json_loads(self, exported):
        out, _ = exported
        with open(os.path.join(out, "manifest.json")) as f:
            m = json.load(f)
        assert m["param_count"] == M.param_count(M.TINY)

    def test_chunk_capacities_halve(self, exported):
        """FCDA bins [1,2,4,8] must export capacities C, C/2, C/4, C/8 —
        the linear memory scaling of Eq. 6."""
        _, manifest = exported
        caps = {e["chunk_bin"]: e["capacity"]
                for e in manifest["entries"] if "chunk_bin" in e}
        base = caps[1]
        for c in aot.CHUNK_BINS:
            assert caps[c] == base // c

    def test_kernel_perf_model_present(self, exported):
        _, manifest = exported
        for row in manifest["kernel_perf"]:
            assert row["vmem_bytes_per_step"] > 0
            assert row["mxu_flops_per_expert"] > 0

    def test_coordinator_block_consistent(self, exported):
        """The rust EpCoordinator reads this block; its invariants are
        load-bearing: capacities are drop-free for every bin."""
        _, manifest = exported
        c = manifest["coordinator"]
        assert c["ep"] * c["local_experts"] == c["global_experts"]
        total_copies = c["ep"] * c["tokens_per_rank"] * c["top_k"]
        caps = {e["chunk_bin"]: e["capacity"]
                for e in manifest["entries"] if "chunk_bin" in e}
        for bin_ in c["chunk_bins"]:
            assert caps[bin_] == total_copies // bin_
            assert c["tokens_per_rank"] % bin_ == 0

    def test_router_entry_matches_coordinator_dims(self, exported):
        _, manifest = exported
        c = manifest["coordinator"]
        router = next(e for e in manifest["entries"]
                      if e["name"] == "router_topk")
        assert router["inputs"][0]["shape"] == [c["tokens_per_rank"], c["hidden"]]
        assert router["inputs"][1]["shape"] == [c["hidden"], c["global_experts"]]
        assert router["outputs"][1]["dtype"] == "i32"
