//! Property tests for the counter-based RNG v2 stack and the v1
//! freeze it must never disturb:
//!
//! * [`CounterRng::skip`]'s O(1) jump-ahead must land on exactly the
//!   words a sequential reader sees, at any offset;
//! * the lane-oblivious wide kernels ([`gamma_many2`],
//!   [`normal_many2`]) must match the scalar per-lane samplers bit
//!   for bit — including rejection-heavy sub-one shapes;
//! * a fused cell split at *any* iteration boundary must fold back
//!   bit-identically to the whole-cell evaluation, under both rng
//!   versions;
//! * the v2 sweep engine must emit byte-identical artifacts at any
//!   forced split width;
//! * v1 provenance must keep serialising to the exact historical hash
//!   documents — no `rng_version` field, `current == with(_, V1)` —
//!   so every pre-existing checkpoint and trace key survives this PR.

use memfine::config::{model_i, paper_run, Method, SweepConfig};
use memfine::prop::{assert_prop, Gen, PairGen, U64Range};
use memfine::router::GatingSim;
use memfine::sim;
use memfine::sweep::{run_sweep_with, SweepRunOptions};
use memfine::trace::{
    trace_key, RngVersion, RouterSampler, SharedRoutingTrace, TraceProvenance,
};
use memfine::util::rng::{gamma_many2, normal_many2, CounterRng, Rng};

#[test]
fn prop_counter_skip_matches_sequential_at_random_offsets() {
    // A stream skipped to position p must read exactly what a
    // sequential reader reads from its p-th word on — across block
    // boundaries (offsets are word counts; blocks hold 4 words).
    assert_prop(
        241,
        60,
        &PairGen(U64Range(0, 1 << 20), U64Range(0, 4099)),
        |&(seed, off): &(u64, u64)| {
            let key = [seed, 0xC0FFEE];
            let site = [seed ^ 5, seed % 3];
            let lane = seed % 7;
            let mut seq = CounterRng::new(key, site, lane);
            for _ in 0..off {
                seq.next_u64();
            }
            let mut jump = CounterRng::new(key, site, lane);
            jump.skip(off);
            if seq.position() != jump.position() {
                return Err(format!(
                    "offset {off}: positions diverge ({} vs {})",
                    seq.position(),
                    jump.position()
                ));
            }
            for w in 0..16 {
                let (a, b) = (seq.next_u64(), jump.next_u64());
                if a != b {
                    return Err(format!(
                        "seed {seed} offset {off} word {w}: {a:#x} != {b:#x}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// (seed, alpha, length) cases for the wide kernels; alpha spans
/// (0.001, 2.0] so both the boost path (alpha < 1) and the plain
/// Marsaglia–Tsang path get rejection-heavy coverage.
#[derive(Clone, Debug)]
struct KernelCase;

impl Gen for KernelCase {
    type Value = (u64, f64, usize);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let seed = rng.below(1 << 20);
        let alpha = (1 + rng.below(2000)) as f64 / 1000.0;
        let n = 1 + rng.below(97) as usize;
        (seed, alpha, n)
    }
}

#[test]
fn prop_lane_oblivious_kernels_match_scalar_lanes() {
    assert_prop(251, 40, &KernelCase, |&(seed, alpha, n): &(u64, f64, usize)| {
        let key = [seed, 0xBEEF];
        let site = [seed ^ 11, 2];
        let mut wide = vec![0.0; n];
        gamma_many2(key, site, alpha, &mut wide);
        for (e, &w) in wide.iter().enumerate() {
            let s = CounterRng::new(key, site, e as u64).gamma(alpha);
            if w.to_bits() != s.to_bits() {
                return Err(format!(
                    "gamma alpha {alpha} seed {seed} lane {e}: {w} != {s}"
                ));
            }
        }
        normal_many2(key, site, &mut wide);
        for (e, &w) in wide.iter().enumerate() {
            let s = CounterRng::new(key, site, e as u64).normal();
            if w.to_bits() != s.to_bits() {
                return Err(format!("normal seed {seed} lane {e}: {w} != {s}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cell_split_at_any_boundary_folds_bit_identical() {
    // Whole-cell evaluation vs a split at a random interior boundary,
    // under both rng versions: the fold must reproduce every
    // aggregate bit (avg_tgs compared by to_bits via PartialEq).
    assert_prop(
        257,
        24,
        &PairGen(U64Range(0, 1 << 16), U64Range(0, 10)),
        |&(seed, cut): &(u64, u64)| {
            let mut base = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
            base.iterations = 9;
            base.seed = seed;
            let methods = [
                Method::FullRecompute,
                Method::Mact(vec![1, 2, 4, 8]),
            ];
            let cut = cut.min(base.iterations);
            for rng in [RngVersion::V1, RngVersion::V2] {
                let gating =
                    GatingSim::new(base.model.clone(), base.parallel.clone(), seed)
                        .with_rng(rng);
                let trace = SharedRoutingTrace::generate(&gating, base.iterations);
                let whole = sim::evaluate_cell(&base, &methods, &trace)
                    .map_err(|e| format!("whole: {e}"))?;
                let a = sim::evaluate_cell_range(&base, &methods, &trace, 0, cut)
                    .map_err(|e| format!("lo: {e}"))?;
                let b = sim::evaluate_cell_range(
                    &base,
                    &methods,
                    &trace,
                    cut,
                    base.iterations,
                )
                .map_err(|e| format!("hi: {e}"))?;
                let folded = sim::fold_cell_partials(vec![a, b])
                    .map_err(|e| format!("fold: {e}"))?;
                if folded != whole {
                    return Err(format!(
                        "seed {seed} cut {cut} rng {}: split fold diverged",
                        rng.tag()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The tiny grid the engine-level properties sweep.
fn tiny_grid() -> SweepConfig {
    SweepConfig {
        models: vec!["i".into()],
        methods: vec![Method::FullRecompute, Method::Mact(vec![1, 2, 4, 8])],
        seeds: vec![7, 11],
        iterations: 8,
    }
}

#[test]
fn prop_engine_v2_is_byte_identical_at_any_split_width() {
    let cfg = tiny_grid();
    let serial = run_sweep_with(
        &cfg,
        &SweepRunOptions { workers: 1, rng: RngVersion::V2, ..Default::default() },
    )
    .expect("serial v2 sweep");
    let golden = serial.report.to_json().to_string_pretty();
    assert_prop(263, 8, &U64Range(1, 13), |&width: &u64| {
        let summary = run_sweep_with(
            &cfg,
            &SweepRunOptions {
                workers: 3,
                rng: RngVersion::V2,
                split_iters: width,
                ..Default::default()
            },
        )
        .map_err(|e| format!("split sweep: {e}"))?;
        if summary.report.to_json().to_string_pretty() != golden {
            return Err(format!("split width {width} changed the artifact bytes"));
        }
        Ok(())
    });
}

#[test]
fn v1_provenance_hashes_stay_frozen() {
    // The migration contract this PR must not break: v1 hash docs are
    // byte-identical to the pre-rng era (no rng_version field), so
    // `current` and `with(_, V1)` agree on every scenario hash and
    // trace key; default engine options still mean v1.
    for sampler in [RouterSampler::Sequential, RouterSampler::Split] {
        let cur = TraceProvenance::current(sampler);
        let v1 = TraceProvenance::with(sampler, RngVersion::V1);
        assert_eq!(cur, v1);
        let doc = memfine::json::obj(v1.hash_fields()).to_string_compact();
        assert!(
            !doc.contains("rng_version"),
            "v1 hash doc grew a field: {doc}"
        );
        let run = paper_run(model_i(), Method::FullRecompute);
        assert_eq!(
            memfine::sweep::checkpoint::scenario_hash(&run, &cur),
            memfine::sweep::checkpoint::scenario_hash(&run, &v1),
        );
        assert_eq!(
            trace_key(&run.model, &run.parallel, run.seed, 8, &cur),
            trace_key(&run.model, &run.parallel, run.seed, 8, &v1),
        );
    }

    // default-options engine run == explicit-v1 run, byte for byte
    let cfg = tiny_grid();
    let default_run = run_sweep_with(
        &cfg,
        &SweepRunOptions { workers: 2, ..Default::default() },
    )
    .expect("default sweep");
    let explicit_v1 = run_sweep_with(
        &cfg,
        &SweepRunOptions { workers: 2, rng: RngVersion::V1, ..Default::default() },
    )
    .expect("explicit v1 sweep");
    assert_eq!(
        default_run.report.to_json().to_string_pretty(),
        explicit_v1.report.to_json().to_string_pretty(),
        "default options no longer mean rng v1"
    );
}
