//! Imbalance sweep: how routing skew drives memory and throughput, and
//! how each method responds — the paper's motivation (Figs. 2/4) as a
//! parameter study.
//!
//! Sweeps the gating simulator's imbalance intensity from near-uniform
//! to near-collapse and reports, for each level: the hottest rank's
//! share, the activation peak under Methods 1/2/3, OOM verdicts, and
//! the per-iteration time ratio — showing the crossover where chunking
//! turns from overhead into a win.
//!
//! Run: `cargo run --release --example imbalance_sweep`

use memfine::bench::BenchReport;
use memfine::config::{model_i, paper_run, Method};
use memfine::memory::ActivationModel;
use memfine::perf::PerfModel;
use memfine::router::{GatingParams, GatingSim};
use memfine::util::fmt_bytes;

fn main() -> memfine::Result<()> {
    memfine::logging::init();
    let run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
    let act = ActivationModel::new(&run);
    let perf = PerfModel::new(run.model.clone(), run.parallel.clone(), run.dtype_bytes);
    let mact = memfine::chunk::Mact::new(&run, vec![1, 2, 4, 8]);

    let mut report = BenchReport::new(
        "imbalance sweep — Model I, stage 1",
        &[
            "alpha", "hot-rank share", "s'' max", "act m1", "act m3",
            "m1 fits", "mact c", "t(m1)/t(m3)",
        ],
    );

    // Sweep the Dirichlet concentration from uniform-ish to collapsed.
    for &alpha in &[5.0, 1.0, 0.3, 0.1, 0.02, 0.005, 0.002, 0.001] {
        let params = GatingParams {
            base_alpha: alpha,
            depth_slope: 0.0,
            chaos_gain: 0.0,
            ..GatingParams::default()
        };
        let sim = GatingSim::new(run.model.clone(), run.parallel.clone(), 7)
            .with_params(params);
        let routing = sim.route(0, run.model.layers - 1);
        let max_recv = routing.max_received();
        let share = max_recv as f64 / sim.total_copies() as f64;
        let decision = mact.decide(1, max_recv);
        let c = decision.chosen_c;
        let act_m1 = act.peak_bytes(1, max_recv, true);
        let act_m3 = act.peak_bytes_chunked(1, max_recv, c, true);
        let budget = (run.alpha * run.gpu_mem_bytes as f64) as u64;
        let static1 = memfine::memory::StaticModel::new(&run).bytes_on_rank(1);
        let t_m1 = perf.moe_layer_method1(max_recv).total();
        let t_m3 = perf.moe_layer_memfine(max_recv, c, true).total();
        report.row(&[
            format!("{alpha}"),
            format!("{:.1}%", share * 100.0),
            max_recv.to_string(),
            fmt_bytes(act_m1),
            fmt_bytes(act_m3),
            if static1 + act_m1 <= budget { "yes".into() } else { "OOM".to_string() },
            c.to_string(),
            format!("{:.2}", t_m1 / t_m3),
        ]);
    }
    report.print();
    println!("\nreading: as the hot-rank share grows, Method 1 first loses throughput (ratio > 1)");
    println!("and then memory (OOM); MACT raises c only when the memory model demands it.");
    Ok(())
}
