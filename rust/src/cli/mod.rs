//! Tiny CLI argument parser (the registry carries no `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed accessors and generated usage text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

/// Declarative option spec used for usage text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

impl Args {
    /// Parse raw args (without argv[0]).
    ///
    /// Every `--name` token is treated as an option if followed by a
    /// non-`--` token and `known_value_opts` lists it (or the token
    /// contains `=`); otherwise it is a flag.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        known_value_opts: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let tokens: Vec<String> = raw.into_iter().collect();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err(Error::Cli("bare '--' not supported".into()));
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known_value_opts.contains(&stripped)
                    && i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                {
                    args.options
                        .insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Parse a comma-separated u64 list (e.g. `--bins 1,2,4,8`).
    pub fn get_u64_list(&self, name: &str, default: &[u64]) -> Result<Vec<u64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| {
                        Error::Cli(format!("--{name} expects u64 list, got '{v}'"))
                    })
                })
                .collect(),
        }
    }
}

/// Render aligned usage text from option specs.
pub fn usage(program: &str, about: &str, commands: &[(&str, &str)], opts: &[OptSpec]) -> String {
    let mut out = format!("{about}\n\nUSAGE:\n    {program} <command> [options]\n");
    if !commands.is_empty() {
        out.push_str("\nCOMMANDS:\n");
        let w = commands.iter().map(|(c, _)| c.len()).max().unwrap_or(0);
        for (c, h) in commands {
            out.push_str(&format!("    {c:w$}    {h}\n"));
        }
    }
    if !opts.is_empty() {
        out.push_str("\nOPTIONS:\n");
        let w = opts.iter().map(|o| o.name.len()).max().unwrap_or(0) + 2;
        for o in opts {
            let name = format!("--{}", o.name);
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("    {name:w$}  {}{def}\n", o.help));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], known: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), known).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["simulate", "foo", "bar"], &[]);
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.positional, vec!["foo", "bar"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["run", "--iters", "25", "--model=ii"], &["iters"]);
        assert_eq!(a.get("iters"), Some("25"));
        assert_eq!(a.get("model"), Some("ii"));
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["run", "--verbose", "--seed", "7"], &["seed"]);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("seed"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn unknown_value_opt_becomes_flag() {
        // "--fast 3": fast not declared as value-taking → flag + positional
        let a = parse(&["cmd", "--fast", "3"], &[]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.positional, vec!["3"]);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse(&["x", "--alpha=0.8"], &[]);
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 0.8);
        assert_eq!(a.get_u64("missing", 42).unwrap(), 42);
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = parse(&["x", "--n=abc"], &[]);
        assert!(a.get_u64("n", 0).is_err());
    }

    #[test]
    fn u64_list_parses() {
        let a = parse(&["x", "--bins=1,2,4,8"], &[]);
        assert_eq!(a.get_u64_list("bins", &[]).unwrap(), vec![1, 2, 4, 8]);
        let b = parse(&["x"], &[]);
        assert_eq!(b.get_u64_list("bins", &[3]).unwrap(), vec![3]);
    }

    #[test]
    fn usage_lists_commands_and_defaults() {
        let text = usage(
            "memfine",
            "MemFine",
            &[("plan", "memory plan")],
            &[OptSpec { name: "seed", help: "rng seed", takes_value: true, default: Some("0") }],
        );
        assert!(text.contains("plan"));
        assert!(text.contains("--seed"));
        assert!(text.contains("[default: 0]"));
    }
}
