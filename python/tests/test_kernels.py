"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes; every kernel must match its ref.py
oracle to float tolerance (paper-faithful: the chunked kernel IS the
expert computation of Eq. 4/6).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.expert_ffn import (
    expert_ffn,
    expert_ffn_ad,
    mxu_flops,
    vmem_bytes,
)
from compile.kernels.router_topk import router_topk


def _rand(key, shape, dtype=jnp.float32, scale=0.3):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _ffn_inputs(seed, e, c, h, g, dtype=jnp.float32, mask_p=0.3):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _rand(ks[0], (e, c, h), dtype, 1.0)
    w1 = _rand(ks[1], (e, h, g), dtype)
    w3 = _rand(ks[2], (e, h, g), dtype)
    w2 = _rand(ks[3], (e, g, h), dtype)
    mask = (jax.random.uniform(ks[4], (e, c)) > mask_p).astype(jnp.float32)
    return x, w1, w3, w2, mask


class TestExpertFfnKernel:
    def test_matches_ref_basic(self):
        x, w1, w3, w2, mask = _ffn_inputs(0, e=4, c=16, h=32, g=64)
        out = expert_ffn(x, w1, w3, w2, mask)
        want = ref.expert_ffn_ref(x, w1, w3, w2, mask)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_full_mask_equals_unmasked_ref(self):
        x, w1, w3, w2, _ = _ffn_inputs(1, e=2, c=8, h=16, g=32)
        mask = jnp.ones((2, 8), jnp.float32)
        out = expert_ffn(x, w1, w3, w2, mask)
        want = ref.expert_ffn_ref(x, w1, w3, w2)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_padded_slots_are_zero(self):
        x, w1, w3, w2, mask = _ffn_inputs(2, e=3, c=24, h=16, g=32, mask_p=0.5)
        out = np.asarray(expert_ffn(x, w1, w3, w2, mask))
        dead = np.asarray(mask) == 0.0
        assert np.all(out[dead] == 0.0)

    def test_zero_mask_zero_output(self):
        x, w1, w3, w2, _ = _ffn_inputs(3, e=2, c=8, h=16, g=16)
        out = expert_ffn(x, w1, w3, w2, jnp.zeros((2, 8), jnp.float32))
        assert np.all(np.asarray(out) == 0.0)

    @pytest.mark.parametrize("token_tile", [4, 8, 16])
    def test_tile_invariance(self, token_tile):
        """Output must not depend on the BlockSpec tile choice."""
        x, w1, w3, w2, mask = _ffn_inputs(4, e=2, c=16, h=16, g=32)
        out = expert_ffn(x, w1, w3, w2, mask, token_tile=token_tile)
        want = ref.expert_ffn_ref(x, w1, w3, w2, mask)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_rejects_indivisible_tile(self):
        x, w1, w3, w2, mask = _ffn_inputs(5, e=2, c=12, h=16, g=16)
        with pytest.raises(ValueError, match="not divisible"):
            expert_ffn(x, w1, w3, w2, mask, token_tile=8)

    def test_bf16_close_to_f32_ref(self):
        x, w1, w3, w2, mask = _ffn_inputs(6, e=2, c=8, h=16, g=32,
                                          dtype=jnp.bfloat16)
        out = expert_ffn(x, w1, w3, w2, mask)
        assert out.dtype == jnp.bfloat16
        want = ref.expert_ffn_ref(
            x.astype(jnp.float32), w1.astype(jnp.float32),
            w3.astype(jnp.float32), w2.astype(jnp.float32), mask)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), want, rtol=0.1, atol=0.1)

    @settings(max_examples=12, deadline=None)
    @given(
        e=st.integers(1, 5),
        c_tiles=st.integers(1, 3),
        h=st.sampled_from([8, 16, 32]),
        g=st.sampled_from([8, 24, 48]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, e, c_tiles, h, g, seed):
        c = 8 * c_tiles
        x, w1, w3, w2, mask = _ffn_inputs(seed, e=e, c=c, h=h, g=g)
        out = expert_ffn(x, w1, w3, w2, mask)
        want = ref.expert_ffn_ref(x, w1, w3, w2, mask)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    def test_vmem_model_positive_and_monotone(self):
        a = vmem_bytes(8, 256, 512)
        b = vmem_bytes(16, 256, 512)
        assert 0 < a < b

    def test_mxu_flops_linear_in_tokens(self):
        assert mxu_flops(128, 64, 32) == 2 * mxu_flops(64, 64, 32)


class TestExpertFfnVjp:
    def test_grads_match_ref_autodiff(self):
        x, w1, w3, w2, mask = _ffn_inputs(7, e=2, c=8, h=16, g=16)

        def f_kernel(x, w1, w3, w2):
            return jnp.sum(jnp.sin(expert_ffn_ad(x, w1, w3, w2, mask)))

        def f_ref(x, w1, w3, w2):
            return jnp.sum(jnp.sin(ref.expert_ffn_ref(x, w1, w3, w2, mask)))

        g_k = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(x, w1, w3, w2)
        g_r = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, w1, w3, w2)
        for a, b in zip(g_k, g_r):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_value_matches_kernel(self):
        x, w1, w3, w2, mask = _ffn_inputs(8, e=3, c=8, h=8, g=8)
        np.testing.assert_allclose(
            expert_ffn_ad(x, w1, w3, w2, mask),
            expert_ffn(x, w1, w3, w2, mask), rtol=1e-6, atol=1e-6)

    def test_no_intermediate_residuals(self):
        """The custom VJP must stash only the chunk inputs (the paper's
        chunked-recompute memory contract): residual pytree leaves are
        exactly {x, w1, w3, w2, mask}."""
        x, w1, w3, w2, mask = _ffn_inputs(9, e=2, c=8, h=8, g=8)
        _, vjp_fn = jax.vjp(expert_ffn_ad, x, w1, w3, w2, mask)
        leaves = jax.tree_util.tree_leaves(vjp_fn)
        shapes = sorted(tuple(l.shape) for l in leaves if hasattr(l, "shape"))
        want = sorted([x.shape, w1.shape, w3.shape, w2.shape, mask.shape])
        assert shapes == want, f"residuals {shapes} != inputs {want}"


class TestRouterKernel:
    def test_matches_ref_basic(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (64, 32))
        wg = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        wk, ik = router_topk(x, wg, 2)
        wr, ir = ref.router_topk_ref(x, wg, 2)
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_allclose(wk, wr, rtol=1e-5, atol=1e-6)

    def test_weights_sum_to_one(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
        wg = jax.random.normal(jax.random.PRNGKey(3), (16, 4))
        wk, _ = router_topk(x, wg, 3, token_tile=16)
        np.testing.assert_allclose(np.sum(np.asarray(wk), -1), 1.0, rtol=1e-5)

    def test_indices_distinct_per_token(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (32, 16))
        wg = jax.random.normal(jax.random.PRNGKey(5), (16, 8))
        _, ik = router_topk(x, wg, 4, token_tile=8)
        ik = np.asarray(ik)
        for row in ik:
            assert len(set(row.tolist())) == 4

    def test_topk_equals_experts_selects_all(self):
        x = jax.random.normal(jax.random.PRNGKey(6), (16, 8))
        wg = jax.random.normal(jax.random.PRNGKey(7), (8, 4))
        _, ik = router_topk(x, wg, 4, token_tile=16)
        for row in np.asarray(ik):
            assert sorted(row.tolist()) == [0, 1, 2, 3]

    def test_rejects_indivisible_tokens(self):
        x = jnp.zeros((30, 8))
        wg = jnp.zeros((8, 4))
        with pytest.raises(ValueError, match="not divisible"):
            router_topk(x, wg, 2, token_tile=32)

    @settings(max_examples=10, deadline=None)
    @given(
        tiles=st.integers(1, 3),
        h=st.sampled_from([8, 16]),
        e=st.sampled_from([4, 8, 16]),
        k=st.integers(1, 4),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, tiles, h, e, k, seed):
        t = 16 * tiles
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        x = jax.random.normal(ks[0], (t, h))
        wg = jax.random.normal(ks[1], (h, e))
        wk, ik = router_topk(x, wg, k, token_tile=16)
        wr, ir = ref.router_topk_ref(x, wg, k)
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_allclose(wk, wr, rtol=1e-4, atol=1e-5)
