//! Synthetic training data: a Zipf-distributed token stream with local
//! n-gram structure, batched for the train-step executable.
//!
//! The E2E driver (examples/train_moe.rs) trains on this corpus; the
//! bigram coupling gives the model something learnable so the loss
//! curve drops well below the unigram entropy floor.

use crate::util::rng::Rng;

/// Synthetic corpus sampler.
#[derive(Clone, Debug)]
pub struct Corpus {
    vocab: u32,
    zipf_s: f64,
    /// Probability that token t+1 is a deterministic function of token
    /// t (learnable bigram structure) instead of a fresh Zipf draw.
    bigram_p: f64,
    rng: Rng,
}

impl Corpus {
    pub fn new(vocab: u32, seed: u64) -> Self {
        assert!(vocab >= 4);
        Corpus { vocab, zipf_s: 1.1, bigram_p: 0.75, rng: Rng::new(seed) }
    }

    /// Deterministic successor used for the bigram structure.
    fn successor(&self, t: u32) -> u32 {
        (t.wrapping_mul(2654435761).wrapping_add(12345)) % self.vocab
    }

    /// Sample one sequence of `len` token ids.
    pub fn sequence(&mut self, len: usize) -> Vec<u32> {
        let mut seq = Vec::with_capacity(len);
        let mut prev = self.rng.zipf(self.vocab as u64, self.zipf_s) as u32;
        seq.push(prev);
        for _ in 1..len {
            let next = if self.rng.f64() < self.bigram_p {
                self.successor(prev)
            } else {
                self.rng.zipf(self.vocab as u64, self.zipf_s) as u32
            };
            seq.push(next);
            prev = next;
        }
        seq
    }

    /// Sample a (batch, seq) matrix flattened row-major as i32 — the
    /// exact layout the train_step executable expects.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            out.extend(self.sequence(seq).into_iter().map(|t| t as i32));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut c = Corpus::new(512, 0);
        for &t in &c.batch(4, 64) {
            assert!((0..512).contains(&t));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Corpus::new(512, 9).batch(2, 32);
        let b = Corpus::new(512, 9).batch(2, 32);
        assert_eq!(a, b);
        let c = Corpus::new(512, 10).batch(2, 32);
        assert_ne!(a, c);
    }

    #[test]
    fn batch_shape() {
        let mut c = Corpus::new(100, 1);
        assert_eq!(c.batch(3, 17).len(), 51);
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // successor(t) must follow t far more often than chance.
        let mut c = Corpus::new(256, 2);
        let seq = c.sequence(5000);
        let mut hits = 0;
        for w in seq.windows(2) {
            if w[1] == c.successor(w[0]) {
                hits += 1;
            }
        }
        let rate = hits as f64 / 4999.0;
        assert!(rate > 0.5, "bigram rate {rate}");
    }

    #[test]
    fn zipf_skews_unigrams() {
        let mut c = Corpus::new(1000, 3);
        let seq = c.sequence(20_000);
        let low = seq.iter().filter(|&&t| t < 10).count();
        let high = seq.iter().filter(|&&t| (500..510).contains(&t)).count();
        assert!(low > high * 3, "low {low} high {high}");
    }
}
