//! `cargo bench --bench hotpaths` — micro-benchmarks of the Layer-3
//! hot paths (EXPERIMENTS.md §Perf tracks these before/after):
//!
//!   * router sampling (multinomial over 256 experts)
//!   * gamma draws: per-draw vs batched (the Dirichlet inner loop)
//!   * dispatch planning (token-level all-to-all plan)
//!   * MACT decision (stage path and hoisted-budget path)
//!   * FCDA schedule construction
//!   * memory-model evaluation + the memoised MemFine timing kernel
//!   * fused cell evaluation vs per-method trace evaluation (Model I)
//!   * JSON parse of a manifest-sized document
//!   * PJRT execute round-trip overhead (when artifacts are present)

use memfine::bench::{fmt_time, time_fn, BenchReport};
use memfine::chunk::{Mact, RecomputeSchedule};
use memfine::config::{model_i, paper_parallel, paper_run, Method};
use memfine::dispatch;
use memfine::memory::ActivationModel;
use memfine::perf::PerfModel;
use memfine::router::GatingSim;
use memfine::sim::{evaluate_cell, run_scenario_on_trace, Simulator};
use memfine::util::rng::{gamma_many2, philox4x64, CounterRng, Rng};

fn main() {
    memfine::logging::init();
    let mut report = BenchReport::new(
        "L3 hot paths",
        &["path", "median", "p90", "ops/s"],
    );
    let mut add = |t: memfine::bench::Timing| {
        report.row(&[
            t.name.clone(),
            fmt_time(t.median_s),
            fmt_time(t.p90_s),
            format!("{:.0}", t.per_sec()),
        ]);
    };

    // Router sampling.
    let sim = GatingSim::new(model_i(), paper_parallel(), 7);
    add(time_fn("router.route (256 experts, 1M copies)", 3, 30, || {
        sim.route(7, 15).max_received()
    }));

    // Gamma sampling: per-draw vs batched (the chaos-regime shape the
    // Dirichlet popularity draw uses, 256 draws = one popularity
    // vector). Bit-identical samplers; the batch hoists the
    // Marsaglia–Tsang constants and the boost exponent.
    let mut rng = Rng::new(11);
    add(time_fn("rng.gamma x256 (shape 0.02)", 30, 2_000, || {
        let mut acc = 0.0;
        for _ in 0..256 {
            acc += rng.gamma(0.02);
        }
        acc
    }));
    let mut rng = Rng::new(11);
    let mut gamma_buf = vec![0.0f64; 256];
    add(time_fn("rng.gamma_batch(256, shape 0.02)", 30, 2_000, || {
        rng.gamma_batch(0.02, &mut gamma_buf);
        gamma_buf[0]
    }));

    // Normal draws: per-draw vs the chunked fixed-lane batch (also
    // bit-identical by construction).
    let mut rng = Rng::new(13);
    add(time_fn("rng.normal x256", 30, 2_000, || {
        let mut acc = 0.0;
        for _ in 0..256 {
            acc += rng.normal();
        }
        acc
    }));
    let mut rng = Rng::new(13);
    let mut normal_buf = vec![0.0f64; 256];
    add(time_fn("rng.normal_batch(256)", 30, 2_000, || {
        rng.normal_batch(&mut normal_buf);
        normal_buf[0]
    }));

    // v2 counter-based generator: raw block throughput vs the v1
    // sequential stream, and the lane-oblivious wide gamma (one lane
    // per element, retries isolated to their lane — no
    // snapshot-rewind-replay) in scalar and wide form.
    let mut rng = Rng::new(17);
    add(time_fn("rng2_philox_raw x256 (64 blocks)", 30, 2_000, || {
        let mut acc = 0u64;
        for b in 0..64u64 {
            let out = philox4x64([17, 0xC0FFEE], [b, 0, 7, 15]);
            acc = acc.wrapping_add(out[0] ^ out[3]);
        }
        acc
    }));
    add(time_fn("rng1_xoshiro_raw x256", 30, 2_000, || {
        let mut acc = 0u64;
        for _ in 0..256 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    }));
    let key2 = [17u64, 0xBEEF];
    let site2 = [7u64, 15];
    add(time_fn("rng2_gamma scalar x256 (shape 0.02)", 30, 2_000, || {
        let mut acc = 0.0;
        for lane in 0..256 {
            acc += CounterRng::new(key2, site2, lane).gamma(0.02);
        }
        acc
    }));
    let mut gamma2_buf = vec![0.0f64; 256];
    add(time_fn("rng2_gamma_many2(256, shape 0.02)", 30, 2_000, || {
        gamma_many2(key2, site2, 0.02, &mut gamma2_buf);
        gamma2_buf[0]
    }));

    // Dispatch planning at coordinator scale: 4 ranks × 512 tokens × top-2.
    let parallel = {
        let mut p = paper_parallel();
        p.ep = 4;
        p
    };
    let assignments: Vec<Vec<Vec<u32>>> = {
        let mut rng = Rng::new(3);
        (0..4)
            .map(|_| {
                (0..512)
                    .map(|_| {
                        let a = rng.below(32) as u32;
                        let mut b = rng.below(32) as u32;
                        if b == a {
                            b = (b + 1) % 32;
                        }
                        vec![a, b]
                    })
                    .collect()
            })
            .collect()
    };
    add(time_fn("dispatch.plan (4096 copies)", 10, 100, || {
        dispatch::plan(&parallel, 32, &assignments, 4096).unwrap().placed()
    }));

    // MACT decision: the per-stage entry point (re-derives the Eq. 8
    // budget) vs the hoisted-budget core the fused evaluator calls.
    let run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
    let mact = Mact::new(&run, vec![1, 2, 4, 8]);
    add(time_fn("mact.decide", 1000, 10_000, || {
        mact.decide(1, 250_000).chosen_c
    }));
    let s_max = mact.s_prime_max(1);
    add(time_fn("mact.decide_given (hoisted Eq.8)", 1000, 10_000, || {
        mact.decide_given(s_max, 250_000).chosen_c
    }));

    // FCDA schedule.
    add(time_fn("RecomputeSchedule::build(4096, 8)", 100, 5_000, || {
        RecomputeSchedule::build(4096, 8).steps.len()
    }));

    // Memory model.
    let act = ActivationModel::new(&run);
    add(time_fn("memory.peak_bytes_chunked", 1000, 50_000, || {
        act.peak_bytes_chunked(1, 250_000, 4, true)
    }));

    // The MemFine timing kernel the fused evaluator memoises — one
    // cache miss costs this much, one hit costs a map probe.
    let perf = PerfModel::new(run.model.clone(), run.parallel.clone(), run.dtype_bytes);
    add(time_fn("perf.moe_layer_memfine(250k, c=4)", 1000, 10_000, || {
        perf.moe_layer_memfine(250_000, 4, true).total()
    }));

    // Fused cell evaluation vs per-method trace evaluation on a
    // Model-I cell (3 methods, 10 iterations) — the sweep engine's
    // method-evaluation stage in both shapes, same trace.
    let methods = vec![
        Method::FullRecompute,
        Method::FixedChunk(8),
        Method::Mact(vec![1, 2, 4, 8]),
    ];
    let mut cell_base = paper_run(model_i(), Method::FullRecompute);
    cell_base.iterations = 10;
    let trace = Simulator::new(cell_base.clone()).unwrap().draw_trace();
    add(time_fn("sim.evaluate_cell (Model I, 3 methods)", 5, 200, || {
        evaluate_cell(&cell_base, &methods, &trace).unwrap().len()
    }));
    add(time_fn("3x run_scenario_on_trace (same cell)", 5, 200, || {
        methods
            .iter()
            .map(|m| {
                run_scenario_on_trace(&cell_base, m.clone(), &trace)
                    .unwrap()
                    .oom_iterations
            })
            .sum::<u64>()
    }));

    // JSON parse (manifest-sized doc).
    let doc = {
        let mut s = String::from("{\"entries\":[");
        for i in 0..64 {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"name\":\"e{i}\",\"shape\":[8,1024,256],\"dtype\":\"f32\",\"n\":{i}}}"
            ));
        }
        s.push_str("]}");
        s
    };
    add(time_fn("json.parse (manifest-sized)", 50, 2_000, || {
        memfine::json::parse(&doc).unwrap()
    }));

    // PJRT execute overhead (only with artifacts present).
    if let Ok(store) = memfine::runtime::ArtifactStore::open("artifacts") {
        if store.entries.contains_key("router_topk") {
            let spec = &store.entries["router_topk"].inputs;
            let x = memfine::runtime::HostTensor::F32(vec![0.1; spec[0].elements()]);
            let w = memfine::runtime::HostTensor::F32(vec![0.1; spec[1].elements()]);
            // compile once outside the timer
            store.execute("router_topk", &[x.clone(), w.clone()]).unwrap();
            add(time_fn("pjrt execute router_topk (512×256)", 3, 30, || {
                store.execute("router_topk", &[x.clone(), w.clone()]).unwrap().len()
            }));
        }
    } else {
        eprintln!("(artifacts/ not built — skipping PJRT hot path; run `make artifacts`)");
    }

    report.print();
}
