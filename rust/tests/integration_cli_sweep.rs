//! CLI-level smoke of the resumable/shardable sweep: drives the real
//! `memfine` binary end to end, checking the flag wiring
//! (`--checkpoint/--resume/--shard/--limit`), the artifact files, and
//! that a 2-shard checkpointed split merged by a resume run emits the
//! byte-identical artifact of a direct run — the same contract the
//! in-process tests pin, proven through the shipped interface.

use std::path::PathBuf;
use std::process::Command;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("memfine-it-cli-{}-{name}", std::process::id()));
    p
}

/// Run `memfine sweep` with the common tiny grid plus `extra` args;
/// panics with stderr attached if the process fails.
fn sweep(extra: &[&str]) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_memfine"));
    cmd.args([
        "sweep", "--models", "i", "--methods", "1,3", "--seeds", "2",
        "--iters", "5", "--workers", "2",
    ]);
    cmd.args(extra);
    let out = cmd.output().expect("spawn memfine");
    assert!(
        out.status.success(),
        "memfine sweep {extra:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn cli_two_shard_merge_matches_direct_run() {
    let direct = tmp("direct.json");
    let shard_out = tmp("shard-partial.json");
    let merged = tmp("merged.json");
    let ck0 = tmp("shard0.jsonl");
    let ck1 = tmp("shard1.jsonl");

    sweep(&["--out", direct.to_str().unwrap()]);
    sweep(&[
        "--shard", "0/2",
        "--checkpoint", ck0.to_str().unwrap(),
        "--out", shard_out.to_str().unwrap(),
    ]);
    sweep(&[
        "--shard", "1/2",
        "--checkpoint", ck1.to_str().unwrap(),
        "--out", shard_out.to_str().unwrap(),
    ]);
    let both = format!("{},{}", ck0.to_str().unwrap(), ck1.to_str().unwrap());
    sweep(&[
        "--resume",
        "--checkpoint", &both,
        "--out", merged.to_str().unwrap(),
    ]);

    let direct_bytes = std::fs::read(&direct).expect("direct artifact");
    let merged_bytes = std::fs::read(&merged).expect("merged artifact");
    assert_eq!(
        direct_bytes, merged_bytes,
        "CLI 2-shard merge diverged from the direct artifact"
    );
    // shard checkpoints partition the 4-scenario grid
    let lines = |p: &PathBuf| {
        std::fs::read_to_string(p)
            .unwrap_or_default()
            .lines()
            .count()
    };
    // 4 records + one provenance header per shard file
    assert_eq!(lines(&ck0) + lines(&ck1), 6);

    for p in [&direct, &shard_out, &merged, &ck0, &ck1] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cli_unfused_matches_fused_artifact() {
    // `--unfused` (the pre-fusion per-method engine) must emit the
    // byte-identical artifact of the fused default — and checkpoint
    // rows written by one path must satisfy a resume under the other.
    let fused = tmp("fused.json");
    let unfused = tmp("unfused.json");
    let mixed = tmp("mixed.json");
    let ck = tmp("unfused.jsonl");

    sweep(&["--out", fused.to_str().unwrap()]);
    sweep(&["--unfused", "--out", unfused.to_str().unwrap()]);
    assert_eq!(
        std::fs::read(&fused).expect("fused artifact"),
        std::fs::read(&unfused).expect("unfused artifact"),
        "--unfused diverged from the fused artifact"
    );

    // cross-path checkpoint: rows written unfused, folded by a fused
    // resume run
    sweep(&["--unfused", "--checkpoint", ck.to_str().unwrap(), "--out", "/dev/null"]);
    sweep(&[
        "--resume",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", mixed.to_str().unwrap(),
    ]);
    assert_eq!(
        std::fs::read(&fused).expect("fused artifact"),
        std::fs::read(&mixed).expect("mixed artifact"),
        "unfused checkpoint rows diverged under a fused resume"
    );

    for p in [&fused, &unfused, &mixed, &ck] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cli_limit_then_resume_completes_the_grid() {
    let ck = tmp("limit.jsonl");
    let out_a = tmp("limit-a.json");
    let out_b = tmp("limit-b.json");
    let direct = tmp("limit-direct.json");

    sweep(&["--out", direct.to_str().unwrap()]);
    sweep(&[
        "--limit", "2",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", out_a.to_str().unwrap(),
    ]);
    assert_eq!(
        std::fs::read_to_string(&ck).expect("checkpoint").lines().count(),
        3 // provenance header + 2 records
    );
    sweep(&[
        "--resume",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", out_b.to_str().unwrap(),
    ]);
    assert_eq!(
        std::fs::read(&direct).expect("direct"),
        std::fs::read(&out_b).expect("resumed"),
        "limit-then-resume diverged from the direct artifact"
    );

    for p in [&ck, &out_a, &out_b, &direct] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cli_resumed_capped_slices_complete_the_grid() {
    // `--resume --limit N` must advance by newly-executed scenarios
    // per slice: 4-scenario grid, limit 3 → slice 1 runs 3, slice 2
    // resumes 3 and runs the last 1, emitting the direct artifact.
    let ck = tmp("cap.jsonl");
    let direct = tmp("cap-direct.json");
    let out_a = tmp("cap-a.json");
    let out_b = tmp("cap-b.json");

    sweep(&["--out", direct.to_str().unwrap()]);
    sweep(&[
        "--limit", "3",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", out_a.to_str().unwrap(),
    ]);
    let lines = std::fs::read_to_string(&ck).expect("checkpoint").lines().count();
    assert_eq!(lines, 4); // provenance header + 3 records
    sweep(&[
        "--resume",
        "--limit", "3",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", out_b.to_str().unwrap(),
    ]);
    let lines = std::fs::read_to_string(&ck).expect("checkpoint").lines().count();
    assert_eq!(lines, 5, "the resumed capped slice must run the remaining scenario");
    assert_eq!(
        std::fs::read(&direct).expect("direct"),
        std::fs::read(&out_b).expect("resumed capped"),
        "capped slices diverged from the direct artifact"
    );

    for p in [&ck, &direct, &out_a, &out_b] {
        std::fs::remove_file(p).ok();
    }
}

/// Grid spec file matching the `sweep()` helper's flags, for
/// `--config`-driven subcommands.
fn write_grid_config(path: &PathBuf) {
    let cfg = memfine::config::SweepConfig {
        models: vec!["i".into()],
        methods: vec![
            memfine::config::Method::parse("1").unwrap(),
            memfine::config::Method::parse("3").unwrap(),
        ],
        seeds: memfine::config::derive_seeds(7, 2),
        iterations: 5,
    };
    std::fs::write(path, format!("{}\n", cfg.to_json().to_string_pretty()))
        .expect("write grid config");
}

#[test]
fn cli_checkpoint_compact_and_audit() {
    let ck = tmp("tools.jsonl");
    let cfg_json = tmp("tools-grid.json");
    let compacted = tmp("tools-compacted.jsonl");
    write_grid_config(&cfg_json);

    sweep(&["--checkpoint", ck.to_str().unwrap()]);

    // dirty the checkpoint: duplicate the first record (line 2 —
    // line 1 is the provenance header), tear a tail
    let text = std::fs::read_to_string(&ck).expect("checkpoint");
    let first_record = text.lines().nth(1).expect("has records").to_string();
    let dirty = format!("{text}{first_record}\n{{\"hash\":\"torn");
    std::fs::write(&ck, dirty).expect("dirty checkpoint");

    // compact drops the duplicate and the torn tail
    let out = Command::new(env!("CARGO_BIN_EXE_memfine"))
        .args([
            "checkpoint", "compact", ck.to_str().unwrap(),
            "--out", compacted.to_str().unwrap(),
        ])
        .output()
        .expect("spawn memfine");
    assert!(
        out.status.success(),
        "checkpoint compact failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let lines = std::fs::read_to_string(&compacted).expect("compacted").lines().count();
    assert_eq!(lines, 5, "header + 4 scenarios survive compaction");

    // audit passes on the compacted file against the grid spec
    let out = Command::new(env!("CARGO_BIN_EXE_memfine"))
        .args([
            "checkpoint", "audit", compacted.to_str().unwrap(),
            "--config", cfg_json.to_str().unwrap(),
        ])
        .output()
        .expect("spawn memfine");
    assert!(
        out.status.success(),
        "checkpoint audit failed on a complete set:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    // drop a record (keep the header): the audit must fail with a
    // missing scenario
    let text = std::fs::read_to_string(&compacted).expect("compacted");
    let truncated: Vec<&str> = text
        .lines()
        .enumerate()
        .filter(|&(i, _)| i != 1) // line 0 is the header; drop record 1
        .map(|(_, l)| l)
        .collect();
    std::fs::write(&compacted, format!("{}\n", truncated.join("\n"))).unwrap();
    let out = Command::new(env!("CARGO_BIN_EXE_memfine"))
        .args([
            "checkpoint", "audit", compacted.to_str().unwrap(),
            "--config", cfg_json.to_str().unwrap(),
        ])
        .output()
        .expect("spawn memfine");
    assert!(
        !out.status.success(),
        "checkpoint audit unexpectedly passed on an incomplete set"
    );
    assert!(String::from_utf8_lossy(&out.stderr).contains("missing"));

    for p in [&ck, &cfg_json, &compacted] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cli_launch_matches_direct_sweep_artifact() {
    let direct = tmp("launch-direct.json");
    let launch_out = tmp("launch-out.json");
    let dir = tmp("launch-dir");
    std::fs::remove_dir_all(&dir).ok();

    sweep(&["--out", direct.to_str().unwrap()]);
    let out = Command::new(env!("CARGO_BIN_EXE_memfine"))
        .args([
            "launch",
            "--models", "i", "--methods", "1,3", "--seeds", "2", "--iters", "5",
            "--procs", "2", "--workers", "1", "--poll-ms", "20",
            "--dir", dir.to_str().unwrap(),
            "--out", launch_out.to_str().unwrap(),
        ])
        .output()
        .expect("spawn memfine");
    assert!(
        out.status.success(),
        "memfine launch failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(
        std::fs::read(&direct).expect("direct artifact"),
        std::fs::read(&launch_out).expect("launch artifact"),
        "CLI launch diverged from the direct sweep artifact"
    );
    // the launch dir carries the merged checkpoint and captured specs
    assert!(dir.join("merged.jsonl").exists());
    assert!(dir.join("sweep.json").exists());
    assert!(dir.join("launch.json").exists());

    // the merged checkpoint audits clean against the captured spec
    let out = Command::new(env!("CARGO_BIN_EXE_memfine"))
        .args([
            "checkpoint", "audit",
            dir.join("merged.jsonl").to_str().unwrap(),
            "--config", dir.join("sweep.json").to_str().unwrap(),
        ])
        .output()
        .expect("spawn memfine");
    assert!(
        out.status.success(),
        "merged checkpoint failed its audit:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    std::fs::remove_file(&direct).ok();
    std::fs::remove_file(&launch_out).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_trace_cache_and_router_flags() {
    // --trace-cache: a warm second run must emit identical bytes (and
    // identical to the uncached run — the default sampler everywhere);
    // --router seq must produce a different, deterministic artifact,
    // and --fast-router must remain an alias for the split default.
    let plain = tmp("rc-plain.json");
    let cold = tmp("rc-cold.json");
    let warm = tmp("rc-warm.json");
    let seq_a = tmp("rc-seq-a.json");
    let seq_b = tmp("rc-seq-b.json");
    let alias = tmp("rc-alias.json");
    let cache = tmp("rc-cache");
    std::fs::remove_dir_all(&cache).ok();

    sweep(&["--out", plain.to_str().unwrap()]);
    sweep(&["--trace-cache", cache.to_str().unwrap(), "--out", cold.to_str().unwrap()]);
    sweep(&["--trace-cache", cache.to_str().unwrap(), "--out", warm.to_str().unwrap()]);
    let plain_bytes = std::fs::read(&plain).expect("plain artifact");
    assert_eq!(
        plain_bytes,
        std::fs::read(&cold).expect("cold artifact"),
        "cold cached run diverged from the uncached artifact"
    );
    assert_eq!(
        plain_bytes,
        std::fs::read(&warm).expect("warm artifact"),
        "warm cached run diverged from the cold artifact"
    );
    assert!(cache.is_dir(), "trace cache dir was created");

    sweep(&["--router", "seq", "--out", seq_a.to_str().unwrap()]);
    sweep(&["--router", "seq", "--out", seq_b.to_str().unwrap()]);
    let seq_bytes = std::fs::read(&seq_a).expect("seq artifact");
    assert_eq!(seq_bytes, std::fs::read(&seq_b).expect("seq artifact b"));
    assert_ne!(seq_bytes, plain_bytes, "seq sampler must be a different sample");

    sweep(&["--fast-router", "--out", alias.to_str().unwrap()]);
    assert_eq!(
        plain_bytes,
        std::fs::read(&alias).expect("alias artifact"),
        "--fast-router must alias the split default"
    );

    for p in [&plain, &cold, &warm, &seq_a, &seq_b, &alias] {
        std::fs::remove_file(p).ok();
    }
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn cli_resume_adopts_the_checkpoints_recorded_sampler() {
    // The golden-trace migration promise at the CLI: a checkpoint
    // recorded under the non-default sequential sampler must resume
    // under its recorded provenance (no --router flag needed) — every
    // row folds back and the artifact matches the seq run, not a
    // silently re-executed split-default grid.
    let ck = tmp("recorded.jsonl");
    let seq_direct = tmp("recorded-direct.json");
    let resumed = tmp("recorded-resumed.json");

    sweep(&["--router", "seq", "--out", seq_direct.to_str().unwrap()]);
    sweep(&["--router", "seq", "--checkpoint", ck.to_str().unwrap(), "--out", "/dev/null"]);
    // resume WITHOUT any sampler flag: the header decides
    sweep(&["--resume", "--checkpoint", ck.to_str().unwrap(), "--out", resumed.to_str().unwrap()]);
    assert_eq!(
        std::fs::read(&seq_direct).expect("seq artifact"),
        std::fs::read(&resumed).expect("resumed artifact"),
        "resume did not adopt the checkpoint's recorded sampler"
    );
    // nothing re-ran: the checkpoint still holds header + 4 records
    assert_eq!(
        std::fs::read_to_string(&ck).expect("checkpoint").lines().count(),
        5
    );

    for p in [&ck, &seq_direct, &resumed] {
        std::fs::remove_file(p).ok();
    }
}

/// Like `sweep`, but returns the child's full output so tests can
/// inspect stderr; still panics if the process fails.
fn sweep_capture(extra: &[&str]) -> std::process::Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_memfine"));
    cmd.args([
        "sweep", "--models", "i", "--methods", "1,3", "--seeds", "2",
        "--iters", "5", "--workers", "2",
    ]);
    cmd.args(extra);
    let out = cmd.output().expect("spawn memfine");
    assert!(
        out.status.success(),
        "memfine sweep {extra:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out
}

#[test]
fn cli_rng_v2_artifacts_split_and_resume() {
    // --rng v2 selects the counter-based generator: a different,
    // deterministic sample that is byte-stable across worker counts
    // and forced intra-cell split widths, resumes under its recorded
    // provenance without the flag, and leaves the v1 default
    // untouched (--rng v1 == no flag).
    let plain = tmp("rng-plain.json");
    let v1 = tmp("rng-v1.json");
    let v2a = tmp("rng-v2-a.json");
    let v2b = tmp("rng-v2-b.json");
    let v2split = tmp("rng-v2-split.json");
    let v2wide = tmp("rng-v2-wide.json");
    let resumed = tmp("rng-v2-resumed.json");
    let ck = tmp("rng-v2.jsonl");

    sweep(&["--out", plain.to_str().unwrap()]);
    sweep(&["--rng", "v1", "--out", v1.to_str().unwrap()]);
    sweep(&["--rng", "v2", "--out", v2a.to_str().unwrap()]);
    sweep(&["--rng", "v2", "--out", v2b.to_str().unwrap()]);
    sweep(&["--rng", "v2", "--split-iters", "2", "--out", v2split.to_str().unwrap()]);
    sweep(&["--rng", "v2", "--workers", "8", "--out", v2wide.to_str().unwrap()]);

    let plain_bytes = std::fs::read(&plain).expect("plain artifact");
    assert_eq!(
        plain_bytes,
        std::fs::read(&v1).expect("v1 artifact"),
        "--rng v1 must be byte-identical to the default"
    );
    let v2_bytes = std::fs::read(&v2a).expect("v2 artifact");
    assert_eq!(v2_bytes, std::fs::read(&v2b).expect("v2 artifact b"));
    assert_ne!(v2_bytes, plain_bytes, "v2 must be a different sample");
    assert_eq!(
        v2_bytes,
        std::fs::read(&v2split).expect("v2 split artifact"),
        "forced intra-cell splitting changed the v2 artifact bytes"
    );
    assert_eq!(
        v2_bytes,
        std::fs::read(&v2wide).expect("v2 wide artifact"),
        "worker count changed the v2 artifact bytes"
    );

    // resume WITHOUT --rng: the checkpoint's recorded v2 provenance
    // decides, every row folds back, nothing re-runs
    sweep(&["--rng", "v2", "--checkpoint", ck.to_str().unwrap(), "--out", "/dev/null"]);
    sweep(&["--resume", "--checkpoint", ck.to_str().unwrap(), "--out", resumed.to_str().unwrap()]);
    assert_eq!(
        v2_bytes,
        std::fs::read(&resumed).expect("resumed artifact"),
        "resume did not adopt the checkpoint's recorded rng version"
    );
    assert_eq!(
        std::fs::read_to_string(&ck).expect("checkpoint").lines().count(),
        5, // header + 4 records: the resume folded, not re-ran
    );

    for p in [&plain, &v1, &v2a, &v2b, &v2split, &v2wide, &resumed, &ck] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cli_launch_rng_v2_matches_direct_sweep() {
    // --rng travels the whole orchestration path: launch forwards it
    // to every shard child, and the merged artifact matches a direct
    // single-process v2 sweep byte for byte.
    let direct = tmp("launch-v2-direct.json");
    let launch_out = tmp("launch-v2-out.json");
    let dir = tmp("launch-v2-dir");
    std::fs::remove_dir_all(&dir).ok();

    sweep(&["--rng", "v2", "--out", direct.to_str().unwrap()]);
    let out = Command::new(env!("CARGO_BIN_EXE_memfine"))
        .args([
            "launch",
            "--models", "i", "--methods", "1,3", "--seeds", "2", "--iters", "5",
            "--rng", "v2",
            "--procs", "2", "--workers", "1", "--poll-ms", "20",
            "--dir", dir.to_str().unwrap(),
            "--out", launch_out.to_str().unwrap(),
        ])
        .output()
        .expect("spawn memfine");
    assert!(
        out.status.success(),
        "memfine launch --rng v2 failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(
        std::fs::read(&direct).expect("direct artifact"),
        std::fs::read(&launch_out).expect("launch artifact"),
        "launch --rng v2 diverged from the direct v2 sweep artifact"
    );

    std::fs::remove_file(&direct).ok();
    std::fs::remove_file(&launch_out).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_provenance_mismatch_warns_once_with_shard_context() {
    // A provenance mismatch between the checkpoint header and the
    // running options is reported exactly once per process (not once
    // per resumed row or per file) and names the shard doing the
    // complaining.
    let ck = tmp("mismatch.jsonl");
    let out_json = tmp("mismatch-out.json");

    sweep(&["--router", "seq", "--checkpoint", ck.to_str().unwrap(), "--out", "/dev/null"]);
    // resume under the other sampler, explicitly: the engine must warn
    let out = sweep_capture(&[
        "--resume",
        "--router", "split",
        "--shard", "0/2",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", out_json.to_str().unwrap(),
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(
        stderr.matches("checkpoint records router").count(),
        1,
        "expected exactly one provenance-mismatch warning, stderr:\n{stderr}"
    );
    assert!(
        stderr.contains("shard 0/2"),
        "warning lacks shard context, stderr:\n{stderr}"
    );
    // a matched resume stays quiet
    let out = sweep_capture(&[
        "--resume",
        "--router", "seq",
        "--checkpoint", ck.to_str().unwrap(),
        "--out", "/dev/null",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(
        stderr.matches("checkpoint records router").count(),
        0,
        "matched provenance must not warn, stderr:\n{stderr}"
    );

    for p in [&ck, &out_json] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cli_rejects_bad_shard_and_bare_resume() {
    for args in [&["--shard", "2/2"][..], &["--resume"][..]] {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_memfine"));
        cmd.args(["sweep", "--models", "i", "--methods", "1", "--seeds", "1", "--iters", "2"]);
        cmd.args(args);
        let out = cmd.output().expect("spawn memfine");
        assert!(
            !out.status.success(),
            "memfine sweep {args:?} unexpectedly succeeded"
        );
    }
}
