//! Sampler provenance and RNG versioning — the golden-trace migration
//! layer.
//!
//! A routed trace is a pure function of `(model, parallel, seed,
//! iterations, sampler, rng algorithm)`. The first four live in the
//! run config; this module makes the last two first-class: a
//! [`RouterSampler`] names *which* multinomial consumes the stream and
//! a [`TraceProvenance`] pairs it with the RNG algorithm version. The
//! provenance is baked into every scenario content hash
//! ([`crate::sweep::checkpoint::scenario_hash`]), written as a header
//! line into every checkpoint file, stamped into the sweep report
//! artifact, and keyed into the on-disk trace cache
//! ([`crate::trace::store::TraceStore`]).
//!
//! That record is what made flipping the **default** router sampler to
//! the splitting multinomial safe: artifacts drawn under the old
//! sequential sampler keep resuming and auditing under their recorded
//! `router: "seq"` tag (their hashes never collide with split-sampler
//! runs), while new campaigns get the fast sampler without asking.
//! Likewise, any future change to the generator itself bumps
//! [`RNG_VERSION`], which perturbs every hash and trace key from that
//! point on — old artifacts stay valid under version 1, and version 1
//! deliberately serialises to the exact historical hash documents so
//! no pre-existing checkpoint is orphaned by this layer's
//! introduction.

use crate::error::{Error, Result};
use crate::json::{self, Value};

/// The version-1 RNG stack (the default). Part of the recorded
/// provenance: a different algorithm would be a different (equally
/// valid) sample, exactly like a sampler change.
pub const RNG_ALGORITHM: &str = "splitmix64+xoshiro256**";

/// The version-2 RNG stack: the counter-based generator behind
/// `--rng v2` ([`crate::util::rng::philox4x64`]).
pub const RNG2_ALGORITHM: &str = "philox4x64-10";

/// Version of the **default** drawn bit-streams. Bump this when any
/// sampler or generator change alters the default drawn bits (the
/// batched/vectorised kernels do **not** — they are pinned
/// bit-identical to the scalar paths); version 1 hashes serialise
/// exactly as the pre-provenance era did, so all historical
/// checkpoints remain resumable. Version 2 (counter-based Philox) is
/// opt-in via `--rng v2` and always perturbs hashes through
/// [`TraceProvenance::hash_fields`].
pub const RNG_VERSION: u64 = 1;

/// Which generator draws the trace streams. v1 is the sequential
/// xoshiro256** fork-per-(iteration, layer) stack — the default, and
/// the version every historical artifact was drawn under. v2 is the
/// counter-based Philox4x64-10 stack: every draw site is an O(1) pure
/// function of its (key, iteration, layer, lane, word) coordinate,
/// which is what makes intra-cell iteration splitting and
/// lane-oblivious batch sampling possible.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum RngVersion {
    #[default]
    V1,
    V2,
}

impl RngVersion {
    /// The CLI / JSON tag ("v1" / "v2").
    pub fn tag(&self) -> &'static str {
        match self {
            RngVersion::V1 => "v1",
            RngVersion::V2 => "v2",
        }
    }

    /// The numeric form recorded in provenance documents.
    pub fn as_u64(&self) -> u64 {
        match self {
            RngVersion::V1 => 1,
            RngVersion::V2 => 2,
        }
    }

    /// Parse a CLI tag (`--rng v1|v2`; bare digits accepted).
    pub fn parse(tag: &str) -> Result<Self> {
        match tag.trim() {
            "v1" | "1" => Ok(RngVersion::V1),
            "v2" | "2" => Ok(RngVersion::V2),
            other => Err(Error::config(format!(
                "unknown rng version '{other}' (expected v1 or v2)"
            ))),
        }
    }

    /// Map a recorded `rng_version` number back to a generator this
    /// build can execute (errors on versions from the future).
    pub fn from_u64(v: u64) -> Result<Self> {
        match v {
            1 => Ok(RngVersion::V1),
            2 => Ok(RngVersion::V2),
            other => Err(Error::config(format!(
                "recorded rng_version {other} is not supported by this build (knows 1, 2)"
            ))),
        }
    }

    /// Human name of the generator stack this version selects.
    pub fn algorithm(&self) -> &'static str {
        match self {
            RngVersion::V1 => RNG_ALGORITHM,
            RngVersion::V2 => RNG2_ALGORITHM,
        }
    }
}

/// Which multinomial consumes the routing stream. Both draw the same
/// distribution over the same forked streams; they consume the raw
/// u64 stream in different orders, so they are two different (equally
/// valid) samples and therefore part of every trace identity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouterSampler {
    /// Left-to-right conditional-binomial chain
    /// ([`crate::util::rng::Rng::multinomial`]) — the historical
    /// default, kept reachable as `--router seq` so pre-flip artifacts
    /// can be reproduced and resumed.
    Sequential,
    /// Recursive binomial splitting
    /// ([`crate::util::rng::Rng::multinomial_split`]) — cost scales
    /// with *populated* categories instead of `n_experts`, which on
    /// the router's peaky popularity vectors makes it materially
    /// faster. **The default sampler** since the trace-store PR (the
    /// provenance record above is the migration story).
    #[default]
    Split,
}

impl RouterSampler {
    /// The short tag hashed into scenario identities and written into
    /// headers/artifacts ("seq" / "split"). Stable forever — it is
    /// load-bearing in every recorded hash.
    pub fn tag(&self) -> &'static str {
        match self {
            RouterSampler::Sequential => "seq",
            RouterSampler::Split => "split",
        }
    }

    /// Parse a tag back (CLI `--router`, artifact headers).
    pub fn parse(tag: &str) -> Result<Self> {
        match tag.trim() {
            "seq" | "sequential" => Ok(RouterSampler::Sequential),
            "split" | "fast" => Ok(RouterSampler::Split),
            other => Err(Error::config(format!(
                "unknown router sampler '{other}' (expected seq or split)"
            ))),
        }
    }

    /// The historical `fast_router: bool` encoding (true = split),
    /// still accepted in legacy `launch.json` files.
    pub fn from_fast_flag(fast: bool) -> Self {
        if fast {
            RouterSampler::Split
        } else {
            RouterSampler::Sequential
        }
    }
}

/// Everything that decides the drawn bits of a trace besides the run
/// config: the sampler and the RNG algorithm version. Recorded in
/// checkpoint headers, report metadata and trace-cache keys; hashed
/// into every scenario identity.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceProvenance {
    pub sampler: RouterSampler,
    pub rng_version: u64,
}

impl Default for TraceProvenance {
    /// The current engine default: splitting sampler, current RNG
    /// version.
    fn default() -> Self {
        TraceProvenance::current(RouterSampler::default())
    }
}

impl TraceProvenance {
    /// Provenance of traces drawn by this build with the given sampler
    /// under the default generator.
    pub fn current(sampler: RouterSampler) -> Self {
        TraceProvenance { sampler, rng_version: RNG_VERSION }
    }

    /// Provenance of traces drawn with an explicit (sampler, rng
    /// version) pair — the `--rng` form of [`TraceProvenance::current`]
    /// (identical to it for [`RngVersion::V1`]).
    pub fn with(sampler: RouterSampler, rng: RngVersion) -> Self {
        TraceProvenance { sampler, rng_version: rng.as_u64() }
    }

    /// The recorded rng version as an executable generator selection
    /// (errors on a version this build does not know).
    pub fn rng(&self) -> Result<RngVersion> {
        RngVersion::from_u64(self.rng_version)
    }

    /// Provenance of pre-flip default-path artifacts (sequential
    /// sampler, version 1) — what a legacy checkpoint without a header
    /// was drawn under.
    pub fn legacy_sequential() -> Self {
        TraceProvenance { sampler: RouterSampler::Sequential, rng_version: 1 }
    }

    /// The provenance fields of a hash document. Version 1 contributes
    /// exactly the historical `{"router": tag}` field — and nothing
    /// else — so every hash recorded before this layer existed is
    /// preserved; later versions add `rng_version` and thereby perturb
    /// every hash, which is the point.
    pub fn hash_fields(&self) -> Vec<(&'static str, Value)> {
        let mut fields = vec![("router", json::s(self.tag().to_string()))];
        if self.rng_version != 1 {
            fields.push(("rng_version", json::num(self.rng_version as f64)));
        }
        fields
    }

    /// The sampler tag (see [`RouterSampler::tag`]).
    pub fn tag(&self) -> &'static str {
        self.sampler.tag()
    }

    /// Full metadata form (checkpoint headers, report artifacts). The
    /// algorithm name follows the recorded version (unknown future
    /// versions are labelled by number only); version-1 output is
    /// byte-identical to the historical form.
    pub fn to_json(&self) -> Value {
        let algorithm = match RngVersion::from_u64(self.rng_version) {
            Ok(v) => v.algorithm().to_string(),
            Err(_) => format!("rng_version_{}", self.rng_version),
        };
        json::obj(vec![
            ("router", json::s(self.tag().to_string())),
            ("rng_algorithm", json::s(algorithm)),
            ("rng_version", json::num(self.rng_version as f64)),
        ])
    }

    /// Parse the metadata form back (headers of future versions may
    /// carry a different `rng_version`; `rng_algorithm` is
    /// informational and not validated here).
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(TraceProvenance {
            sampler: RouterSampler::parse(v.req_str("router")?)?,
            rng_version: v.req_u64("rng_version")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sampler_is_split() {
        // THE flip: the engine-wide default sampler is the splitting
        // multinomial; the sequential sampler stays reachable by tag.
        assert_eq!(RouterSampler::default(), RouterSampler::Split);
        assert_eq!(TraceProvenance::default().sampler, RouterSampler::Split);
        assert_eq!(TraceProvenance::default().rng_version, RNG_VERSION);
    }

    #[test]
    fn tags_parse_and_roundtrip() {
        for s in [RouterSampler::Sequential, RouterSampler::Split] {
            assert_eq!(RouterSampler::parse(s.tag()).unwrap(), s);
        }
        assert_eq!(
            RouterSampler::parse("fast").unwrap(),
            RouterSampler::Split
        );
        assert!(RouterSampler::parse("bogus").is_err());
        assert_eq!(RouterSampler::from_fast_flag(true), RouterSampler::Split);
        assert_eq!(
            RouterSampler::from_fast_flag(false),
            RouterSampler::Sequential
        );
    }

    #[test]
    fn version_1_hash_fields_match_the_historical_doc() {
        // The migration contract: version-1 provenance contributes the
        // exact pre-provenance hash field, nothing more.
        let seq = TraceProvenance::legacy_sequential();
        let doc = json::obj(seq.hash_fields());
        assert_eq!(doc.to_string_compact(), "{\"router\":\"seq\"}");
        let split = TraceProvenance::current(RouterSampler::Split);
        let doc = json::obj(split.hash_fields());
        assert_eq!(doc.to_string_compact(), "{\"router\":\"split\"}");
        // a future version perturbs the doc
        let v2 = TraceProvenance { sampler: RouterSampler::Split, rng_version: 2 };
        assert!(json::obj(v2.hash_fields())
            .to_string_compact()
            .contains("rng_version"));
    }

    #[test]
    fn rng_version_tags_parse_and_roundtrip() {
        assert_eq!(RngVersion::default(), RngVersion::V1);
        for v in [RngVersion::V1, RngVersion::V2] {
            assert_eq!(RngVersion::parse(v.tag()).unwrap(), v);
            assert_eq!(RngVersion::from_u64(v.as_u64()).unwrap(), v);
        }
        assert_eq!(RngVersion::parse("1").unwrap(), RngVersion::V1);
        assert_eq!(RngVersion::parse("2").unwrap(), RngVersion::V2);
        assert!(RngVersion::parse("v3").is_err());
        assert!(RngVersion::from_u64(7).is_err());
        assert_eq!(RngVersion::V1.algorithm(), RNG_ALGORITHM);
        assert_eq!(RngVersion::V2.algorithm(), RNG2_ALGORITHM);
    }

    #[test]
    fn with_rng_matches_current_for_v1_and_perturbs_for_v2() {
        // the migration contract extended to --rng: v1 provenance is
        // indistinguishable from the historical default...
        let s = RouterSampler::Split;
        assert_eq!(TraceProvenance::with(s, RngVersion::V1), TraceProvenance::current(s));
        assert_eq!(
            json::obj(TraceProvenance::with(s, RngVersion::V1).hash_fields())
                .to_string_compact(),
            "{\"router\":\"split\"}"
        );
        // ...while v2 adds the rng_version hash field and names its
        // algorithm in the metadata form
        let v2 = TraceProvenance::with(s, RngVersion::V2);
        assert_eq!(v2.rng().unwrap(), RngVersion::V2);
        assert!(json::obj(v2.hash_fields()).to_string_compact().contains("rng_version"));
        assert!(v2.to_json().to_string_compact().contains(RNG2_ALGORITHM));
    }

    #[test]
    fn metadata_json_roundtrip() {
        for p in [
            TraceProvenance::default(),
            TraceProvenance::legacy_sequential(),
            TraceProvenance { sampler: RouterSampler::Split, rng_version: 3 },
        ] {
            let back = TraceProvenance::from_json(&p.to_json()).unwrap();
            assert_eq!(back, p);
        }
        // the metadata form names the algorithm
        let text = TraceProvenance::default().to_json().to_string_compact();
        assert!(text.contains(RNG_ALGORITHM));
    }
}
