//! Injectable IO-fault seam for chaos drills.
//!
//! Writers that participate in the degradation ladder (the checkpoint
//! record stream, the trace store, the event log) call
//! [`check`] with their site tag immediately before touching the
//! filesystem. In production the seam is a single relaxed atomic load
//! and nothing else. Under a chaos drill the seam is armed — either
//! in-process via [`inject`] (supervisor scope) or through the
//! `MEMFINE_FAULT_INJECT` environment variable that `memfine launch`
//! sets on shard children (children scope) — and the next `count`
//! calls for that site fail with a real `std::io::Error` carrying the
//! requested errno (ENOSPC / EIO), exactly as a full disk or a dying
//! device would surface it.
//!
//! The env format is `site:kind:count[,site:kind:count...]`, e.g.
//! `checkpoint:enospc:1,trace-store:eio:2`. Unknown entries are
//! ignored with a warning so a newer launcher can drill an older
//! binary.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, OnceLock};

use crate::logging;

/// Environment variable `memfine launch` uses to arm faults in shard
/// child processes.
pub const FAULT_ENV: &str = "MEMFINE_FAULT_INJECT";

/// Site tag for the streaming checkpoint record writer.
pub const SITE_CHECKPOINT: &str = "checkpoint";
/// Site tag for the on-disk trace store.
pub const SITE_TRACE_STORE: &str = "trace-store";
/// Site tag for the sidecar event log.
pub const SITE_EVENT_LOG: &str = "event-log";

/// The errno an armed fault surfaces as.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `ENOSPC` — no space left on device.
    Enospc,
    /// `EIO` — low-level IO error.
    Eio,
}

impl FaultKind {
    /// Parse the plan/env spelling (`enospc` / `eio`).
    pub fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "enospc" => Some(FaultKind::Enospc),
            "eio" => Some(FaultKind::Eio),
            _ => None,
        }
    }

    /// The plan/env spelling.
    pub fn tag(self) -> &'static str {
        match self {
            FaultKind::Enospc => "enospc",
            FaultKind::Eio => "eio",
        }
    }

    fn to_io_error(self) -> std::io::Error {
        // Raw POSIX errnos so callers see the same ErrorKind a real
        // full disk / failing device would produce.
        let errno = match self {
            FaultKind::Enospc => 28, // ENOSPC
            FaultKind::Eio => 5,     // EIO
        };
        std::io::Error::from_raw_os_error(errno)
    }
}

struct Armed {
    site: String,
    kind: FaultKind,
    remaining: u64,
}

/// Fast-path flag: false means `check` is a single relaxed load.
static ANY_ARMED: AtomicBool = AtomicBool::new(false);
static ENV_PARSED: Once = Once::new();

fn table() -> &'static Mutex<Vec<Armed>> {
    static TABLE: OnceLock<Mutex<Vec<Armed>>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(Vec::new()))
}

fn parse_env_once() {
    ENV_PARSED.call_once(|| {
        let Ok(spec) = std::env::var(FAULT_ENV) else {
            return;
        };
        for entry in spec.split(',').filter(|e| !e.is_empty()) {
            let parts: Vec<&str> = entry.split(':').collect();
            let parsed = match parts.as_slice() {
                [site, kind, count] => FaultKind::parse(kind)
                    .zip(count.parse::<u64>().ok())
                    .map(|(k, c)| (site.to_string(), k, c)),
                _ => None,
            };
            match parsed {
                Some((site, kind, count)) => inject(&site, kind, count),
                None => logging::warn(
                    "faultfs",
                    &format!("ignoring malformed {FAULT_ENV} entry {entry:?}"),
                ),
            }
        }
    });
}

/// Arm `count` faults of `kind` against `site`. Counts accumulate if
/// the same (site, kind) pair is armed twice.
pub fn inject(site: &str, kind: FaultKind, count: u64) {
    if count == 0 {
        return;
    }
    let mut t = table().lock().unwrap_or_else(|p| p.into_inner());
    if let Some(a) = t.iter_mut().find(|a| a.site == site && a.kind == kind) {
        a.remaining = a.remaining.saturating_add(count);
    } else {
        t.push(Armed {
            site: site.to_string(),
            kind,
            remaining: count,
        });
    }
    ANY_ARMED.store(true, Ordering::Release);
    logging::warn(
        "faultfs",
        &format!("armed {count} injected {} fault(s) on site {site:?}", kind.tag()),
    );
}

/// Disarm everything (test hygiene).
pub fn clear() {
    let mut t = table().lock().unwrap_or_else(|p| p.into_inner());
    t.clear();
    ANY_ARMED.store(false, Ordering::Release);
}

/// The seam. Returns `Err` with the armed errno if a fault for `site`
/// is pending, consuming one charge; `Ok(())` otherwise. Disarmed
/// cost: one relaxed atomic load.
pub fn check(site: &str) -> std::io::Result<()> {
    parse_env_once();
    if !ANY_ARMED.load(Ordering::Acquire) {
        return Ok(());
    }
    let mut t = table().lock().unwrap_or_else(|p| p.into_inner());
    let Some(a) = t
        .iter_mut()
        .find(|a| a.site == site && a.remaining > 0)
    else {
        return Ok(());
    };
    a.remaining -= 1;
    let kind = a.kind;
    if t.iter().all(|a| a.remaining == 0) {
        ANY_ARMED.store(false, Ordering::Release);
    }
    Err(kind.to_io_error())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The armed table is process-global, so every assertion about it
    // lives in this one test: cargo runs tests in the same process and
    // parallel tests would otherwise race each other's charges.
    #[test]
    fn seam_is_quiet_then_fails_exactly_count_times_per_site() {
        clear();
        assert!(check(SITE_CHECKPOINT).is_ok());
        inject(SITE_CHECKPOINT, FaultKind::Enospc, 2);
        inject(SITE_TRACE_STORE, FaultKind::Eio, 1);
        // other sites unaffected
        assert!(check(SITE_EVENT_LOG).is_ok());
        let e1 = check(SITE_CHECKPOINT).unwrap_err();
        assert_eq!(e1.raw_os_error(), Some(28));
        let e2 = check(SITE_TRACE_STORE).unwrap_err();
        assert_eq!(e2.raw_os_error(), Some(5));
        assert!(check(SITE_TRACE_STORE).is_ok(), "charge consumed");
        assert!(check(SITE_CHECKPOINT).is_err());
        assert!(check(SITE_CHECKPOINT).is_ok(), "both charges consumed");
        clear();
        assert!(check(SITE_CHECKPOINT).is_ok());
    }

    #[test]
    fn fault_kind_round_trips_its_tag() {
        for k in [FaultKind::Enospc, FaultKind::Eio] {
            assert_eq!(FaultKind::parse(k.tag()), Some(k));
        }
        assert_eq!(FaultKind::parse("enoent"), None);
    }
}
