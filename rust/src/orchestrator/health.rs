//! Shard liveness from checkpoint growth: a shard process proves it is
//! making progress by appending completed-scenario lines to its
//! checkpoint file, so the supervisor never needs an IPC channel — the
//! kill-safe artifact the sweep engine already writes doubles as the
//! heartbeat. A shard whose checkpoint has not changed for longer than
//! the stall timeout is presumed wedged (deadlocked child, hung I/O,
//! livelocked host) and is killed and relaunched with `--resume`.
//!
//! The monitor is pure over injected clocks (`Instant` values are
//! passed in, never sampled), so stall logic is unit-testable without
//! sleeping.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Once;
use std::time::{Duration, Instant};

/// Current checkpoint size in bytes, `None` while the file does not
/// exist yet (child still starting up).
pub fn probe_len(path: &Path) -> Option<u64> {
    std::fs::metadata(path).ok().map(|m| m.len())
}

/// Times this process observed a file mtime in the future — the
/// `health.clock_skew` counter. On a shared campaign dir (NFS between
/// hosts) a writer's clock running ahead of ours puts mtimes in our
/// future; each such probe bumps this instead of erasing the
/// heartbeat.
static CLOCK_SKEW: AtomicU64 = AtomicU64::new(0);
static CLOCK_SKEW_WARN: Once = Once::new();

/// How many mtime probes hit cross-host clock skew so far (the
/// `health.clock_skew` metric; process-lifetime, observability only).
pub fn clock_skew_count() -> u64 {
    CLOCK_SKEW.load(Ordering::Relaxed)
}

/// Time since the file was last modified — `memfine status` renders it
/// as heartbeat freshness. `None` when the file does not exist or the
/// filesystem has no mtimes. An mtime in the future (another host's
/// skewed clock wrote it) clamps to `Some(ZERO)` — the file was just
/// touched, which is the freshest heartbeat there is — and counts a
/// `health.clock_skew` metric with a one-time warning, rather than
/// reading as a dead file.
pub fn probe_mtime_age(path: &Path) -> Option<Duration> {
    let mtime = std::fs::metadata(path).ok()?.modified().ok()?;
    match mtime.elapsed() {
        Ok(age) => Some(age),
        Err(skew) => {
            CLOCK_SKEW.fetch_add(1, Ordering::Relaxed);
            CLOCK_SKEW_WARN.call_once(|| {
                eprintln!(
                    "memfine: warning: {} has an mtime {:.1}s in the future \
                     (cross-host clock skew?); clamping heartbeat age to 0 \
                     [health.clock_skew]",
                    path.display(),
                    skew.duration().as_secs_f64(),
                );
            });
            Some(Duration::ZERO)
        }
    }
}

/// Progress tracker for one shard's checkpoint file.
#[derive(Clone, Debug)]
pub struct HeartbeatMonitor {
    last_len: Option<u64>,
    last_progress: Instant,
}

impl HeartbeatMonitor {
    pub fn new(now: Instant) -> Self {
        HeartbeatMonitor { last_len: None, last_progress: now }
    }

    /// Feed one observation of the checkpoint size. Any change —
    /// growth, appearance, even truncation — counts as progress and
    /// rewinds the stall clock; returns whether this observation was
    /// progress.
    pub fn observe(&mut self, len: Option<u64>, now: Instant) -> bool {
        if len != self.last_len {
            self.last_len = len;
            self.last_progress = now;
            true
        } else {
            false
        }
    }

    /// Restart the stall clock (a fresh child was just spawned) while
    /// keeping the last seen size, so the respawned child's untouched
    /// checkpoint does not read as instant progress.
    pub fn reset(&mut self, now: Instant) {
        self.last_progress = now;
    }

    /// Time since the last observed progress (or since `new`/`reset`).
    pub fn idle(&self, now: Instant) -> Duration {
        now.saturating_duration_since(self.last_progress)
    }

    /// Whether the shard has gone longer than `timeout` without
    /// progress.
    pub fn stalled(&self, timeout: Duration, now: Instant) -> bool {
        self.idle(now) >= timeout
    }

    /// Last observed checkpoint size (`None` = never seen the file).
    pub fn last_len(&self) -> Option<u64> {
        self.last_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn growth_rewinds_the_stall_clock() {
        let t0 = Instant::now();
        let mut m = HeartbeatMonitor::new(t0);
        let timeout = 100 * MS;
        // file appears: progress
        assert!(m.observe(Some(0), t0 + 10 * MS));
        // grows: progress
        assert!(m.observe(Some(64), t0 + 50 * MS));
        assert_eq!(m.last_len(), Some(64));
        // unchanged: no progress, but not yet stalled
        assert!(!m.observe(Some(64), t0 + 100 * MS));
        assert!(!m.stalled(timeout, t0 + 149 * MS));
        // 100 ms past the last change: stalled
        assert!(m.stalled(timeout, t0 + 150 * MS));
        assert_eq!(m.idle(t0 + 150 * MS), 100 * MS);
        // growth after the stall read rewinds the clock again
        assert!(m.observe(Some(128), t0 + 151 * MS));
        assert!(!m.stalled(timeout, t0 + 250 * MS));
    }

    #[test]
    fn missing_file_stalls_from_construction() {
        let t0 = Instant::now();
        let mut m = HeartbeatMonitor::new(t0);
        assert_eq!(m.last_len(), None);
        // never-appearing checkpoint: no observation is progress
        assert!(!m.observe(None, t0 + 30 * MS));
        assert!(m.stalled(50 * MS, t0 + 60 * MS));
    }

    #[test]
    fn reset_rewinds_clock_but_keeps_size() {
        let t0 = Instant::now();
        let mut m = HeartbeatMonitor::new(t0);
        assert!(m.observe(Some(32), t0 + 10 * MS));
        m.reset(t0 + 200 * MS);
        assert_eq!(m.last_len(), Some(32));
        assert!(!m.stalled(100 * MS, t0 + 250 * MS));
        // the unchanged file is still not progress after a reset
        assert!(!m.observe(Some(32), t0 + 260 * MS));
        assert!(m.stalled(100 * MS, t0 + 300 * MS));
    }

    #[test]
    fn probe_len_reads_real_files() {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-health-{}", std::process::id()));
        std::fs::remove_file(&p).ok();
        assert_eq!(probe_len(&p), None);
        std::fs::write(&p, b"12345").unwrap();
        assert_eq!(probe_len(&p), Some(5));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn probe_mtime_age_tracks_fresh_writes() {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-health-mtime-{}", std::process::id()));
        std::fs::remove_file(&p).ok();
        assert_eq!(probe_mtime_age(&p), None);
        std::fs::write(&p, b"x").unwrap();
        let age = probe_mtime_age(&p).expect("file exists");
        assert!(age < Duration::from_secs(60));
        std::fs::remove_file(&p).ok();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn future_mtime_clamps_to_zero_and_counts_skew() {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-health-skew-{}", std::process::id()));
        std::fs::write(&p, b"x").unwrap();
        // stamp the file one hour into the future, as a skewed peer
        // host writing the shared campaign dir would (GNU touch -d)
        let future = std::time::SystemTime::now() + Duration::from_secs(3600);
        let epoch = future
            .duration_since(std::time::SystemTime::UNIX_EPOCH)
            .unwrap()
            .as_secs();
        let ok = std::process::Command::new("touch")
            .arg("-d")
            .arg(format!("@{epoch}"))
            .arg(&p)
            .status()
            .map(|s| s.success())
            .unwrap_or(false);
        assert!(ok, "GNU touch -d @epoch available on linux CI");
        let before = clock_skew_count();
        // not None (the old behaviour: a skewed writer read as dead)
        // but a zero age: freshest possible heartbeat
        assert_eq!(probe_mtime_age(&p), Some(Duration::ZERO));
        assert!(clock_skew_count() > before);
        std::fs::remove_file(&p).ok();
    }
}
